PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench bench-serving example-serve docs-check

# tier-1 verification (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# docs job: markdown links resolve + doctested examples run
docs-check:
	$(PY) tools/check_docs.py

bench:
	$(PY) benchmarks/run.py

bench-serving:
	$(PY) benchmarks/run.py serving

example-serve:
	$(PY) examples/serve_pruned.py
