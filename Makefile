PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench bench-serving example-serve

# tier-1 verification (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

bench:
	$(PY) benchmarks/run.py

bench-serving:
	$(PY) benchmarks/run.py serving

example-serve:
	$(PY) examples/serve_pruned.py
