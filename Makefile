PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)
# extra pytest flags (CI passes --timeout=N; needs pytest-timeout)
PYTEST_FLAGS ?=

.PHONY: test test-fast test-stress test-stats bench bench-serving \
	bench-slo trace-smoke example-serve docs-check lint

# tier-1 verification (ROADMAP.md) — runs everything
test:
	$(PY) -m pytest -x -q $(PYTEST_FLAGS)

# CI split: deterministic tests vs randomized/property stress suites.
# Statistical tests (@pytest.mark.stats, tests/stats.py) run in both: a
# fixed-seed subset lands in test-fast; stats+stress tests widen their
# seed matrix in the stress job under REPRO_STATS_WIDE=1.
test-fast:
	$(PY) -m pytest -q -m "not stress" $(PYTEST_FLAGS)

test-stress:
	$(PY) -m pytest -q -m stress $(PYTEST_FLAGS)

# every statistical claim in one run (helper self-tests, spec-sampling
# equivalence oracle, f8-KV agreement) — explicit alpha/n throughout
test-stats:
	$(PY) -m pytest -q -m stats $(PYTEST_FLAGS)

# docs job: markdown links resolve + doctested examples run
docs-check:
	$(PY) tools/check_docs.py

# lint job: dispatch-safety static analysis (aliasing-hazard,
# jit-discipline, pallas-invariants, dtype-discipline,
# timing-discipline) — stdlib-only, fails on any finding or unexplained
# suppression; benchmarks/ additionally gets the wall-clock hygiene pass
lint:
	$(PY) tools/lint_repro.py src/ --strict
	$(PY) tools/lint_repro.py benchmarks/ --check timing-discipline --strict

bench:
	$(PY) benchmarks/run.py

bench-serving:
	$(PY) benchmarks/run.py serving

# open-loop SLO harness: Poisson wall-clock arrivals, per-request
# TTFT/TPOT attainment, QPS bisection per engine config; merges the
# `slo` section into BENCH_serving.json and asserts attainment degrades
# monotonically with offered load
bench-slo:
	$(PY) benchmarks/run.py slo

# trace-driven replay smoke: serve the committed bursty workload trace
# through the telemetry-instrumented engine, export Chrome-trace JSON,
# validate it structurally, and merge the disaggregated stage timing
# (`trace_replay` section) into BENCH_serving.json
trace-smoke:
	$(PY) benchmarks/bench_slo.py \
		--replay benchmarks/traces/bursty_small.jsonl \
		--trace trace_replay.json
	$(PY) tools/validate_trace.py trace_replay.json

example-serve:
	$(PY) examples/serve_pruned.py
