"""Checkpoint store: optional-zstd codec, roundtrip, atomicity basics."""
import numpy as np
import pytest

import repro.checkpoint.store as store
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _tree():
    rs = np.random.RandomState(0)
    return {"params": {"w": rs.randn(4, 3).astype(np.float32),
                       "layers": {"0": {"b": rs.randn(5).astype(np.float16)}}},
            "step_count": np.int64(7)}


def test_roundtrip_records_codec(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, _tree())
    assert latest_step(d) == 3
    step, tree = restore_checkpoint(d)
    assert step == 3
    ref = _tree()
    np.testing.assert_array_equal(tree["params"]["w"], ref["params"]["w"])
    np.testing.assert_array_equal(tree["params"]["layers"]["0"]["b"],
                                  ref["params"]["layers"]["0"]["b"])
    # manifest must say which codec wrote the shard
    import msgpack
    import os
    mpath = os.path.join(d, "step_00000003", "manifest.msgpack")
    with open(mpath, "rb") as f:
        manifest = msgpack.unpackb(f.read())
    assert manifest["codec"] == ("zstd" if store.HAVE_ZSTD else "raw")


def test_raw_codec_roundtrip_without_zstd(tmp_path, monkeypatch):
    """Force the raw fallback even when zstandard is installed."""
    monkeypatch.setattr(store, "HAVE_ZSTD", False)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _tree())
    _, tree = restore_checkpoint(d)
    np.testing.assert_array_equal(tree["params"]["w"], _tree()["params"]["w"])


def test_zstd_shard_without_module_raises(tmp_path, monkeypatch):
    if not store.HAVE_ZSTD:
        # emulate a zstd-written checkpoint arriving in a zstd-less env
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 1, _tree())
        import msgpack
        import os
        mpath = os.path.join(d, "step_00000001", "manifest.msgpack")
        with open(mpath, "rb") as f:
            manifest = msgpack.unpackb(f.read())
        manifest["codec"] = "zstd"
        with open(mpath, "wb") as f:
            f.write(msgpack.packb(manifest))
        with pytest.raises(RuntimeError, match="zstandard"):
            restore_checkpoint(d)
    else:  # with zstd present just check the decoder rejects junk codecs
        with pytest.raises(ValueError):
            store._decode_shard("lz99", b"x")


def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        store._decode_shard("gzip", b"")
