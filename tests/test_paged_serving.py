"""Paged KV cache + paged serving engine: property/stress coverage.

Drives random submit/decode/finish sequences through ``PagedKVCache`` and
the paged ``ServeEngine`` and asserts the page-table invariants
(kv_cache.py module docstring): no page owned by two lanes, the sentinel
page is never allocated, freed pages return to the pool.  Generation
correctness is pinned three ways — the paged engine must be
token-identical to the PR-1 slot engine, and both to a teacher-forced
``forward()`` replay — across dense, windowed-attention, runtime
``expert_mask``, and stage-2 weight-mask configurations.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import abstract_params, forward
from repro.models import param as pm
from repro.serving import PagedKVCache, Request, Scheduler, ServeEngine


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _greedy_reference(params, cfg, prompt, n_tokens):
    """Teacher-forced forward() replay — the token-by-token oracle."""
    seq = list(np.asarray(prompt))
    out = []
    for _ in range(n_tokens):
        lg = forward(params, cfg, {"tokens": jnp.asarray([seq])})
        tok = int(jnp.argmax(lg[0, -1, : cfg.vocab]))
        out.append(tok)
        seq.append(tok)
    return np.asarray(out, np.int32)


def _check_invariants(cache: PagedKVCache):
    owned = []
    for slot, pages in cache._pages_of.items():
        assert 0 not in pages, f"sentinel page allocated to lane {slot}"
        owned.extend(pages)
        width = len(pages)
        np.testing.assert_array_equal(cache.page_table[slot, :width], pages)
        assert (cache.page_table[slot, width:] == 0).all(), \
            "table entries past the reservation must point at the sentinel"
        assert int(cache.seq_lens[slot]) <= width * cache.page_size, \
            "valid rows extend past the lane's page reservation"
    assert len(owned) == len(set(owned)), "page owned by two lanes"
    assert 0 not in cache._free_pages, "sentinel in the free pool"
    assert len(cache._free_pages) + len(owned) == cache.page_budget, \
        "pages leaked or double-freed"
    free_lanes = set(cache._free_slots)
    for slot in free_lanes:
        assert (cache.page_table[slot] == 0).all(), \
            "freed lane still maps real pages"


# ---------------------------------------------------------------------------
# cache-level property test: random alloc/free sequences
# ---------------------------------------------------------------------------


@pytest.mark.stress
def test_page_table_invariants_random_lifecycle(moe):
    cfg, _ = moe
    rs = np.random.RandomState(0)
    cache = PagedKVCache(cfg, n_slots=4, max_len=64, page_size=8,
                         page_budget=20)
    live = {}
    for step in range(400):
        if live and (rs.rand() < 0.45 or len(live) == 4):
            slot = rs.choice(sorted(live))
            cache.release(slot)
            del live[slot]
        else:
            n_tok = int(rs.randint(1, 65))
            slot = cache.alloc(n_tok)
            if slot is None:
                assert not cache.can_admit(n_tok)
                continue
            assert slot not in live
            live[slot] = n_tok
            cache.seq_lens[slot] = rs.randint(1, n_tok + 1)
        _check_invariants(cache)
    for slot in list(live):
        cache.release(slot)
    _check_invariants(cache)
    assert cache.free_pages == cache.page_budget
    assert cache.n_free == cache.n_slots


def test_alloc_rejects_when_pages_short(moe):
    cfg, _ = moe
    cache = PagedKVCache(cfg, n_slots=4, max_len=64, page_size=8,
                         page_budget=6)
    a = cache.alloc(33)                   # 5 pages
    assert a is not None and cache.free_pages == 1
    assert cache.alloc(9) is None         # needs 2, only 1 free
    b = cache.alloc(8)                    # exactly 1 page
    assert b is not None and cache.free_pages == 0
    cache.release(a)
    assert cache.free_pages == 5 and cache.alloc(33) is not None


# ---------------------------------------------------------------------------
# engine-level stress: random waves, mid-flight admission, invariants
# ---------------------------------------------------------------------------


@pytest.mark.stress
def test_paged_engine_stress_matches_slot_and_reference(moe):
    cfg, params = moe
    rs = np.random.RandomState(42)
    specs = [(int(rs.randint(2, 20)), int(rs.randint(1, 9)))
             for _ in range(10)]
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in specs]
    # page budget far below slots*max_pages: admission must gate on pages
    paged = ServeEngine(params, cfg, max_len=32, max_batch=3,
                        prefill_chunk=8, page_size=8, page_budget=9)
    slot = ServeEngine(params, cfg, max_len=32, max_batch=3,
                       prefill_chunk=8, kv_layout="slot")

    # drive the paged engine by hand: submit in bursts, step, check
    # invariants after every decode step (mid-flight admission + free)
    rids = []
    pending = list(reqs)
    while pending or paged.busy:
        while pending and rs.rand() < 0.6:
            rids.append(paged.submit(pending.pop(0)))
        paged.step()
        _check_invariants(paged.cache)
    outs_paged = [paged.scheduler.result(rid) for rid in rids]
    assert paged.cache.free_pages == paged.cache.page_budget
    assert paged.cache.n_free == paged.cache.n_slots

    outs_slot = slot.generate(reqs)
    for (n, m), a, b in zip(specs, outs_paged, outs_slot):
        assert a.shape == (m,)
        np.testing.assert_array_equal(a, b)
    # spot-check two requests against the teacher-forced oracle
    for idx in (0, len(reqs) - 1):
        ref = _greedy_reference(params, cfg, reqs[idx].prompt,
                                specs[idx][1])
        np.testing.assert_array_equal(outs_paged[idx], ref)


@pytest.mark.stress
def test_spec_engine_stress_rollback_keeps_invariants(moe):
    """Speculative engine under the randomized stress harness: bursty
    submits, mid-flight admission/free, and per-round seq_len rollback
    must preserve every page-table invariant — and the outputs must stay
    token-identical to the plain paged engine."""
    cfg, params = moe
    rs = np.random.RandomState(21)
    specs = [(int(rs.randint(2, 18)), int(rs.randint(1, 9)))
             for _ in range(10)]
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in specs]
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    spec = ServeEngine(params, cfg, max_len=32, max_batch=3,
                       prefill_chunk=8, page_size=8, page_budget=12,
                       spec_decode="pruned", spec_k=3, expert_mask=mask)
    plain = ServeEngine(params, cfg, max_len=32, max_batch=3,
                        prefill_chunk=8, page_size=8)

    rids = []
    pending = list(reqs)
    while pending or spec.busy:
        while pending and rs.rand() < 0.6:
            rids.append(spec.submit(pending.pop(0)))
        spec.step()
        _check_invariants(spec.cache)
    outs_spec = [spec.scheduler.result(rid) for rid in rids]
    assert spec.cache.free_pages == spec.cache.page_budget
    assert spec.cache.n_free == spec.cache.n_slots

    outs_plain = plain.generate([Request(r.prompt, r.max_new_tokens)
                                 for r in reqs])
    for (n, m), a, b in zip(specs, outs_spec, outs_plain):
        assert a.shape == (m,)
        np.testing.assert_array_equal(a, b)
    st = spec.latency_stats()
    # each request's first token comes from prefill; spec rounds emit the
    # rest (acceptance-aware accounting must neither drop nor duplicate)
    assert st["spec_emitted"] == sum(m for _, m in specs) - len(specs)


@pytest.mark.stress
def test_spec_tree_sampled_stress_keeps_invariants(moe):
    """Tree drafts + mixed greedy/sampled temperatures + random EOS
    under the randomized stress harness.  Sampled streams are not
    token-comparable to plain decode, so the oracle here is the
    SpecStats delivered-accounting invariants (emitted == accepted +
    corrections, accepted <= drafted, drafted_nodes == N * drafted) plus
    the page-table invariants after every step — with EOS/max_new firing
    mid-tree-block and per-round rollback of the N*k overdraft rows.
    Greedy lanes must still match plain decode exactly."""
    cfg, params = moe
    rs = np.random.RandomState(33)
    N, k = 2, 3
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    reqs = []
    for _ in range(12):
        n, m = int(rs.randint(2, 16)), int(rs.randint(1, 9))
        temp = float(rs.choice([0.0, 0.7, 1.3]))
        eos = int(rs.randint(0, cfg.vocab)) if rs.rand() < 0.5 else None
        reqs.append(Request(rs.randint(0, cfg.vocab, n).astype(np.int32),
                            m, eos_id=eos, temperature=temp))
    spec = ServeEngine(params, cfg, max_len=32, max_batch=3,
                       prefill_chunk=8, page_size=8, page_budget=12,
                       spec_decode="pruned", spec_k=k, spec_tree=N,
                       expert_mask=mask)
    assert spec.cache.overdraft == N * k - 1

    rids = []
    pending = list(reqs)
    while pending or spec.busy:
        while pending and rs.rand() < 0.6:
            rids.append(spec.submit(pending.pop(0)))
        spec.step()
        _check_invariants(spec.cache)
    outs = [spec.scheduler.result(rid) for rid in rids]
    assert spec.cache.free_pages == spec.cache.page_budget
    assert spec.cache.n_free == spec.cache.n_slots

    plain = ServeEngine(params, cfg, max_len=32, max_batch=3,
                        prefill_chunk=8, page_size=8)
    refs = plain.generate([Request(r.prompt, r.max_new_tokens,
                                   eos_id=r.eos_id,
                                   temperature=r.temperature)
                           for r in reqs])
    for r, out, ref in zip(reqs, outs, refs):
        assert len(out) <= r.max_new_tokens
        if r.eos_id is not None and len(out) < r.max_new_tokens:
            assert out[-1] == r.eos_id
        if r.temperature == 0.0:
            # same seed, greedy: spec must reproduce plain exactly
            np.testing.assert_array_equal(out, ref)

    st = spec.latency_stats()
    assert st["spec_emitted"] == st["spec_accepted"] + st["spec_corrections"]
    assert st["spec_accepted"] <= st["spec_drafted"]
    assert st["spec_drafted_nodes"] == N * st["spec_drafted"]
    assert st["spec_emitted"] == sum(len(o) for o in outs) - len(reqs)


def test_paged_matches_slot_windowed(moe):
    """Sliding-window dense config through both cache layouts."""
    cfg = reduced(get_config("qwen2-7b"), n_layers=2)
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="full",
                              local_window=8)
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(2))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rs = np.random.RandomState(5)
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in [(13, 5), (3, 7), (21, 4)]]
    paged = ServeEngine(params, cfg, max_len=32, max_batch=2,
                        prefill_chunk=4, page_size=8)
    slot = ServeEngine(params, cfg, max_len=32, max_batch=2,
                       prefill_chunk=4, kv_layout="slot")
    outs_p = paged.generate([Request(r.prompt, r.max_new_tokens)
                             for r in reqs])
    outs_s = slot.generate([Request(r.prompt, r.max_new_tokens)
                            for r in reqs])
    for a, b in zip(outs_p, outs_s):
        np.testing.assert_array_equal(a, b)
    ref = _greedy_reference(params, cfg, reqs[0].prompt,
                            reqs[0].max_new_tokens)
    np.testing.assert_array_equal(outs_p[0], ref)


def test_paged_matches_slot_expert_mask_and_weight_masks(moe):
    """Pruned serving paths: runtime expert_mask and stage-2 weight masks
    must generate identically through paged and slot caches."""
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    rs = np.random.RandomState(3)
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), 6)
            for n in (5, 11)]
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    for kwargs in ({"expert_mask": mask},):
        outs = []
        for layout in ("paged", "slot"):
            eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                              prefill_chunk=8, kv_layout=layout, **kwargs)
            outs.append(eng.generate([Request(r.prompt, r.max_new_tokens)
                                      for r in reqs]))
        for a, b in zip(*outs):
            np.testing.assert_array_equal(a, b)

    batches = calibration_batches(cfg, n_batches=2)
    _, masks, _ = unstructured_only(params, cfg, batches,
                                    target_sparsity=0.4, method="wanda")
    outs = []
    for layout in ("paged", "slot"):
        eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                          prefill_chunk=8, kv_layout=layout,
                          weight_masks=masks)
        outs.append(eng.generate([Request(r.prompt, r.max_new_tokens)
                                  for r in reqs]))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_short_requests_pack_past_slot_capacity(moe):
    """The headline paged win: a budget sized to the live working set
    serves a wave that the same-memory slot layout could only serve
    serially.  8 short requests through 4 lanes with 8 pages — a slot
    cache with 8*page_size rows per 4 lanes would hold the same bytes."""
    cfg, params = moe
    rs = np.random.RandomState(9)
    reqs = [Request(rs.randint(0, cfg.vocab, 6).astype(np.int32), 3)
            for _ in range(8)]
    eng = ServeEngine(params, cfg, max_len=16, max_batch=4,
                      prefill_chunk=8, page_size=8, page_budget=8)
    outs = eng.generate(reqs)
    for r, got in zip(reqs, outs):
        solo = ServeEngine(params, cfg, max_len=16, max_batch=1,
                           prefill_chunk=8, kv_layout="slot")
        np.testing.assert_array_equal(
            got, solo.generate([Request(r.prompt, r.max_new_tokens)])[0])
    assert eng.requests_admitted == 8
    assert eng.pages_allocated == 8 * 2   # ceil((6+3)/8) = 2 pages each


# ---------------------------------------------------------------------------
# submit-time rejection + gauges
# ---------------------------------------------------------------------------


def test_submit_rejects_unservable_requests(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8, page_size=8, page_budget=3)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(np.zeros(30, np.int32), 8))
    with pytest.raises(ValueError, match="page"):
        # fits max_len but not the whole page budget (needs 4 pages of 3)
        eng.submit(Request(np.zeros(20, np.int32), 8))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(np.array([], np.int32), 4))
    assert not eng.scheduler.has_pending          # nothing leaked
    assert eng.cache.free_pages == eng.cache.page_budget
    # a bare Scheduler enforces the same token bound at submit()
    sched = Scheduler(max_request_tokens=16)
    with pytest.raises(ValueError, match="capacity"):
        sched.submit(Request(np.zeros(12, np.int32), 8))
    assert sched.submit(Request(np.zeros(8, np.int32), 8)) == 0


def test_gauges_track_pages_in_flight(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8, page_size=8)
    rs = np.random.RandomState(1)
    eng.submit(Request(rs.randint(0, cfg.vocab, 9).astype(np.int32), 8))
    eng.step()                                    # admit + first decode
    g = eng.latency_stats()
    assert g["pages_in_use"] == 3                 # ceil((9+8)/8)
    assert 0 < g["page_utilization"] <= 1
    assert 0 <= g["kv_fragmentation"] < 1
    eng.run()
    g = eng.latency_stats()
    assert g["pages_in_use"] == 0 and g["kv_fragmentation"] == 0
