"""Integration: train loop fault tolerance, serving, STUN pipeline on a
trained model, calibration stats, local dry-run path."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, reduced
from repro.core import stun_prune, unstructured_only
from repro.core.calibration import run_calibration
from repro.data.synthetic import batch_iterator, calibration_batches
from repro.models import abstract_params, forward, loss_fn
from repro.models import param as pm
from repro.optim import AdamWConfig
from repro.runtime import TrainLoopConfig, train_loop
from repro.serving import Request, ServeEngine

RNG = jax.random.PRNGKey(0)


def _mk(cfg):
    params = pm.init_params(abstract_params(cfg), RNG)
    return jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def trained_moe():
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = _mk(cfg)
    it = batch_iterator(cfg, 8, 64, seed=11)
    params, _, hist = train_loop(
        cfg, params, it,
        TrainLoopConfig(total_steps=120, log_every=1000, warmup_steps=10),
        AdamWConfig(lr=1e-3), log_fn=lambda *a: None)
    assert hist["history"][-1]["loss"] < hist["history"][0]["loss"]
    return cfg, params


def test_train_checkpoint_resume_and_elasticity():
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b"), n_layers=2,
                                      vocab=128), dtype="float32",
                              remat_policy="full")
    params = _mk(cfg)
    with tempfile.TemporaryDirectory() as d:
        it = batch_iterator(cfg, 4, 32, seed=7)
        lc = TrainLoopConfig(total_steps=8, checkpoint_every=4,
                             checkpoint_dir=d, log_every=1000)
        p1, _, h1 = train_loop(cfg, params, it, lc, log_fn=lambda *a: None)
        # resume: fresh params, should restore from step 8 and continue
        it2 = batch_iterator(cfg, 4, 32, seed=7, start_step=8)
        lc2 = TrainLoopConfig(total_steps=10, checkpoint_every=4,
                              checkpoint_dir=d, log_every=1000)
        p2, _, h2 = train_loop(cfg, params, it2, lc2, log_fn=lambda *a: None)
        assert h2["history"][0]["step"] == 8
        assert h2["history"][-1]["step"] == 9


def test_nan_batch_is_skipped():
    cfg = dataclasses.replace(reduced(get_config("musicgen-medium"),
                                      n_layers=1, vocab=64),
                              dtype="float32", remat_policy="full")
    params = _mk(cfg)
    from repro.runtime.step import make_train_step
    from repro.optim import adamw_init
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    opt = {"adam": adamw_init(params)}
    bad = {"embeds": jnp.full((2, 8, cfg.d_model), jnp.nan, jnp.float32),
           "labels": jnp.zeros((2, 8), jnp.int32)}
    new_params, _, m = step(params, opt, bad)
    assert int(m["skipped_nonfinite"]) == 1
    # params unchanged
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params,
                        new_params)
    assert all(jax.tree.leaves(same))


def test_stun_on_trained_model_beats_unstructured(trained_moe):
    """The paper's RQ1 on an actually-trained model (integration)."""
    cfg, params = trained_moe
    batches = calibration_batches(cfg, n_batches=3)
    p1, c1, _, _ = stun_prune(params, cfg, batches, target_sparsity=0.5,
                              expert_ratio=0.25, unstructured="owl")
    p2, _, _ = unstructured_only(params, cfg, batches, target_sparsity=0.5,
                                 method="owl")
    eval_b = calibration_batches(cfg, n_batches=2, seed=999)
    l1 = np.mean([float(loss_fn(p1, c1, b)) for b in eval_b])
    l2 = np.mean([float(loss_fn(p2, cfg, b)) for b in eval_b])
    assert l1 < l2, (l1, l2)


def test_serving_engine_batched(trained_moe):
    cfg, params = trained_moe
    eng = ServeEngine(params, cfg, max_len=48)
    rs = np.random.RandomState(0)
    reqs = [Request(rs.randint(0, cfg.vocab, 6).astype(np.int32), 5)
            for _ in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    for o in outs:
        assert o.shape == (5,)
        assert (o >= 0).all() and (o < cfg.vocab).all()


def test_calibration_stats_complete(trained_moe):
    cfg, params = trained_moe
    batches = calibration_batches(cfg, n_batches=1)
    stats = run_calibration(params, cfg, batches, collect_inputs=True)
    norms = stats.norms()
    for l in range(cfg.n_layers):
        assert (l, "attn_in") in norms
        assert norms[(l, "attn_in")].shape == (cfg.d_model,)
        assert (l, "moe_expert_in") in norms
        assert norms[(l, "moe_expert_in")].shape == (cfg.n_experts,
                                                     cfg.d_model)
        assert l in stats.coact
    assert (norms[(0, "attn_in")] >= 0).all()


def test_gradient_compression_trains():
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b"), n_layers=1,
                                      vocab=64), dtype="float32",
                              remat_policy="full")
    params = _mk(cfg)
    it = batch_iterator(cfg, 4, 32, seed=3)
    lc = TrainLoopConfig(total_steps=20, log_every=1000,
                         compress_grads=True, warmup_steps=2)
    p, _, hist = train_loop(cfg, params, it, lc, AdamWConfig(lr=1e-3),
                            log_fn=lambda *a: None)
    assert hist["history"][-1]["loss"] < hist["history"][0]["loss"]


def test_local_dryrun_machinery():
    """Exercise input_specs + lower_cell on the 1-device local mesh with a
    reduced config — same code path the 512-device dry-run uses."""
    import repro.launch.dryrun as dr
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b"), n_layers=2, vocab=128),
        scan_layers=True)
    # shrink the cell: patch a tiny shape into the table for this test
    orig = dr.SHAPES
    try:
        from repro.configs.base import ShapeSpec
        dr.SHAPES = dict(orig)
        dr.SHAPES["tiny_train"] = ShapeSpec("tiny_train", 64, 4, "train")
        dr.SHAPES["tiny_decode"] = ShapeSpec("tiny_decode", 64, 4, "decode")
        for shape in ("tiny_train", "tiny_decode"):
            lowered = dr.lower_cell(cfg, shape, mesh)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            assert cost.get("flops", 0) > 0
    finally:
        dr.SHAPES = orig


def test_structured_nonmoe_stage():
    from repro.core import structured_prune_ffn
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b"), n_layers=2,
                                      vocab=128), dtype="float32",
                              remat_policy="full")
    params = _mk(cfg)
    batches = calibration_batches(cfg, n_batches=1)
    stats = run_calibration(params, cfg, batches)
    p, c, kept = structured_prune_ffn(params, cfg, stats.norms(), ratio=0.1)
    assert c.d_ff < cfg.d_ff
    assert c.d_ff % 8 == 0
    loss = loss_fn(p, c, batches[0])
    assert jnp.isfinite(loss)
