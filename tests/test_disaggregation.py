"""Prefill/decode disaggregation: equivalence oracle + starvation tests.

The interleaved schedule (``ServeEngine(schedule="interleaved")``) meters
chunked prefill at ``prefill_budget`` prompt tokens per engine step so
decode lanes never stall behind a long prompt.  Two properties pin it:

  * **Equivalence oracle** — over randomized mixed workloads (both KV
    layouts, runtime ``expert_mask`` / stage-2 weight masks, speculative
    decode on/off, EOS firing mid-stream, bursty submits), the
    interleaved schedule's per-request greedy outputs are token-identical
    to the blocking engine's.  Only latency may differ, never content.
  * **Starvation/fairness** — under randomized submit/step/finish, no
    decode-active lane waits more than ``ceil(prefill_budget/chunk)+1``
    engine steps between decode dispatches, no request is lost or
    duplicated, and the paged cache's page-table invariants (from
    ``test_paged_serving``) hold after every step.  The randomized driver
    runs with fixed seeds always and widens under hypothesis when the
    optional dependency is installed (mirroring ``test_property.py``).

Plus unit coverage for the satellites: inter-token (TPOT) latency
percentiles in ``Scheduler.latencies()`` and the ``SchedulerError``
raised (not ``assert``-ed, so it survives ``python -O``) when a token is
delivered to a finished request.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import Request, Scheduler, SchedulerError, ServeEngine
from test_paged_serving import _check_invariants

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dev dep (see requirements.txt)
    HAVE_HYPOTHESIS = False


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _random_workload(cfg, rs, n=8, max_prompt=20, max_new=8):
    return [Request(rs.randint(0, cfg.vocab,
                               int(rs.randint(2, max_prompt))
                               ).astype(np.int32),
                    int(rs.randint(1, max_new + 1)))
            for _ in range(n)]


def _clone(reqs):
    return [Request(r.prompt, r.max_new_tokens, eos_id=r.eos_id,
                    temperature=r.temperature) for r in reqs]


def _drive_bursty(eng, reqs, rs):
    """Submit in random bursts while stepping — the interleaved schedule
    must interleave mid-flight admissions' prefills with live decodes.
    Returns outputs in request order."""
    pending = list(reqs)
    rids = []
    while pending or eng.busy:
        while pending and rs.rand() < 0.6:
            rids.append(eng.submit(pending.pop(0)))
        eng.step()
    return [eng.scheduler.result(rid) for rid in rids]


def _engine(params, cfg, layout="paged", spec=False, **kw):
    kwargs = dict(max_len=32, max_batch=3, prefill_chunk=8,
                  kv_layout=layout)
    if layout == "paged":
        kwargs.update(page_size=8, page_budget=12)
    if spec:
        mask = np.ones(cfg.n_experts, np.float32)
        mask[-cfg.n_experts // 4:] = 0.0
        kwargs.update(spec_decode="pruned", spec_k=3, expert_mask=mask)
    kwargs.update(kw)
    return ServeEngine(params, cfg, **kwargs)


# ---------------------------------------------------------------------------
# equivalence oracle: interleaved == blocking, token for token
# ---------------------------------------------------------------------------


@pytest.mark.stress
@pytest.mark.parametrize("layout,spec", [("paged", False), ("slot", False),
                                         ("paged", True)])
def test_interleaved_token_identical_to_blocking(moe, layout, spec):
    """Randomized mixed workload with EOS mid-stream: the interleaved
    schedule (driven with bursty submits, so prefills genuinely overlap
    decodes) must reproduce the blocking engine's outputs exactly —
    on both KV layouts, and with speculative decode on the paged one."""
    cfg, params = moe
    seed = {("paged", False): 100, ("slot", False): 200,
            ("paged", True): 300}[(layout, spec)]
    rs = np.random.RandomState(seed)
    reqs = _random_workload(cfg, rs, n=8)

    # harvest free-running outputs, then plant a mid-stream EOS in every
    # third request so termination fires inside the token stream
    harvest = _engine(params, cfg, layout, spec,
                      schedule="blocking").generate(_clone(reqs))
    for i in range(0, len(reqs), 3):
        out = harvest[i]
        if len(out) >= 3:
            reqs[i].eos_id = int(out[len(out) // 2])

    blocking = _engine(params, cfg, layout, spec, schedule="blocking")
    outs_blk = blocking.generate(_clone(reqs))
    interleaved = _engine(params, cfg, layout, spec, schedule="interleaved")
    outs_itl = _drive_bursty(interleaved, _clone(reqs), rs)

    for r, a, b in zip(reqs, outs_blk, outs_itl):
        np.testing.assert_array_equal(a, b)
        assert len(a) <= r.max_new_tokens
    # everything drained: no lane, page, or request state left behind
    assert not interleaved.busy
    assert interleaved.cache.n_free == interleaved.cache.n_slots


@pytest.fixture(scope="module")
def packed_sparse(moe):
    """Stage-2 masks planned + packed into the block-compressed artifact,
    plus the dense-mask baseline that realizes the identical model."""
    from repro import sparse
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    batches = calibration_batches(cfg, n_batches=2)
    _, masks, _ = unstructured_only(params, cfg, batches,
                                    target_sparsity=0.3, method="owl")
    plan = sparse.plan_sparse_ffn(
        masks, sparse.ffn_weights_from_params(params, cfg), block=(8, 8),
        target_block_sparsity=0.2)
    packed, _ = sparse.pack_sparse_ffn(params, cfg, plan)
    base_masks = dict(masks)
    base_masks.update(plan.element_masks())
    return packed, base_masks


@pytest.mark.stress
@pytest.mark.parametrize("layout,spec", [("paged", False), ("slot", False),
                                         ("paged", True)])
def test_packed_sparse_token_identical_to_dense_masked(moe, packed_sparse,
                                                       layout, spec):
    """The serving oracle's sparse_weights axis: the packed-artifact
    engine (block-compressed expert FFNs, block-sparse execute path)
    must reproduce the dense-masked engine token for token — across both
    KV layouts, with speculative decode on the paged one (where the
    packed artifact is the DRAFTER), and through both schedules."""
    from repro import sparse  # noqa: F401 — exercised via the engine

    cfg, params = moe
    packed, base_masks = packed_sparse
    seed = {("paged", False): 400, ("slot", False): 500,
            ("paged", True): 600}[(layout, spec)]
    rs = np.random.RandomState(seed)
    reqs = _random_workload(cfg, rs, n=6)

    dense = _engine(params, cfg, layout, spec, schedule="blocking",
                    weight_masks=base_masks)
    outs_dense = dense.generate(_clone(reqs))
    packed_blk = _engine(params, cfg, layout, spec, schedule="blocking",
                         weight_masks=base_masks, sparse_weights=packed)
    outs_packed = packed_blk.generate(_clone(reqs))
    for a, b in zip(outs_dense, outs_packed):
        np.testing.assert_array_equal(a, b)
    # and through the interleaved schedule with bursty submits
    packed_itl = _engine(params, cfg, layout, spec, schedule="interleaved",
                         weight_masks=base_masks, sparse_weights=packed)
    outs_itl = _drive_bursty(packed_itl, _clone(reqs), rs)
    for a, b in zip(outs_dense, outs_itl):
        np.testing.assert_array_equal(a, b)
    assert not packed_itl.busy


@pytest.mark.stress
def test_interleaved_equivalence_with_pruned_serving(moe):
    """Runtime expert_mask and stage-2 weight masks through the
    interleaved schedule must match the blocking engine on both
    layouts."""
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    rs = np.random.RandomState(7)
    reqs = _random_workload(cfg, rs, n=5)
    emask = np.ones(cfg.n_experts, np.float32)
    emask[-cfg.n_experts // 4:] = 0.0
    batches = calibration_batches(cfg, n_batches=2)
    _, wmasks, _ = unstructured_only(params, cfg, batches,
                                     target_sparsity=0.4, method="wanda")
    for kwargs in ({"expert_mask": emask}, {"weight_masks": wmasks}):
        for layout in ("paged", "slot"):
            blk = _engine(params, cfg, layout, schedule="blocking",
                          **kwargs).generate(_clone(reqs))
            itl = _drive_bursty(
                _engine(params, cfg, layout, schedule="interleaved",
                        **kwargs), _clone(reqs), rs)
            for a, b in zip(blk, itl):
                np.testing.assert_array_equal(a, b)


def test_interleaved_spreads_prefill_across_steps(moe):
    """The mechanics of the token budget: a 4-chunk prompt admitted at
    step 0 must take 4 steps of budget=1-chunk prefill (cursor visible in
    RequestState.prefill_pos), with a decode dispatch for the already-
    active lane on EVERY one of those steps."""
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=48, max_batch=2, prefill_chunk=8,
                      schedule="interleaved")
    rs = np.random.RandomState(0)
    eng.submit(Request(rs.randint(0, cfg.vocab, 5).astype(np.int32), 12))
    eng.step()                               # short request becomes active
    assert len(eng.scheduler.active) == 1
    rid_long = eng.submit(
        Request(rs.randint(0, cfg.vocab, 29).astype(np.int32), 4))
    seen_cursors = []
    for _ in range(4):                       # ceil(29/8) = 4 chunk steps
        d0 = eng.decode_dispatches
        eng.step()
        assert eng.decode_dispatches == d0 + 1, \
            "active lane must decode on every step of the long prefill"
        st = (eng.scheduler.prefilling.get(rid_long)
              or eng.scheduler.active.get(rid_long))
        seen_cursors.append(st.prefill_pos)
    assert seen_cursors == [8, 16, 24, 32]   # resumable, chunk-aligned
    assert rid_long in eng.scheduler.active  # prefill completed on step 4
    g = eng.latency_stats()
    assert g["lanes_prefilling"] == 0
    eng.run()


def test_blocking_schedule_prefills_to_completion(moe):
    """The reference schedule is preserved: one step fully prefills the
    admitted prompt (all chunks) before any decode dispatch."""
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=48, max_batch=2, prefill_chunk=8,
                      schedule="blocking")
    rs = np.random.RandomState(0)
    eng.submit(Request(rs.randint(0, cfg.vocab, 29).astype(np.int32), 2))
    eng.step()
    assert eng.prefill_dispatches == 4       # ceil(29/8) in ONE step
    assert not eng.scheduler.has_prefilling
    eng.run()


# ---------------------------------------------------------------------------
# starvation / fairness stress (hypothesis-gated widening)
# ---------------------------------------------------------------------------


def _starvation_drive(params, cfg, seed, layout="paged", spec=False,
                      prefill_budget=None, schedule="interleaved"):
    """Randomized submit/step/finish; asserts the fairness bound, page
    invariants, and exactly-once request accounting.

    The fairness bound is measured in the unit that actually stalls a
    token stream: **prefill dispatches interposed between the decode
    dispatches an active lane is owed**.  Per engine step with a
    decode-active lane, at most ``prefill_budget // chunk`` prefill
    chunks may run, and the decode round must fire — together these give
    the ``ceil(prefill_budget/chunk)+1``-step bound.  The blocking
    schedule VIOLATES this whenever a long prompt is admitted while
    lanes are decoding (its whole ``ceil(S/chunk)``-dispatch prefill is
    interposed) — pinned by ``test_blocking_schedule_fails_the_bound``,
    so this bound is known to discriminate, not vacuously pass."""
    rs = np.random.RandomState(seed)
    reqs = _random_workload(cfg, rs, n=7, max_prompt=24)
    eng = _engine(params, cfg, layout, spec, schedule=schedule,
                  prefill_budget=prefill_budget)
    budget_chunks = max(1, eng.prefill_budget // eng.prefill_chunk)
    pending = list(reqs)
    rids = []
    n_steps = 0
    while pending or eng.busy:
        while pending and rs.rand() < 0.5:
            rids.append(eng.submit(pending.pop(0)))
        had_active = eng.scheduler.has_active
        p0, d0 = eng.prefill_dispatches, eng.decode_dispatches
        eng.step()
        n_steps += 1
        assert n_steps < 10_000, "engine failed to drain"
        if layout == "paged":
            _check_invariants(eng.cache)
        if had_active:
            # lanes owed a token this step: the prefill work interposed
            # before their decode dispatch is capped by the budget...
            interposed = eng.prefill_dispatches - p0
            assert interposed <= budget_chunks, \
                f"{interposed} prefill dispatches starved active lanes " \
                f"(budget {budget_chunks} chunks)"
            # ...and the decode round itself must have fired
            assert eng.decode_dispatches > d0, \
                "step with active lanes issued no decode dispatch"
    # exactly-once accounting: every submitted rid finished exactly once,
    # with a plausible token count; nothing lingers in any stage
    assert len(rids) == len(reqs) and len(set(rids)) == len(rids)
    for req, rid in zip(reqs, rids):
        out = eng.scheduler.result(rid)      # KeyError here == lost
        assert 1 <= len(out) <= req.max_new_tokens
    assert not eng.scheduler.finished and not eng.busy
    assert eng.cache.n_free == eng.cache.n_slots
    if layout == "paged":
        assert eng.cache.free_pages == eng.cache.page_budget


@pytest.mark.stress
@pytest.mark.parametrize("layout,spec", [("paged", False), ("slot", False),
                                         ("paged", True)])
@pytest.mark.parametrize("seed", [0, 1])
def test_starvation_fairness_seeded(moe, layout, spec, seed):
    cfg, params = moe
    _starvation_drive(params, cfg, seed, layout, spec)


@pytest.mark.stress
def test_starvation_fairness_wide_budget(moe):
    """A multi-chunk budget (prefill_budget=3*chunk) still respects the
    ceil(budget/chunk)+1 bound."""
    cfg, params = moe
    _starvation_drive(params, cfg, 3, "paged", False, prefill_budget=24)


@pytest.mark.stress
def test_blocking_schedule_fails_the_bound(moe):
    """Regression-power check: the SAME driver against the blocking
    schedule must trip the fairness assertion (a multi-chunk prompt
    admitted while lanes decode interposes its whole prefill), proving
    the bound discriminates between the schedules rather than passing
    vacuously."""
    cfg, params = moe
    with pytest.raises(AssertionError, match="starved"):
        _starvation_drive(params, cfg, 0, "paged", False,
                          schedule="blocking")


if HAVE_HYPOTHESIS:
    @pytest.mark.stress
    @settings(max_examples=5, deadline=None)
    @given(hst.integers(0, 10 ** 6))
    def test_starvation_fairness_hypothesis(seed):
        cfg, params = _tiny_moe()
        _starvation_drive(params, cfg, seed, "paged", False)


# ---------------------------------------------------------------------------
# inter-token (TPOT) latency accounting
# ---------------------------------------------------------------------------


def test_inter_token_latency_percentiles():
    """Gaps between consecutive on_token calls of one request land in
    p50/p95_inter_token_s; the first token of each request never does
    (that gap is TTFT, reported separately)."""
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1, 2], np.int32),
                               max_new_tokens=4), now=0.0)
    sched.admit(slot=0)
    sched.activate(rid)
    for t in (1.0, 1.5, 3.5, 3.6):           # gaps: 0.5, 2.0, 0.1
        sched.on_token(rid, 7, now=t)
    lat = sched.latencies()
    gaps = np.array([0.5, 2.0, 0.1])
    assert lat["p50_inter_token_s"] == pytest.approx(np.percentile(gaps, 50))
    assert lat["p95_inter_token_s"] == pytest.approx(np.percentile(gaps, 95))
    assert lat["p50_first_token_s"] == pytest.approx(1.0)
    assert lat["p95_latency_s"] == pytest.approx(3.6)
    sched.reset_latencies()
    assert sched.latencies() == {}


def test_engine_reports_inter_token_latency(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2, prefill_chunk=8)
    eng.generate([Request(np.array([1, 2, 3], np.int32), 5)])
    st = eng.latency_stats()
    assert 0 <= st["p50_inter_token_s"] <= st["p95_inter_token_s"]


def test_single_token_requests_have_no_inter_token_samples():
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1], np.int32), 1), now=0.0)
    sched.admit(slot=0)
    sched.activate(rid)
    assert sched.on_token(rid, 3, now=1.0)
    lat = sched.latencies()
    assert "p50_inter_token_s" not in lat     # no second token, no gap
    assert "p50_latency_s" in lat


# ---------------------------------------------------------------------------
# token-after-finish raises a real exception (not a -O-stripped assert)
# ---------------------------------------------------------------------------


def test_on_token_after_finish_raises():
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1], np.int32), 1))
    sched.admit(slot=0)
    sched.activate(rid)
    assert sched.on_token(rid, 5) is True     # max_new_tokens reached
    with pytest.raises(SchedulerError, match="finished"):
        sched.on_token(rid, 6)
    with pytest.raises(SchedulerError, match="unknown"):
        sched.on_token(rid + 1, 6)
    # on_tokens (speculative block path) funnels through the same check
    with pytest.raises(SchedulerError, match="finished"):
        sched.on_tokens(rid, [6, 7])
    assert sched.result(rid).tolist() == [5]  # stream unaffected


def test_on_token_mid_prefill_raises():
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1, 2], np.int32), 2))
    sched.admit(slot=0)                       # prefilling, NOT active yet
    with pytest.raises(SchedulerError, match="mid-prefill"):
        sched.on_token(rid, 5)
    sched.activate(rid)
    assert sched.on_token(rid, 5) is False


def test_activate_requires_prefilling_state():
    sched = Scheduler()
    with pytest.raises(SchedulerError, match="not mid-prefill"):
        sched.activate(0)


def test_engine_rejects_bad_schedule_args(moe):
    cfg, params = moe
    with pytest.raises(ValueError, match="schedule"):
        ServeEngine(params, cfg, max_len=16, schedule="async")
    with pytest.raises(ValueError, match="prefill_budget"):
        ServeEngine(params, cfg, max_len=16, prefill_budget=0)
