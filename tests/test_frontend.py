"""Asyncio streaming frontend over the serve engine.

Everything runs through plain ``asyncio.run`` (no pytest-asyncio
dependency).  Coverage: per-request streams match the batch API
token-for-token, arrivals submitted while the loop is stepping
interleave correctly, backpressure holds submitters until admission
headroom exists, client disconnect (breaking out of the stream)
cancels engine-side with zero page leaks, and ``aclose`` tears down
in-flight requests.
"""
import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import AsyncFrontend, Request, ServeEngine


def _tiny_moe(seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _prompts(cfg, n, seed=0):
    rs = np.random.RandomState(seed)
    return [rs.randint(0, cfg.vocab, rs.randint(3, 10)).astype(np.int32)
            for _ in range(n)]


def _engine(params, cfg, **kw):
    kw.setdefault("max_len", 64)
    kw.setdefault("max_batch", 4)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(params, cfg, **kw)


def test_streams_match_batch_api(moe):
    """Streamed tokens == the synchronous batch API's outputs, per
    request, including a sampled lane (per-request PRNG key chains make
    sampled streams schedule- and batch-invariant)."""
    cfg, params = moe
    prompts = _prompts(cfg, 5)
    temps = [0.0, 0.0, 0.8, 0.0, 0.8]
    refs = _engine(params, cfg).generate(
        [Request(p.copy(), 8, temperature=t)
         for p, t in zip(prompts, temps)])

    async def main():
        async with AsyncFrontend(_engine(params, cfg)) as fe:
            streams = [await fe.submit(Request(p.copy(), 8, temperature=t))
                       for p, t in zip(prompts, temps)]
            return await asyncio.gather(*(s.drain() for s in streams))

    outs = asyncio.run(main())
    for out, ref in zip(outs, refs):
        assert out == ref.tolist()


def test_late_arrival_interleaves_mid_flight(moe):
    """A request submitted while earlier streams are mid-decode is
    admitted by the running step loop and completes — the open-loop
    property the frontend exists for."""
    cfg, params = moe
    prompts = _prompts(cfg, 3, seed=1)

    async def main():
        async with AsyncFrontend(_engine(params, cfg)) as fe:
            first = await fe.submit(Request(prompts[0].copy(), 12))
            got = []
            late = None
            async for tok in first:
                got.append(tok)
                if len(got) == 2:            # engine mid-flight: arrive now
                    late = await fe.submit(Request(prompts[1].copy(), 4))
            return got, await late.drain()

    got, late_out = asyncio.run(main())
    assert len(got) == 12 and len(late_out) == 4


def test_backpressure_holds_submitter_until_headroom(moe):
    """With one lane, the second ``submit(wait=True)`` parks until the
    first request finishes, then admits and completes."""
    cfg, params = moe

    async def main():
        eng = _engine(params, cfg, max_batch=1, max_len=32)
        async with AsyncFrontend(eng) as fe:
            s1 = await fe.submit(Request(_prompts(cfg, 1)[0], 6))
            waiter = asyncio.ensure_future(
                fe.submit(Request(_prompts(cfg, 1, seed=2)[0], 4)))
            await asyncio.sleep(0)
            held = not waiter.done()         # no headroom: still parked
            out1 = await s1.drain()
            s2 = await waiter
            return held, out1, await s2.drain()

    held, out1, out2 = asyncio.run(main())
    assert held and len(out1) == 6 and len(out2) == 4


def test_disconnect_cancels_engine_side(moe):
    """Breaking out of a stream (client disconnect) cancels the request:
    the lane frees immediately, pages are restored, and batchmates
    stream on unperturbed."""
    cfg, params = moe
    prompts = _prompts(cfg, 2, seed=3)
    ref = _engine(params, cfg).generate([Request(prompts[1].copy(), 10)])[0]

    async def main():
        eng = _engine(params, cfg, max_batch=2)
        async with AsyncFrontend(eng) as fe:
            s1 = await fe.submit(Request(prompts[0].copy(), 16))
            s2 = await fe.submit(Request(prompts[1].copy(), 10))
            got = []
            async for tok in s1:
                got.append(tok)
                if len(got) == 3:
                    break                    # disconnect
            out2 = await s2.drain()
            return eng, got, out2

    eng, got, out2 = asyncio.run(main())
    assert len(got) == 3
    assert out2 == ref.tolist()              # survivor unchanged
    assert eng.requests_canceled == 1
    cache = eng.cache
    assert len(cache._free_pages) + len(cache._refs) == cache.page_budget


def test_explicit_cancel_is_idempotent_and_finished_safe(moe):
    cfg, params = moe

    async def main():
        eng = _engine(params, cfg)
        async with AsyncFrontend(eng) as fe:
            s = await fe.submit(Request(_prompts(cfg, 1, seed=4)[0], 4))
            out = await s.drain()
            finished_cancel = s.cancel()     # after completion: no-op
            s2 = await fe.submit(Request(_prompts(cfg, 1, seed=5)[0], 16))
            first = s2.cancel()              # live: removes engine state
            second = s2.cancel()             # idempotent: no-op
            return out, finished_cancel, first, second, eng

    out, finished_cancel, first, second, eng = asyncio.run(main())
    assert len(out) == 4
    assert finished_cancel is False
    assert first is True and second is False
    assert eng.requests_canceled == 1


def test_validation_surfaces_at_submit(moe):
    cfg, params = moe

    async def main():
        eng = _engine(params, cfg, max_len=16)
        async with AsyncFrontend(eng) as fe:
            with pytest.raises(ValueError, match="max_len"):
                await fe.submit(Request(np.arange(1, 12, dtype=np.int32),
                                        16))
            with pytest.raises(ValueError, match="empty"):
                await fe.submit(Request(np.array([], np.int32), 4))
            return fe.in_flight

    assert asyncio.run(main()) == 0          # nothing was queued


def test_aclose_cancels_in_flight(moe):
    cfg, params = moe

    async def main():
        eng = _engine(params, cfg)
        fe = AsyncFrontend(eng)
        fe.start()
        s = await fe.submit(Request(_prompts(cfg, 1, seed=6)[0], 16))
        await asyncio.sleep(0)
        await fe.aclose()
        return eng, s

    eng, s = asyncio.run(main())
    assert s.canceled and not eng.busy
    cache = eng.cache
    assert len(cache._free_pages) + len(cache._refs) == cache.page_budget
