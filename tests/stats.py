"""Shared statistical test helpers: exact binomial + χ² with explicit α.

The speculative-sampling equivalence oracle can only pin correctness
*statistically* — rejection sampling is exactly distribution-preserving,
so spec-sampled token frequencies must be indistinguishable from plain
temperature sampling, and a deliberately-biased accept rule must be
distinguishable.  Tests that hand-roll tolerances drift and hide their
false-positive rate; these helpers make every statistical claim carry an
explicit significance level ``alpha`` and sample size ``n``.

Stdlib-only math (``math.lgamma`` + incomplete-gamma series/continued
fraction) — CI does not ship scipy.

Every test that uses this module must be marked ``@pytest.mark.stats``;
conftest fails collection otherwise (see ``pytest_collection_modifyitems``).
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "chi2_sf",
    "chi2_gof",
    "chi2_homogeneity",
    "binom_pvalue_two_sided",
    "binom_sf",
    "assert_same_distribution",
    "assert_matches_probs",
    "assert_binom_fraction",
]


# ---------------------------------------------------------------------------
# special functions (Numerical-Recipes-style incomplete gamma)
# ---------------------------------------------------------------------------


def _gammainc_q(s: float, x: float) -> float:
    """Regularized upper incomplete gamma Q(s, x) = Γ(s, x)/Γ(s)."""
    if s <= 0.0 or x < 0.0:
        raise ValueError(f"gammainc_q domain: s={s}, x={x}")
    if x == 0.0:
        return 1.0
    if x < s + 1.0:
        # lower series for P(s, x); Q = 1 - P
        term = 1.0 / s
        total = term
        denom = s
        for _ in range(10_000):
            denom += 1.0
            term *= x / denom
            total += term
            if abs(term) < abs(total) * 1e-16:
                break
        p = total * math.exp(-x + s * math.log(x) - math.lgamma(s))
        return min(1.0, max(0.0, 1.0 - p))
    # modified Lentz continued fraction for Q(s, x)
    tiny = 1e-300
    b = x + 1.0 - s
    c = 1.0 / tiny
    d = 1.0 / b
    h = d
    for i in range(1, 10_000):
        an = -i * (i - s)
        b += 2.0
        d = an * d + b
        if abs(d) < tiny:
            d = tiny
        c = b + an / c
        if abs(c) < tiny:
            c = tiny
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < 1e-16:
            break
    return min(1.0, max(0.0, h * math.exp(-x + s * math.log(x)
                                          - math.lgamma(s))))


def chi2_sf(x: float, df: float) -> float:
    """χ² survival function P[X >= x] for ``df`` degrees of freedom."""
    if x <= 0.0:
        return 1.0
    return _gammainc_q(df / 2.0, x / 2.0)


# ---------------------------------------------------------------------------
# exact binomial tests
# ---------------------------------------------------------------------------


def _binom_logpmf(n: int) -> np.ndarray:
    i = np.arange(n + 1, dtype=np.float64)
    lgamma = np.vectorize(math.lgamma)
    return lgamma(n + 1.0) - lgamma(i + 1.0) - lgamma(n - i + 1.0)


def binom_pvalue_two_sided(k: int, n: int, p: float) -> float:
    """Exact two-sided binomial test of H0: success prob == ``p``.

    Sums P(X = i) over every outcome no more likely than the observed
    ``k`` (the scipy ``binomtest`` convention, relative tolerance 1e-7).
    """
    if not 0 <= k <= n:
        raise ValueError(f"k={k} outside [0, {n}]")
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"p={p} outside [0, 1]")
    if p == 0.0:
        return 1.0 if k == 0 else 0.0
    if p == 1.0:
        return 1.0 if k == n else 0.0
    i = np.arange(n + 1, dtype=np.float64)
    logpmf = (_binom_logpmf(n) + i * math.log(p)
              + (n - i) * math.log1p(-p))
    pmf = np.exp(logpmf)
    return float(min(1.0, pmf[pmf <= pmf[k] * (1.0 + 1e-7)].sum()))


def binom_sf(k: int, n: int, p: float) -> float:
    """One-sided exact binomial P[X >= k] under success prob ``p``."""
    if not 0 <= k <= n:
        raise ValueError(f"k={k} outside [0, {n}]")
    if p <= 0.0:
        return 1.0 if k == 0 else 0.0
    if p >= 1.0:
        return 1.0
    i = np.arange(n + 1, dtype=np.float64)
    logpmf = (_binom_logpmf(n) + i * math.log(p)
              + (n - i) * math.log1p(-p))
    return float(min(1.0, np.exp(logpmf[k:]).sum()))


# ---------------------------------------------------------------------------
# χ² goodness-of-fit / homogeneity with small-bin merging
# ---------------------------------------------------------------------------


def _merge_bins(rows: np.ndarray, expected: np.ndarray,
                min_expected: float) -> Tuple[np.ndarray, np.ndarray]:
    """Merge low-expectation bins into one pooled bin.

    ``rows`` [R, V] observed counts, ``expected`` [R, V] — bins whose
    expected count falls below ``min_expected`` in ANY row are pooled
    (standard Cochran guard: χ²'s asymptotics need E >= ~5 per cell).
    Returns merged ``(rows [R, V'], expected [R, V'])``.
    """
    ok = (expected >= min_expected).all(axis=0)
    keep_r = rows[:, ok]
    keep_e = expected[:, ok]
    if (~ok).any():
        pool_r = rows[:, ~ok].sum(axis=1, keepdims=True)
        pool_e = expected[:, ~ok].sum(axis=1, keepdims=True)
        if (pool_e < min_expected).any() and keep_r.shape[1] > 0:
            # pooled leftover still too small: fold it into the smallest
            # kept bin instead of giving it its own cell
            j = int(keep_e[0].argmin())
            keep_r = keep_r.copy()
            keep_e = keep_e.copy()
            keep_r[:, j] += pool_r[:, 0]
            keep_e[:, j] += pool_e[:, 0]
        else:
            keep_r = np.concatenate([keep_r, pool_r], axis=1)
            keep_e = np.concatenate([keep_e, pool_e], axis=1)
    return keep_r, keep_e


def chi2_gof(counts: Sequence[int], probs: Sequence[float],
             min_expected: float = 5.0) -> Tuple[float, int, float]:
    """χ² goodness-of-fit of observed ``counts`` against ``probs``.

    Returns ``(stat, df, pvalue)`` after merging bins with expected
    count < ``min_expected``.  ``df = bins - 1``.
    """
    obs = np.asarray(counts, np.float64)[None]
    probs = np.asarray(probs, np.float64)
    if probs.min() < 0 or not math.isclose(probs.sum(), 1.0, rel_tol=1e-6):
        raise ValueError("probs must be a distribution")
    exp = (obs.sum() * probs)[None]
    obs, exp = _merge_bins(obs, exp, min_expected)
    if obs.shape[1] < 2:
        return 0.0, 0, 1.0
    stat = float(((obs - exp) ** 2 / exp).sum())
    df = obs.shape[1] - 1
    return stat, df, chi2_sf(stat, df)


def chi2_homogeneity(counts_a: Sequence[int], counts_b: Sequence[int],
                     min_expected: float = 5.0) -> Tuple[float, int, float]:
    """Two-sample χ² homogeneity test over shared bins.

    ``counts_a`` / ``counts_b`` are observed frequencies over the same
    support (e.g. next-token histograms from two engines).  Expected
    cell counts come from the pooled distribution; bins expected below
    ``min_expected`` in either sample are merged.  Returns
    ``(stat, df, pvalue)`` with ``df = bins - 1`` (a 2 x V table).
    """
    a = np.asarray(counts_a, np.float64)
    b = np.asarray(counts_b, np.float64)
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    rows = np.stack([a, b])
    n_a, n_b = a.sum(), b.sum()
    if n_a == 0 or n_b == 0:
        raise ValueError("empty sample")
    pooled = (a + b) / (n_a + n_b)
    exp = np.stack([pooled * n_a, pooled * n_b])
    live = pooled > 0
    rows, exp = rows[:, live], exp[:, live]
    rows, exp = _merge_bins(rows, exp, min_expected)
    if rows.shape[1] < 2:
        return 0.0, 0, 1.0
    stat = float(((rows - exp) ** 2 / exp).sum())
    df = rows.shape[1] - 1
    return stat, df, chi2_sf(stat, df)


# ---------------------------------------------------------------------------
# assertion helpers — every claim names its alpha and n
# ---------------------------------------------------------------------------


def assert_same_distribution(counts_a, counts_b, *, alpha: float,
                             what: str = "") -> float:
    """Assert two frequency histograms are statistically indistinguishable
    (χ² homogeneity, significance ``alpha``).  Returns the p-value."""
    n_a = int(np.asarray(counts_a).sum())
    n_b = int(np.asarray(counts_b).sum())
    stat, df, p = chi2_homogeneity(counts_a, counts_b)
    assert p >= alpha, (
        f"distributions differ{': ' + what if what else ''} — "
        f"chi2={stat:.2f} df={df} p={p:.3e} < alpha={alpha} "
        f"(n_a={n_a}, n_b={n_b})")
    return p


def assert_matches_probs(counts, probs, *, alpha: float,
                         what: str = "") -> float:
    """Assert a histogram matches a known distribution (χ² GOF)."""
    n = int(np.asarray(counts).sum())
    stat, df, p = chi2_gof(counts, probs)
    assert p >= alpha, (
        f"histogram off its distribution{': ' + what if what else ''} — "
        f"chi2={stat:.2f} df={df} p={p:.3e} < alpha={alpha} (n={n})")
    return p


def assert_binom_fraction(k: int, n: int, *, p_null: float, alpha: float,
                          what: str = "") -> float:
    """Assert ``k`` successes out of ``n`` are significantly MORE likely
    than the null success probability ``p_null`` (one-sided exact
    binomial).  Returns the p-value."""
    p = binom_sf(k, n, p_null)
    assert p < alpha, (
        f"fraction not above chance{': ' + what if what else ''} — "
        f"{k}/{n} successes, one-sided binomial p={p:.3e} >= alpha={alpha} "
        f"under p_null={p_null}")
    return p
