"""Self-speculative decoding invariants.

The correctness oracle (ISSUE 3): greedy speculative decode must be
**token-identical** to plain paged decode for ANY drafter — the draft
only decides how many dense-verified tokens each round emits.  Pinned
here across dense, windowed, runtime-expert-mask, and stage-2
weight-mask drafters, plus EOS / ``max_new_tokens`` firing mid-block,
overdraft page accounting, and submit-time rejection of unservable
speculative requests.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import Request, ServeEngine


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _requests(cfg, specs, seed=7):
    rs = np.random.RandomState(seed)
    return [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in specs]


def _clone(reqs):
    return [Request(r.prompt, r.max_new_tokens, eos_id=r.eos_id,
                    temperature=r.temperature) for r in reqs]


SPECS = [(5, 7), (12, 4), (3, 9), (9, 8), (2, 1)]


def test_spec_identical_to_plain_paged_moe(moe):
    """Expert-mask drafter: spec output == plain dense paged decode,
    for several spec_k values (including k=1, the minimal block)."""
    cfg, params = moe
    reqs = _requests(cfg, SPECS)
    plain = ServeEngine(params, cfg, max_len=32, max_batch=3,
                        prefill_chunk=8, page_size=8)
    ref = plain.generate(_clone(reqs))
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    for k in (1, 4):
        spec = ServeEngine(params, cfg, max_len=32, max_batch=3,
                           prefill_chunk=8, page_size=8,
                           spec_decode="pruned", spec_k=k,
                           expert_mask=mask)
        outs = spec.generate(_clone(reqs))
        for a, b in zip(outs, ref):
            np.testing.assert_array_equal(a, b)
        st = spec.latency_stats()
        assert 0.0 <= st["spec_accept_rate"] <= 1.0
        assert st["spec_tokens_per_verify"] > 0
        # every lane emits >= 1 token per verify round
        assert st["spec_emitted"] >= st["spec_rounds"]
        assert spec.cache.free_pages == spec.cache.page_budget


def test_spec_identity_drafter_accepts_everything(moe):
    """draft params == dense params: every draft token must be accepted
    and each round emits the full spec_k + 1 block per lane."""
    cfg, params = moe
    reqs = _requests(cfg, [(6, 9), (4, 9)])
    spec = ServeEngine(params, cfg, max_len=32, max_batch=2,
                       prefill_chunk=8, page_size=8,
                       spec_decode="pruned", spec_k=3)
    plain = ServeEngine(params, cfg, max_len=32, max_batch=2,
                        prefill_chunk=8, page_size=8)
    outs, ref = spec.generate(_clone(reqs)), plain.generate(_clone(reqs))
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(a, b)
    st = spec.latency_stats()
    assert st["spec_accept_rate"] == 1.0
    assert st["spec_drafted"] == st["spec_accepted"]


def test_spec_weight_mask_drafter(moe):
    """Stage-2 weight-masked drafter (the STUN artifact): still
    token-identical — and the engine must serve the UNMASKED weights."""
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    batches = calibration_batches(cfg, n_batches=2)
    _, masks, _ = unstructured_only(params, cfg, batches,
                                    target_sparsity=0.5, method="wanda")
    reqs = _requests(cfg, [(5, 8), (11, 6)])
    plain = ServeEngine(params, cfg, max_len=32, max_batch=2,
                        prefill_chunk=8, page_size=8)
    spec = ServeEngine(params, cfg, max_len=32, max_batch=2,
                       prefill_chunk=8, page_size=8,
                       spec_decode="pruned", spec_k=3, weight_masks=masks)
    for a, b in zip(spec.generate(_clone(reqs)), plain.generate(_clone(reqs))):
        np.testing.assert_array_equal(a, b)


def test_spec_windowed_dense():
    """Sliding-window attention through draft + verify blocks."""
    cfg = reduced(get_config("qwen2-7b"), n_layers=2)
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="full",
                              local_window=8)
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(2))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    reqs = _requests(cfg, [(13, 5), (3, 7), (21, 4)], seed=5)
    plain = ServeEngine(params, cfg, max_len=32, max_batch=2,
                        prefill_chunk=4, page_size=8)
    # draft from a perturbed copy: disagreement exercises rollback under
    # the window
    draft = jax.tree.map(lambda x: x + 0.05 * jnp.ones_like(x), params)
    spec = ServeEngine(params, cfg, max_len=32, max_batch=2,
                       prefill_chunk=4, page_size=8,
                       spec_decode="pruned", spec_k=4, draft_params=draft)
    for a, b in zip(spec.generate(_clone(reqs)), plain.generate(_clone(reqs))):
        np.testing.assert_array_equal(a, b)


def test_spec_eos_fires_mid_block(moe):
    """EOS inside an accepted block must terminate exactly where plain
    decode does — the block's rejected/overrun suffix is dropped."""
    cfg, params = moe
    req = _requests(cfg, [(6, 12)])[0]
    plain = ServeEngine(params, cfg, max_len=32, max_batch=1,
                        prefill_chunk=8, page_size=8)
    ref = plain.generate([Request(req.prompt, 12)])[0]
    assert len(ref) == 12
    # pick an eos that plain decode hits mid-stream
    eos = int(ref[5])
    plain2 = ServeEngine(params, cfg, max_len=32, max_batch=1,
                         prefill_chunk=8, page_size=8)
    ref_eos = plain2.generate([Request(req.prompt, 12, eos_id=eos)])[0]
    spec = ServeEngine(params, cfg, max_len=32, max_batch=1,
                       prefill_chunk=8, page_size=8,
                       spec_decode="pruned", spec_k=4)
    out = spec.generate([Request(req.prompt, 12, eos_id=eos)])[0]
    np.testing.assert_array_equal(out, ref_eos)
    assert out[-1] == eos and len(out) <= 12


def test_spec_overdraft_reservation(moe):
    """Admission reserves ceil((total + spec_k - 1)/ps) pages so verify
    blocks never write onto the sentinel page; submit() gates on the
    same lifetime reservation."""
    cfg, params = moe
    k = 4
    spec = ServeEngine(params, cfg, max_len=32, max_batch=2,
                       prefill_chunk=8, page_size=8,
                       spec_decode="pruned", spec_k=k, page_budget=4)
    assert spec.cache.overdraft == k - 1
    # 9 + 8 = 17 lifetime tokens + 3 overdraft rows -> ceil(20/8) = 3 pages
    rs = np.random.RandomState(0)
    spec.submit(Request(rs.randint(0, cfg.vocab, 9).astype(np.int32), 8))
    spec.step()
    g = spec.latency_stats()
    assert g["pages_in_use"] == spec.cache.lifetime_pages(17) == 3
    spec.run()
    assert spec.cache.free_pages == spec.cache.page_budget
    with pytest.raises(ValueError, match="overdraft"):
        # 22 + 8 = 30 tokens (4 pages, fits the budget) + 3 overdraft
        # rows = 33 -> 5 pages > budget 4: the submit gate must count the
        # overdraft, not just the request's own lifetime
        spec.submit(Request(rs.randint(0, cfg.vocab, 22).astype(np.int32), 8))


def test_spec_rejects_unservable(moe):
    cfg, params = moe
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(params, cfg, max_len=32, kv_layout="slot",
                    spec_decode="pruned")
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(params, cfg, max_len=32, spec_decode="pruned", spec_k=0)
    with pytest.raises(ValueError, match="spec_tree"):
        ServeEngine(params, cfg, max_len=32, spec_decode="pruned",
                    spec_tree=0)
    with pytest.raises(ValueError, match="spec_decode"):
        ServeEngine(params, cfg, max_len=32, spec_decode="layerdrop")
    # sampled requests are servable in spec mode now: rejection-sampling
    # verification preserves the dense distribution at any temperature
    spec = ServeEngine(params, cfg, max_len=32, max_batch=2,
                       prefill_chunk=8, page_size=8, spec_decode="pruned")
    out = spec.generate([Request(np.arange(4, dtype=np.int32), 4,
                                 temperature=0.7)])[0]
    assert len(out) == 4


def test_spec_tree_greedy_identical_to_plain(moe):
    """Tree drafts (spec_tree > 1): greedy output must STILL be
    token-identical to plain dense decode for any drafter — the tree
    only widens what each verify dispatch can accept."""
    cfg, params = moe
    reqs = _requests(cfg, SPECS)
    plain = ServeEngine(params, cfg, max_len=32, max_batch=3,
                        prefill_chunk=8, page_size=8)
    ref = plain.generate(_clone(reqs))
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    for n_branches, k in ((2, 3), (3, 2)):
        spec = ServeEngine(params, cfg, max_len=32, max_batch=3,
                           prefill_chunk=8, page_size=8,
                           spec_decode="pruned", spec_k=k,
                           spec_tree=n_branches, expert_mask=mask)
        assert spec.cache.overdraft == n_branches * k - 1
        outs = spec.generate(_clone(reqs))
        for a, b in zip(outs, ref):
            np.testing.assert_array_equal(a, b)
        st = spec.latency_stats()
        assert st["spec_emitted"] == (st["spec_accepted"]
                                      + st["spec_corrections"])
        assert st["spec_accepted"] <= st["spec_drafted"]
        assert st["spec_drafted_nodes"] == n_branches * st["spec_drafted"]
        assert spec.cache.free_pages == spec.cache.page_budget


def test_spec_tree_eos_mid_block(moe):
    """EOS firing inside an accepted tree block terminates exactly where
    plain decode does, and the lane's pages are fully released."""
    cfg, params = moe
    req = _requests(cfg, [(6, 12)])[0]
    plain = ServeEngine(params, cfg, max_len=32, max_batch=1,
                        prefill_chunk=8, page_size=8)
    ref = plain.generate([Request(req.prompt, 12)])[0]
    eos = int(ref[5])
    plain2 = ServeEngine(params, cfg, max_len=32, max_batch=1,
                         prefill_chunk=8, page_size=8)
    ref_eos = plain2.generate([Request(req.prompt, 12, eos_id=eos)])[0]
    spec = ServeEngine(params, cfg, max_len=32, max_batch=1,
                       prefill_chunk=8, page_size=8,
                       spec_decode="pruned", spec_k=3, spec_tree=2)
    out = spec.generate([Request(req.prompt, 12, eos_id=eos)])[0]
    np.testing.assert_array_equal(out, ref_eos)
    st = spec.latency_stats()
    assert st["spec_emitted"] == (st["spec_accepted"]
                                  + st["spec_corrections"])
    assert spec.cache.free_pages == spec.cache.page_budget
