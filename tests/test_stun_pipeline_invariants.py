"""STUN pipeline accounting invariants + mixtral-proxy coverage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import stun_prune
from repro.core.stun import _expert_param_fraction
from repro.data import calibration_batches
from repro.models import abstract_params, forward, loss_fn
from repro.models import param as pm

RNG = jax.random.PRNGKey(0)


def _tiny(arch="olmoe-1b-7b", **kw):
    cfg = reduced(get_config(arch), n_layers=2, **kw)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          pm.init_params(abstract_params(cfg), RNG))
    return cfg, params


@pytest.mark.parametrize("target", [0.3, 0.5, 0.65])
def test_total_sparsity_accounting(target):
    """structured_ratio + (1-structured)·unstructured == target (the
    paper's sparsity bookkeeping)."""
    cfg, params = _tiny(n_experts=8, top_k=2)
    batches = calibration_batches(cfg, n_batches=2)
    _, _, _, rep = stun_prune(params, cfg, batches, target_sparsity=target,
                              expert_ratio=0.25)
    total = rep.structured_ratio + (1 - rep.structured_ratio) * \
        rep.unstructured_ratio
    assert abs(total - target) < 1e-6


def test_expert_param_fraction_bounds():
    cfg, _ = _tiny(n_experts=8, top_k=2)
    f = _expert_param_fraction(cfg)
    assert 0.0 < f < 1.0
    # expert weights dominate attention in this geometry
    assert f > 0.5


def test_lam2_coactivation_path_end_to_end():
    """λ=(1,1): coactivation statistics flow through the whole pipeline."""
    cfg, params = _tiny(n_experts=8, top_k=2)
    batches = calibration_batches(cfg, n_batches=2)
    p, c, _, rep = stun_prune(params, cfg, batches, target_sparsity=0.4,
                              expert_ratio=0.25, lam1=1.0, lam2=1.0)
    assert rep.forward_passes >= len(batches)  # coactivation sweep counted
    assert c.n_experts == 6
    assert jnp.isfinite(loss_fn(p, c, batches[0]))


def test_mixtral_proxy_registered_and_runs():
    """The paper's own comparison arch (Table 2 parity config)."""
    cfg = get_config("mixtral-8x7b-proxy")
    assert cfg.n_experts == 8 and cfg.top_k == 2 and cfg.n_layers == 32
    small, params = _tiny("mixtral-8x7b-proxy", n_experts=8, top_k=2)
    toks = jax.random.randint(RNG, (2, 16), 0, small.vocab)
    logits = forward(params, small, {"tokens": toks})
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pruned_model_still_serves():
    from repro.serving import Request, ServeEngine
    cfg, params = _tiny(n_experts=8, top_k=2)
    batches = calibration_batches(cfg, n_batches=2)
    p, c, _, _ = stun_prune(params, cfg, batches, target_sparsity=0.4,
                            expert_ratio=0.25)
    eng = ServeEngine(p, c, max_len=32)
    outs = eng.generate([Request(np.array([1, 2, 3], np.int32), 4)])
    assert outs[0].shape == (4,)
