"""Per-request PRNG key chains: sampled streams are schedule-invariant.

The engine derives sampling noise from ``request_key(seed, rid, m)`` —
a pure function of the engine seed, the request id, and the 0-based
token index — with NO shared mutable key.  Consequences pinned here:

  * a request's sampled stream is identical whether it runs alone or
    packed in a batch, whatever the admission timing, lane count, or
    prefill schedule;
  * identity-drafter speculative decoding reproduces the plain sampled
    stream token-for-token at any temperature (draft proposals and
    bonus tokens consume the same ROLE_TARGET stream plain sampling
    does, and q == p accepts everything);
  * temperature==0 lanes in spec mode stay bit-for-bit greedy (chain
    AND tree), even sharing a batch with sampled lanes;
  * the OLD design — one shared key split per dispatch — fails the
    batch-composition invariance (discrimination twin: reinstating it
    via monkeypatch must break the test the new sampler passes).
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import Request, ServeEngine


def _tiny_moe(seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _engine(moe, **kw):
    cfg, params = moe
    kw.setdefault("max_len", 32)
    kw.setdefault("max_batch", 4)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("page_size", 8)
    kw.setdefault("seed", 9)
    return ServeEngine(params, cfg, **kw)


def _prompts(cfg, specs, seed=3):
    rs = np.random.RandomState(seed)
    return [rs.randint(0, cfg.vocab, n).astype(np.int32) for n in specs]


def test_sampled_stream_invariant_to_batch_composition(moe):
    """Same (seed, rid): running alone == running packed with neighbors,
    across different lane counts."""
    cfg, _ = moe
    p0, p1, p2, p3 = _prompts(cfg, [6, 9, 4, 11])
    solo = _engine(moe).generate([Request(p0, 6, temperature=0.8)])[0]
    batched = _engine(moe).generate(
        [Request(p0, 6, temperature=0.8), Request(p1, 5, temperature=0.5),
         Request(p2, 7, temperature=1.2), Request(p3, 4)])
    np.testing.assert_array_equal(solo, batched[0])
    # fewer lanes -> different waves/slots, same rids, same streams
    narrow = _engine(moe, max_batch=2).generate(
        [Request(p0, 6, temperature=0.8), Request(p1, 5, temperature=0.5),
         Request(p2, 7, temperature=1.2), Request(p3, 4)])
    for a, b in zip(batched, narrow):
        np.testing.assert_array_equal(a, b)


def test_sampled_stream_invariant_to_admission_timing(moe):
    """Submitting mid-flight (same rids) does not perturb anyone's
    stream — no shared key advances when a neighbor joins."""
    cfg, _ = moe
    p0, p1, p2 = _prompts(cfg, [6, 9, 4])
    reqs = lambda: [Request(p0, 6, temperature=0.8),
                    Request(p1, 6, temperature=0.6),
                    Request(p2, 6, temperature=1.0)]
    upfront = _engine(moe).generate(reqs())
    eng = _engine(moe)
    r0, r1, r2 = reqs()
    rid0 = eng.submit(r0)
    eng.step(); eng.step()
    rid1 = eng.submit(r1)
    eng.step()
    rid2 = eng.submit(r2)
    eng.run()
    staggered = [eng.scheduler.result(r) for r in (rid0, rid1, rid2)]
    for a, b in zip(upfront, staggered):
        np.testing.assert_array_equal(a, b)


def test_sampled_stream_invariant_to_schedule(moe):
    cfg, _ = moe
    prompts = _prompts(cfg, [6, 9, 4, 11])
    mk = lambda: [Request(p, 6, temperature=0.9) for p in prompts]
    a = _engine(moe, schedule="interleaved").generate(mk())
    b = _engine(moe, schedule="blocking").generate(mk())
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_spec_identity_drafter_sampled_identical_to_plain(moe):
    """q == p: every draft accepted, and because draft proposals +
    bonus tokens ride the ROLE_TARGET stream at the token's own index,
    the spec sampled stream is token-identical to plain sampling — for
    chain AND tree drafts."""
    cfg, _ = moe
    prompts = _prompts(cfg, [6, 4])
    mk = lambda: [Request(prompts[0], 8, temperature=0.7),
                  Request(prompts[1], 8, temperature=1.1)]
    ref = _engine(moe).generate(mk())
    for tree in (1, 2):
        spec = _engine(moe, spec_decode="pruned", spec_k=3, spec_tree=tree)
        outs = spec.generate(mk())
        for a, b in zip(outs, ref):
            np.testing.assert_array_equal(a, b)
        assert spec.latency_stats()["spec_accept_rate"] == 1.0


def test_temp0_lanes_stay_greedy_in_mixed_spec_batch(moe):
    """Greedy lanes sharing a spec batch with sampled lanes stay
    bit-for-bit identical to plain greedy decode (chain and tree,
    disagreeing drafter)."""
    cfg, params = moe
    prompts = _prompts(cfg, [6, 9, 4])
    mk = lambda: [Request(prompts[0], 8),
                  Request(prompts[1], 8, temperature=0.7),
                  Request(prompts[2], 8)]
    plain = _engine(moe)
    ref = plain.generate(mk())
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    for tree in (1, 2):
        spec = _engine(moe, spec_decode="pruned", spec_k=3, spec_tree=tree,
                       expert_mask=mask)
        outs = spec.generate(mk())
        np.testing.assert_array_equal(outs[0], ref[0])
        np.testing.assert_array_equal(outs[2], ref[2])
        st = spec.latency_stats()
        assert st["spec_emitted"] == (st["spec_accepted"]
                                      + st["spec_corrections"])


def _install_legacy_shared_sampler(eng, seed):
    """Reinstate the pre-ISSUE-8 sampler: ONE engine-owned key, split
    once per sampling dispatch — every neighbor's dispatch advances it."""
    eng._legacy_key = jax.random.PRNGKey(seed)

    def shared(self, logits, states):
        lg = jnp.asarray(logits)[:, : self.cfg.vocab].astype(jnp.float32)
        temps = np.zeros(lg.shape[0], np.float32)
        for st in states:
            idx = st.slot if lg.shape[0] > 1 else 0
            temps[idx] = st.req.temperature
        self._legacy_key, sub = jax.random.split(self._legacy_key)
        g = jax.random.gumbel(sub, lg.shape, jnp.float32)
        t = jnp.asarray(temps)
        samp = jnp.argmax(lg / jnp.maximum(t[:, None], 1e-6) + g, axis=-1)
        return np.asarray(
            jnp.where(t > 0, samp, jnp.argmax(lg, axis=-1)), np.int32)

    eng._sample_batch = types.MethodType(shared, eng)


def test_shared_stream_sampler_breaks_batch_invariance(moe):
    """Discrimination twin: with the legacy shared-key sampler patched
    back in, the batch-composition invariance that
    test_sampled_stream_invariant_to_batch_composition pins MUST fail —
    proving that test discriminates the old design, not vacuously
    passing for any sampler."""
    cfg, _ = moe
    p0, p1, p2 = _prompts(cfg, [6, 9, 4])
    solo = _engine(moe)
    _install_legacy_shared_sampler(solo, seed=9)
    out_solo = solo.generate([Request(p0, 6, temperature=0.8)])[0]
    batched = _engine(moe)
    _install_legacy_shared_sampler(batched, seed=9)
    out_batched = batched.generate(
        [Request(p0, 6, temperature=0.8),
         Request(p1, 6, temperature=0.6),
         Request(p2, 6, temperature=1.0)])[0]
    assert not np.array_equal(out_solo, out_batched), (
        "legacy shared-stream sampler unexpectedly schedule-invariant — "
        "the batch-composition test has no discriminating power")
