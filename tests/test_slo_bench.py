"""Units for the open-loop SLO harness (benchmarks/bench_slo.py).

Fake-clock tests pin the scoring logic (TTFT measured from *arrival*,
per-request p95 TPOT from the scheduler's per-request gap trace, the
attainment/goodput arithmetic) and the Poisson arrival generator;
one tiny-engine test drives the real wall-clock loop end to end and
checks every request is submitted at (not before) its arrival and the
drained trial scores cleanly.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import bench_slo
from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import Request, Scheduler, ServeEngine


def _tiny_moe(seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


def test_poisson_arrivals_shape_and_rate():
    arr = bench_slo._arrivals(qps=4.0, n=4000, seed=0)
    assert len(arr) == 4000
    assert np.all(np.diff(arr) > 0)              # strictly increasing
    gaps = np.diff(np.concatenate([[0.0], arr]))
    assert np.mean(gaps) == pytest.approx(0.25, rel=0.1)   # Exp(1/qps)
    # deterministic per seed, different across seeds
    np.testing.assert_array_equal(arr, bench_slo._arrivals(4.0, 4000, 0))
    assert not np.array_equal(arr, bench_slo._arrivals(4.0, 4000, 1))


def _fake_finished(t_submit, token_times):
    """Drive one request through a real Scheduler on a fake clock."""
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1, 2], np.int32),
                               max_new_tokens=len(token_times)),
                       now=t_submit)
    sched.admit(slot=0)
    sched.activate(rid)
    for t in token_times:
        sched.on_token(rid, 7, now=t)
    return sched, rid


def test_score_trial_ttft_from_arrival_and_per_request_tpot():
    """TTFT is scored against the request's ARRIVAL offset (queueing
    counts), TPOT against the request's own p95 gap."""
    # t0=10; arrival at offset 1 (absolute 11); first token at 12 ->
    # TTFT = 1.0s even though t_submit (12 - well after arrival) would
    # say less.  Gaps 0.1 x3 -> p95 0.1.
    sched, rid = _fake_finished(t_submit=11.5,
                                token_times=[12.0, 12.1, 12.2, 12.3])
    eng = types.SimpleNamespace(scheduler=sched)
    out = bench_slo.score_trial(eng, [(rid, 1.0)], t0=10.0, wall=5.0,
                                slo_ttft=1.5, slo_tpot=0.2)
    assert out["attainment"] == 1.0
    assert out["goodput_rps"] == pytest.approx(1 / 5.0)
    assert out["p95_ttft_s"] == pytest.approx(1.0)   # 12.0 - (10.0 + 1.0)
    assert out["p95_tpot_s"] == pytest.approx(0.1)


def test_score_trial_attainment_counts_both_slos():
    # req A: fast TTFT, fast TPOT -> meets.  B: slow TTFT.  C: TTFT ok,
    # one huge gap -> p95 TPOT blows the SLO.
    sched = Scheduler()
    specs = [  # (arrival_offset, first_token_at, gaps)
        (0.0, 0.5, [0.1, 0.1]),
        (0.0, 9.0, [0.1, 0.1]),
        (0.0, 0.5, [5.0, 0.1]),
    ]
    records = []
    for slot, (arr, first, gaps) in enumerate(specs):
        rid = sched.submit(Request(np.array([1], np.int32),
                                   max_new_tokens=1 + len(gaps)), now=arr)
        sched.admit(slot=slot)
        sched.activate(rid)
        t = first
        sched.on_token(rid, 7, now=t)
        for g in gaps:
            t += g
            sched.on_token(rid, 7, now=t)
        records.append((rid, arr))
    eng = types.SimpleNamespace(scheduler=sched)
    out = bench_slo.score_trial(eng, records, t0=0.0, wall=10.0,
                                slo_ttft=1.0, slo_tpot=1.0)
    assert out["attainment"] == pytest.approx(1 / 3)
    assert out["goodput_rps"] == pytest.approx(1 / 10.0)
    # scoring pops finished state (bounded memory over a long run)
    assert not sched.finished


def test_score_trial_single_token_stream_tpot_vacuous():
    sched, rid = _fake_finished(t_submit=0.0, token_times=[0.5])
    eng = types.SimpleNamespace(scheduler=sched)
    out = bench_slo.score_trial(eng, [(rid, 0.0)], t0=0.0, wall=1.0,
                                slo_ttft=1.0, slo_tpot=1e-9)
    assert out["attainment"] == 1.0              # no gaps: TPOT can't fail


def test_drive_open_loop_wall_clock(monkeypatch):
    """End to end on a real tiny engine: every request is submitted at
    or after its arrival offset, all drain, and the trial scores."""
    cfg, params = _tiny_moe()
    monkeypatch.setattr(bench_slo, "N_REQUESTS", 6)
    eng = ServeEngine(params, cfg, max_len=64, max_batch=2,
                      prefill_chunk=8)
    rs = np.random.RandomState(0)
    reqs = [Request(rs.randint(0, cfg.vocab, 6).astype(np.int32), 4)
            for _ in range(6)]
    arrivals = bench_slo._arrivals(qps=50.0, n=6, seed=0)
    records, wall, t0 = bench_slo.drive_open_loop(eng, reqs, arrivals)
    assert len(records) == 6 and wall >= arrivals[-1]
    sched = eng.scheduler
    for (rid, arr) in records:
        st = sched.finished[rid]
        # submitted at/after its arrival instant, never before
        assert st.t_submit - t0 >= arr - 1e-6
    out = bench_slo.score_trial(eng, records, t0, wall,
                                slo_ttft=None, slo_tpot=None)
    assert out["attainment"] == 1.0              # no SLO: everything meets
    assert out["n_requests"] == 6


def test_config_matrix_covers_required_grid():
    grid = {(c["schedule"], c["spec"]) for c in bench_slo.CONFIGS.values()}
    assert {("blocking", False), ("interleaved", False),
            ("blocking", True), ("interleaved", True)} <= grid
