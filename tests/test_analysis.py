"""Dispatch-safety analysis: checker discrimination + sanitizer oracle.

Each lint checker gets a **bad fixture** (trips exactly that checker)
and a **clean twin** (the minimal correct rewrite — zero findings), so
the suite proves the checkers discriminate rather than merely fire.
The runtime sanitizer is pinned two ways: re-introducing the PR-4
``seq_lens`` aliasing bug into a live engine fails **deterministically**
under ``REPRO_SANITIZE=1`` (the bug it was built for was a
timing-dependent coin flip), and a healthy engine under the sanitizer
stays token-identical to an unsanitized run.  Finally the lint over the
real ``src/`` tree is pinned clean — a regression that introduces a
finding (or an unexplained suppression) fails here before CI.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import analyze_source, checkers_for, sanitizer

ROOT = Path(__file__).resolve().parent.parent

SERVING = "src/repro/serving/fixture.py"
KERNELS = "src/repro/kernels/fixture.py"


def _checks(text, path):
    return [(f.check, f.severity) for f in analyze_source(text, path)]


# ---------------------------------------------------------------------------
# aliasing-hazard
# ---------------------------------------------------------------------------

ALIAS_BAD = '''
import numpy as np
import jax.numpy as jnp

class Cache:
    def __init__(self, n):
        self.seq_lens = np.zeros(n, np.int32)
        self._decode = jit(step)

    def seq_lens_device(self):
        return jnp.asarray(self.seq_lens)

    def dispatch(self, params):
        return self._decode(params, self.seq_lens)
'''

ALIAS_CLEAN = '''
import numpy as np
import jax.numpy as jnp

class Cache:
    def __init__(self, n):
        self.seq_lens = np.zeros(n, np.int32)
        self._decode = jit(step)

    def seq_lens_device(self):
        return jnp.asarray(self.seq_lens.copy())

    def dispatch(self, params):
        return self._decode(params, self.seq_lens.copy())
'''


def test_aliasing_hazard_trips_on_live_buffer():
    checks = _checks(ALIAS_BAD, SERVING)
    assert ("aliasing-hazard", "error") in checks
    assert all(c == "aliasing-hazard" for c, _ in checks)
    # both the device-view return and the dispatcher argument are flagged
    assert len(checks) == 2


def test_aliasing_hazard_clean_twin():
    assert _checks(ALIAS_CLEAN, SERVING) == []


def test_aliasing_hazard_sees_through_sanitizer_guard():
    # guard() wrapping must not hide the attribute from the checker
    guarded = ALIAS_BAD.replace(
        "np.zeros(n, np.int32)",
        'sanitizer.guard(np.zeros(n, np.int32), "seq_lens")')
    checks = _checks(guarded, SERVING)
    assert ("aliasing-hazard", "error") in checks


def test_aliasing_hazard_flags_bare_device_return():
    src = '''
import numpy as np

class Cache:
    def __init__(self):
        self.table = np.zeros((4, 4), np.int32)

    def table_device(self):
        return self.table
'''
    checks = _checks(src, SERVING)
    assert checks == [("aliasing-hazard", "error")]


CONTAINER_BAD = '''
import numpy as np
import jax.numpy as jnp

class Cache:
    def __init__(self):
        self._pages_of = {}
        self._trie_pages: list = []
        self._decode = jax.jit(step)

    def table_row(self, slot):
        return jnp.asarray(self._pages_of[slot])

    def dispatch(self, params):
        return self._decode(params, self._trie_pages[0])
'''

CONTAINER_CLEAN = CONTAINER_BAD.replace(
    "self._pages_of[slot])", "self._pages_of[slot].copy())").replace(
    "self._trie_pages[0])", "self._trie_pages[0].copy())")


def test_aliasing_hazard_flags_container_elements():
    """Trie-held / dict-held page lists handed to device conversions or
    jitted dispatches need the same .copy() discipline as seq_lens —
    both the dict-literal and annotated list-attr forms are caught."""
    checks = _checks(CONTAINER_BAD, SERVING)
    assert checks == [("aliasing-hazard", "error")] * 2


def test_aliasing_hazard_container_clean_twin():
    assert _checks(CONTAINER_CLEAN, SERVING) == []


# ---------------------------------------------------------------------------
# jit-discipline
# ---------------------------------------------------------------------------

JIT_BAD = '''
import jax

@jax.jit
def step(params, tokens):
    return params @ tokens

fast = jax.jit(step, static_argnames=("missing",))
'''

JIT_CLEAN = '''
import jax

@jax.jit
def step(params, tokens):
    return params @ tokens

fast = jax.jit(step, static_argnames=("tokens",))
'''


def test_jit_discipline_unknown_static_argname():
    checks = _checks(JIT_BAD, SERVING)
    assert checks == [("jit-discipline", "error")]


def test_jit_discipline_clean_twin():
    assert _checks(JIT_CLEAN, SERVING) == []


def test_jit_discipline_out_of_range_argnum():
    src = '''
import jax

@jax.jit
def f(x):
    return x

g = jax.jit(f, static_argnums=(3,))
'''
    checks = _checks(src, SERVING)
    assert checks == [("jit-discipline", "error")]


def test_jit_discipline_captured_mutation():
    src = '''
import jax

state = []

@jax.jit
def f(x):
    state.append(x)
    return x
'''
    checks = _checks(src, SERVING)
    assert checks == [("jit-discipline", "error")]


def test_jit_discipline_shape_branch_warns():
    src = '''
import jax

@jax.jit
def f(x):
    if x.shape[0] > 4:
        return x * 2
    return x
'''
    checks = _checks(src, SERVING)
    assert checks == [("jit-discipline", "warning")]


# ---------------------------------------------------------------------------
# pallas-invariants
# ---------------------------------------------------------------------------

PALLAS_BAD_DIVIS = '''
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def run(x):
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((100,), jnp.float32),
        grid=(7,),
        out_specs=pl.BlockSpec((16,), lambda i: (i,)),
    )(x)
'''

# 112 = 7 * 16: divisible and exactly covered by the grid
PALLAS_CLEAN = PALLAS_BAD_DIVIS.replace("(100,)", "(112,)")

PALLAS_BAD_ARITY = '''
from jax.experimental import pallas as pl
from repro.kernels.compat import PrefetchScalarGridSpec

def run(x, s):
    gs = PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i, sref: (sref[i],)))
    return pl.pallas_call(kern, grid_spec=gs, out_shape=o)(s, x)
'''

PALLAS_CLEAN_ARITY = PALLAS_BAD_ARITY.replace(
    "lambda i: (i,)", "lambda i, sref: (i,)")


def test_pallas_indivisible_block():
    checks = _checks(PALLAS_BAD_DIVIS, KERNELS)
    assert checks == [("pallas-invariants", "error")]


def test_pallas_clean_twin():
    assert _checks(PALLAS_CLEAN, KERNELS) == []


def test_pallas_prefetch_arity():
    # in_specs map misses the scalar-ref param: prefetch order shifts
    checks = _checks(PALLAS_BAD_ARITY, KERNELS)
    assert checks == [("pallas-invariants", "error")]


def test_pallas_clean_prefetch_twin():
    assert _checks(PALLAS_CLEAN_ARITY, KERNELS) == []


def test_pallas_index_map_reads_grid_index():
    src = PALLAS_CLEAN_ARITY.replace("(sref[i],)", "(i[0],)")
    checks = _checks(src, KERNELS)
    assert checks == [("pallas-invariants", "error")]


def test_pallas_operand_count():
    src = PALLAS_CLEAN_ARITY.replace(")(s, x)", ")(x)")
    checks = _checks(src, KERNELS)
    assert checks == [("pallas-invariants", "error")]


def test_pallas_shimmed_symbol_outside_compat():
    src = '''
from jax.experimental.pallas import tpu as pltpu

params = pltpu.CompilerParams(dimension_semantics=("parallel",))
'''
    checks = _checks(src, KERNELS)
    assert checks == [("pallas-invariants", "error")]


def test_pallas_not_run_outside_kernels():
    assert checkers_for(SERVING) and all(
        c.name != "pallas-invariants" for c in checkers_for(SERVING))


# ---------------------------------------------------------------------------
# dtype-discipline
# ---------------------------------------------------------------------------

DTYPE_BAD = '''
import jax.numpy as jnp

def matmul_f8(a, b):
    a8 = a.astype(jnp.float8_e4m3fn)
    return jnp.einsum("ij,jk->ik", a8, b)
'''

DTYPE_CLEAN = DTYPE_BAD.replace(
    'jnp.einsum("ij,jk->ik", a8, b)',
    'jnp.einsum("ij,jk->ik", a8, b, preferred_element_type=jnp.float32)')


def test_dtype_discipline_f8_accumulation():
    checks = _checks(DTYPE_BAD, "src/repro/sparse/fixture.py")
    assert checks == [("dtype-discipline", "warning")]


def test_dtype_discipline_clean_twin():
    assert _checks(DTYPE_CLEAN, "src/repro/sparse/fixture.py") == []


def test_dtype_discipline_scoped_to_sub_fp32_functions():
    # plain fp32 einsum: no sub-fp32 dtype in scope, nothing to flag
    src = '''
import jax.numpy as jnp

def matmul(a, b):
    return jnp.einsum("ij,jk->ik", a, b)
'''
    assert _checks(src, "src/repro/sparse/fixture.py") == []


# ---------------------------------------------------------------------------
# timing-discipline
# ---------------------------------------------------------------------------

TIMING_BAD = '''
import time

class Engine:
    def step(self, params, toks):
        t0 = time.monotonic()
        logits = self._decode(params, toks)
        self.window.append(time.monotonic() - t0)
'''

# the minimal correct rewrite: materialize the dispatch result before
# the closing stamp
TIMING_CLEAN = TIMING_BAD.replace(
    "self.window.append(time.monotonic() - t0)",
    "np.asarray(logits)\n"
    "        self.window.append(time.monotonic() - t0)")


def test_timing_discipline_unfenced_window():
    checks = _checks(TIMING_BAD, SERVING)
    assert checks == [("timing-discipline", "error")]


def test_timing_discipline_clean_twin():
    assert _checks(TIMING_CLEAN, SERVING) == []


def test_timing_discipline_wall_clock():
    src = '''
import time

def stamp():
    return time.time()
'''
    checks = _checks(src, SERVING)
    assert checks == [("timing-discipline", "error")]
    # scoped: the same code outside serving/bench/launch is not flagged
    assert _checks(src, "src/repro/core/fixture.py") == []


def test_timing_discipline_jit_local_dispatch():
    src = '''
import time
import jax

step = jax.jit(lambda x: x * 2)

def bench(x):
    t0 = time.monotonic()
    y = step(x)
    return time.monotonic() - t0
'''
    checks = _checks(src, "benchmarks/fixture.py")
    assert checks == [("timing-discipline", "error")]
    fenced = src.replace("y = step(x)", "y = jax.block_until_ready(step(x))")
    assert _checks(fenced, "benchmarks/fixture.py") == []


def test_timing_discipline_nested_stamp_fence_order():
    # post-order: int(tok) fences before the stamp argument is taken —
    # the exact on_token(rid, int(tok), time.monotonic()) engine idiom
    src = '''
import time

class Engine:
    def step(self, params, toks, rid):
        t0 = time.monotonic()
        tok = self._decode(params, toks)
        self.sched.on_token(rid, int(tok), time.monotonic())
'''
    assert _checks(src, SERVING) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_justified_suppression_silences_finding():
    src = ALIAS_BAD.replace(
        "return jnp.asarray(self.seq_lens)",
        "return jnp.asarray(self.seq_lens)  "
        "# repro-lint: disable=aliasing-hazard -- harness snapshot, "
        "no dispatch in flight")
    checks = _checks(src, SERVING)
    # the suppressed line is silent; the dispatcher-arg finding remains
    assert checks == [("aliasing-hazard", "error")]


def test_unjustified_suppression_is_an_error():
    src = ALIAS_BAD.replace(
        "return jnp.asarray(self.seq_lens)",
        "return jnp.asarray(self.seq_lens)  "
        "# repro-lint: disable=aliasing-hazard")
    checks = _checks(src, SERVING)
    assert ("unexplained-suppression", "error") in checks
    # and the suppression still applies — the finding itself is gone
    assert ("aliasing-hazard", "error") in checks  # dispatcher arg only
    assert len([c for c, _ in checks if c == "aliasing-hazard"]) == 1


def test_parse_error_is_a_finding():
    checks = _checks("def broken(:\n", SERVING)
    assert checks == [("parse-error", "error")]


# ---------------------------------------------------------------------------
# the real tree lints clean
# ---------------------------------------------------------------------------


def test_src_tree_lints_clean():
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "lint_repro.py"),
         str(ROOT / "src"), "--strict"],
        capture_output=True, text=True)
    assert out.returncode == 0, f"lint found issues:\n{out.stdout}"


# ---------------------------------------------------------------------------
# sanitizer semantics (unit)
# ---------------------------------------------------------------------------


@pytest.fixture
def sanitize():
    sanitizer.enable(True)
    yield
    sanitizer.clear_override()


def test_guard_is_identity_when_disabled():
    sanitizer.enable(False)
    try:
        a = np.zeros(4, np.int32)
        assert sanitizer.guard(a, "x") is a
    finally:
        sanitizer.clear_override()


def test_live_view_then_mutation_raises(sanitize):
    a = sanitizer.guard(np.zeros(4, np.int32), "cache.seq_lens")
    a[0] = 1                      # mutation before any view: fine
    sanitizer.device_view(a)      # zero-copy alias of live memory
    with pytest.raises(sanitizer.DispatchRaceError, match="cache.seq_lens"):
        a[1] = 2


def test_copy_snapshot_never_aliases(sanitize):
    a = sanitizer.guard(np.zeros(4, np.int32), "cache.seq_lens")
    for i in range(4):
        sanitizer.device_view(a.copy())   # snapshot: guard stripped
        a[i] = i                          # mutation stays legal


def test_slice_view_inherits_guard(sanitize):
    a = sanitizer.guard(np.zeros((4, 4), np.int32), "cache.page_table")
    sanitizer.device_view(a[1])           # row view shares memory
    with pytest.raises(sanitizer.DispatchRaceError, match="page_table"):
        a[3, 0] = 7                       # any write to the buffer trips


def test_fill_trips_guard(sanitize):
    a = sanitizer.guard(np.zeros(4, np.int32), "buf")
    sanitizer.device_view(a)
    with pytest.raises(sanitizer.DispatchRaceError):
        a.fill(0)


def test_release_clears_aliases(sanitize):
    a = sanitizer.guard(np.zeros(4, np.int32), "buf")
    sanitizer.device_view(a)
    sanitizer.release(a)
    a[0] = 1                              # proven-complete: legal again


# ---------------------------------------------------------------------------
# sanitizer vs the live engine: the PR-4 race, deterministically
# ---------------------------------------------------------------------------


def _tiny_moe():
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import abstract_params
    from repro.models import param as pm

    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _requests(cfg, n=3, seed=7):
    from repro.serving import Request
    rs = np.random.RandomState(seed)
    return [Request(rs.randint(0, cfg.vocab, int(rs.randint(3, 9)))
                    .astype(np.int32), max_new_tokens=4)
            for _ in range(n)]


def test_sanitized_engine_is_token_identical(moe, sanitize):
    """A healthy engine under REPRO_SANITIZE=1: no false positives, and
    the sampled tokens are bit-identical to an unsanitized run."""
    from repro.serving import ServeEngine
    cfg, params = moe
    reqs = _requests(cfg)
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8, page_size=8)
    outs = eng.generate(reqs)
    sanitizer.clear_override()
    plain = ServeEngine(params, cfg, max_len=32, max_batch=2,
                        prefill_chunk=8, page_size=8)
    ref = plain.generate(_requests(cfg))
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(a, b)


def test_pr4_race_fails_deterministically(moe, sanitize, monkeypatch):
    """Re-introduce the exact PR-4 bug — ``seq_lens_device`` returning a
    view of the *live* buffer instead of a ``.copy()`` snapshot — and the
    sanitizer turns the timing-dependent wrong-token coin flip into a
    DispatchRaceError on the first post-dispatch mutation, every run."""
    from repro.serving import PagedKVCache, ServeEngine
    cfg, params = moe
    monkeypatch.setattr(
        PagedKVCache, "seq_lens_device",
        lambda self: sanitizer.device_view(self.seq_lens))
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8, page_size=8)
    with pytest.raises(sanitizer.DispatchRaceError,
                       match=r"seq_lens"):
        eng.generate(_requests(cfg))


def test_slot_cache_race_also_caught(moe, sanitize, monkeypatch):
    from repro.serving import ServeEngine
    from repro.serving.kv_cache import SlotKVCache
    cfg, params = moe
    monkeypatch.setattr(
        SlotKVCache, "seq_lens_device",
        lambda self: sanitizer.device_view(self.seq_lens))
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8, kv_layout="slot")
    with pytest.raises(sanitizer.DispatchRaceError, match=r"seq_lens"):
        eng.generate(_requests(cfg))
