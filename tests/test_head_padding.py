"""Exactness of the head-padding/duplication optimization (§Perf cell 1).

Padded configs must produce bit-comparable outputs: padded q slots are
killed by zero-masked wo rows, duplicated kv heads carry identical K/V,
real q heads are permuted into group-aligned slots.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import stats
from repro.configs import get_config, reduced
from repro.models import abstract_params, decode_step, forward, init_cache
from repro.models import param as pm
from repro.models.transformer import pad_attention_params

RNG = jax.random.PRNGKey(0)

# (arch, reduced head geometry) — covers GQA-pad-q, MHA-pad-both,
# GQA-dup-kv, MQA-dup-kv, already-aligned
CASES = [
    ("qwen2-7b", dict(n_heads=7, n_kv_heads=1, head_dim=16)),
    ("qwen1.5-4b", dict(n_heads=5, n_kv_heads=5, head_dim=16)),
    ("deepseek-67b", dict(n_heads=8, n_kv_heads=2, head_dim=16)),
    ("musicgen-medium", dict(n_heads=6, n_kv_heads=6, head_dim=16)),
    ("command-r-plus-104b", dict(n_heads=12, n_kv_heads=2, head_dim=16)),
    ("internvl2-2b", dict(n_heads=4, n_kv_heads=4, head_dim=16)),
]


def _cfgs(arch, red):
    cfg = dataclasses.replace(reduced(get_config(arch), n_layers=2, **red),
                              dtype="float32", head_pad_to=4)
    return cfg, dataclasses.replace(cfg, pad_heads=True)


def _batch(cfg, B=2, S=16):
    if cfg.frontend_stub:
        return {"embeds": jax.random.normal(RNG, (B, S, cfg.d_model),
                                            jnp.float32)}
    return {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch,red", CASES)
def test_forward_exact(arch, red):
    cfg, cfgp = _cfgs(arch, red)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          pm.init_params(abstract_params(cfg), RNG))
    padded = pad_attention_params(params, cfg, cfgp)
    b = _batch(cfg)
    err = float(jnp.max(jnp.abs(forward(params, cfg, b)
                                - forward(padded, cfgp, b))))
    assert err < 1e-4, (arch, err)


@pytest.mark.parametrize("arch,red", CASES[:3])
def test_decode_exact(arch, red):
    cfg, cfgp = _cfgs(arch, red)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          pm.init_params(abstract_params(cfg), RNG))
    padded = pad_attention_params(params, cfg, cfgp)
    B = 2
    c0, c1 = init_cache(cfg, B, 8), init_cache(cfgp, B, 8)
    toks = jax.random.randint(RNG, (B, 6), 0, cfg.vocab)
    for t in range(6):
        l0, c0 = decode_step(params, cfg, c0, toks[:, t: t + 1], jnp.int32(t))
        l1, c1 = decode_step(padded, cfgp, c1, toks[:, t: t + 1], jnp.int32(t))
        err = float(jnp.max(jnp.abs(l0 - l1)))
        assert err < 1e-4, (arch, t, err)


def test_padded_geometry():
    for arch, exp_h, exp_kv in [("qwen1.5-4b", 32, 32), ("qwen2-7b", 32, 16),
                                ("deepseek-67b", 64, 16),
                                ("musicgen-medium", 32, 32),
                                ("recurrentgemma-2b", 16, 16),
                                ("command-r-plus-104b", 96, 16)]:
        cfg = dataclasses.replace(get_config(arch), pad_heads=True)
        assert cfg.heads_eff == exp_h, (arch, cfg.heads_eff)
        assert cfg.kv_eff == exp_kv, (arch, cfg.kv_eff)
        assert cfg.heads_eff % cfg.kv_eff == 0
        mask = cfg.head_slot_mask()
        assert mask.sum() == cfg.n_heads


@pytest.mark.stats
def test_f8_kv_cache_decode_close():
    """f8 cache decode should track the fp32-cache decode closely.

    e4m3 carries 3 mantissa bits (~6% relative rounding per element), so
    after two layers the logit drift is bounded but not tiny — on a random
    tiny model the top-2 margin is often *smaller* than that drift, so
    exact argmax equality is only asserted on rows where the fp32 margin
    decisively exceeds the worst-case drift.  Instead of a hand-rolled
    "most rows agree" tolerance, overall argmax agreement over all
    (row, step) samples is an exact one-sided binomial claim with
    explicit alpha against a p_null=0.5 coin-flip null (chance agreement
    for a 128-way argmax is ~1/128, so the null is conservative).
    """
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b"), n_layers=2,
                                      vocab=128), dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="float8_e4m3fn")
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          pm.init_params(abstract_params(cfg), RNG))
    B, T = 8, 12
    c0, c1 = init_cache(cfg, B, T + 2), init_cache(cfg8, B, T + 2)
    assert jax.tree.leaves(c1)[0].dtype == jnp.float8_e4m3fn
    toks = jax.random.randint(RNG, (B, T), 0, cfg.vocab)
    agree, n_samples, snap = 0, 0, None
    for t in range(T):
        l0, c0 = decode_step(params, cfg, c0, toks[:, t: t + 1], jnp.int32(t))
        l1, c1 = decode_step(params, cfg8, c1, toks[:, t: t + 1],
                             jnp.int32(t))
        a, b = np.asarray(l0), np.asarray(l1)
        assert np.isfinite(b).all()
        agree += int((np.argmax(a, -1) == np.argmax(b, -1)).sum())
        n_samples += B
        if t == 5:          # e4m3 drift compounds with context length —
            snap = (a, b)   # the bounded-drift claim is pinned at step 6
    a, b = snap
    drift = float(np.max(np.abs(a - b)))
    assert drift < 1.5, drift
    for i in range(B):
        cos = float(np.dot(a[i], b[i])
                    / (np.linalg.norm(a[i]) * np.linalg.norm(b[i])))
        assert cos > 0.9, (i, cos)
        top2 = np.sort(a[i])[-2:]
        if top2[1] - top2[0] > 2 * drift:      # decisive margin
            assert int(np.argmax(a[i])) == int(np.argmax(b[i]))
    stats.assert_binom_fraction(agree, n_samples, p_null=0.5, alpha=1e-3,
                                what="f8 vs fp32 argmax agreement")
