"""Data pipeline, checkpointing, optimizer, sharding rules."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config, reduced
from repro.data.synthetic import SyntheticLM, batch_iterator, make_batch
from repro.distributed.sharding import logical_to_spec
from repro.optim import AdamWConfig, adamw_init, adamw_update, global_norm


# ---------------- data ----------------

def test_data_deterministic_and_resumable():
    lm = SyntheticLM(vocab=64, seed=3)
    b1 = make_batch(lm, 2, 16, step=5)
    b2 = make_batch(SyntheticLM(vocab=64, seed=3), 2, 16, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_steps_differ():
    lm = SyntheticLM(vocab=64, seed=3)
    assert not np.array_equal(make_batch(lm, 2, 16, 0)["tokens"],
                              make_batch(lm, 2, 16, 1)["tokens"])


def test_markov_structure_learnable_signal():
    """Markov successors restrict the next-token support (vs uniform)."""
    lm = SyntheticLM(vocab=256, seed=0, mix=1.0)
    toks = lm.sample(4, 512, 0)
    ok = 0
    for b in range(4):
        for t in range(511):
            if toks[b, t + 1] in lm.successors[toks[b, t]]:
                ok += 1
    assert ok / (4 * 511) > 0.95


def test_frontend_stub_batches():
    cfg = reduced(get_config("musicgen-medium"))
    lm = SyntheticLM(vocab=cfg.vocab, seed=1)
    b = make_batch(lm, 2, 8, 0, d_model=cfg.d_model, frontend_stub=True)
    assert b["embeds"].shape == (2, 8, cfg.d_model)
    assert b["embeds"].dtype == jnp.bfloat16


# ---------------- checkpoint ----------------

def _tree():
    import ml_dtypes
    return {"params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                       "b": np.ones(3, ml_dtypes.bfloat16)},
            "opt": {"step": np.int32(7)}}


def test_checkpoint_roundtrip_dtypes():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, _tree())
        step, tree = restore_checkpoint(d)
        assert step == 3
        np.testing.assert_array_equal(tree["params"]["w"],
                                      _tree()["params"]["w"])
        assert tree["params"]["b"].dtype == np.dtype("bfloat16")
        assert tree["opt"]["step"] == 7


def test_checkpoint_keep_last_k():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            save_checkpoint(d, s, _tree(), keep=3)
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
        assert steps == [3, 4, 5]
        assert latest_step(d) == 5


def test_checkpoint_ignores_partial_tmp():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        os.makedirs(os.path.join(d, "step_00000009.tmp"))  # crashed writer
        assert latest_step(d) == 1


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(4, _tree())
        ck.wait()
        assert latest_step(d) == 4


def test_restore_overwrite_same_step():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 2, _tree())
        _, tree = restore_checkpoint(d, 2)
        assert "params" in tree


# ---------------- optimizer ----------------

def test_adamw_descends_quadratic():
    p = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(p)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, opt, _ = adamw_update(p, g, opt, cfg)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.2


def test_adamw_clip():
    p = {"w": jnp.zeros(4)}
    opt = adamw_init(p)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(p, g, opt, AdamWConfig(clip_norm=1.0))
    assert m["grad_norm"] > 1e5  # reported pre-clip


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones(9)}
    np.testing.assert_allclose(float(global_norm(t)), np.sqrt(13.0),
                               rtol=1e-6)


# ---------------- sharding rules ----------------

class _FakeMesh:
    def __init__(self, axes):
        self.axis_names = tuple(axes)
        import numpy as _np
        self.devices = _np.empty(tuple(axes.values()))

    @property
    def shape(self):
        return dict(zip(self.axis_names, self.devices.shape))


def test_divisibility_fallback():
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    # with 1-sized axes everything divides; use rule resolution directly
    spec = logical_to_spec(("fsdp", "heads", "head_dim"), (64, 28, 128), mesh)
    assert len(spec) == 3


def test_sharding_spec_no_duplicate_axes():
    import jax
    mesh = jax.make_mesh((1,), ("model",), devices=jax.devices()[:1])
    # vocab and mlp both want "model": second must fall back to None
    spec = logical_to_spec(("vocab", "mlp"), (512, 512), mesh)
    flat = [s for s in spec if s is not None]
    assert len(set(flat)) == len(flat)
