"""The statistical harness itself: pinned special-function values and
calibration/discrimination sanity for the helpers every ``stats``-marked
suite builds on.  Reference numbers are standard χ²/binomial table
values (scipy agrees to the shown precision, but CI does not ship scipy
— stats.py is stdlib math on purpose).
"""
import numpy as np
import pytest

import stats

pytestmark = pytest.mark.stats


def test_chi2_sf_reference_values():
    assert stats.chi2_sf(0.0, 5) == 1.0
    assert stats.chi2_sf(-1.0, 5) == 1.0
    # classic critical values: P[X >= x] for df at alpha in {.05, .01}
    assert abs(stats.chi2_sf(3.841458820694124, 1) - 0.05) < 1e-9
    assert abs(stats.chi2_sf(11.070497693516351, 5) - 0.05) < 1e-9
    assert abs(stats.chi2_sf(6.634896601021213, 1) - 0.01) < 1e-9
    # both incomplete-gamma regimes (series x < s+1, continued fraction)
    assert abs(stats.chi2_sf(1.0, 10) - 0.9998278843700441) < 1e-12
    assert abs(stats.chi2_sf(40.0, 10) - 1.694474393006737e-05) < 1e-15
    # monotone in x, antitone in df direction of mass
    xs = [stats.chi2_sf(x, 4) for x in (0.5, 1.0, 2.0, 8.0, 20.0)]
    assert all(a > b for a, b in zip(xs, xs[1:]))


def test_binom_two_sided_exact_values():
    # most-likely outcome -> p = 1 (up to summation roundoff)
    assert abs(stats.binom_pvalue_two_sided(5, 10, 0.5) - 1.0) < 1e-12
    # extreme outcome: {0, 10} each 2^-10 -> exactly 2/1024
    assert abs(stats.binom_pvalue_two_sided(0, 10, 0.5) - 2 / 1024) < 1e-15
    # asymmetric null keeps exactness
    p = stats.binom_pvalue_two_sided(9, 10, 0.2)
    assert 0.0 < p < 1e-4
    # degenerate nulls
    assert stats.binom_pvalue_two_sided(0, 7, 0.0) == 1.0
    assert stats.binom_pvalue_two_sided(3, 7, 0.0) == 0.0
    assert stats.binom_pvalue_two_sided(7, 7, 1.0) == 1.0


def test_binom_sf_exact_values():
    assert abs(stats.binom_sf(0, 10, 0.5) - 1.0) < 1e-12
    assert abs(stats.binom_sf(10, 10, 0.5) - 1 / 1024) < 1e-15
    # complement identity: P[X >= k] + P[X <= k-1] == 1
    total = stats.binom_sf(4, 12, 0.3) + sum(
        np.exp(stats._binom_logpmf(12)[i] + i * np.log(0.3)
               + (12 - i) * np.log(0.7)) for i in range(4))
    assert abs(total - 1.0) < 1e-12


def test_chi2_gof_calibration_and_power():
    rs = np.random.RandomState(11)
    probs = np.array([0.5, 0.25, 0.125, 0.0625, 0.0625])
    counts = rs.multinomial(2000, probs)
    stats.assert_matches_probs(counts, probs, alpha=1e-3)
    # a clearly different distribution must be rejected at the same n
    skew = rs.multinomial(2000, probs[::-1])
    _, _, p = stats.chi2_gof(skew, probs)
    assert p < 1e-6


def test_chi2_homogeneity_calibration_and_power():
    rs = np.random.RandomState(7)
    probs = rs.dirichlet(np.ones(32))
    a = rs.multinomial(1500, probs)
    b = rs.multinomial(1500, probs)
    stats.assert_same_distribution(a, b, alpha=1e-3, what="same source")
    other = rs.dirichlet(np.ones(32))
    c = rs.multinomial(1500, other)
    _, _, p = stats.chi2_homogeneity(a, c)
    assert p < 1e-6
    with pytest.raises(AssertionError, match="alpha"):
        stats.assert_same_distribution(a, c, alpha=1e-3)


def test_small_expected_bins_are_merged():
    # 100 samples over 64 bins: raw expected ~1.5/bin would wreck the
    # asymptotics; merging must keep df well below bins-1 and the test
    # calibrated
    rs = np.random.RandomState(3)
    probs = rs.dirichlet(np.ones(64) * 0.3)
    a = rs.multinomial(100, probs)
    b = rs.multinomial(100, probs)
    stat, df, p = stats.chi2_homogeneity(a, b)
    assert 1 <= df < 63
    assert p >= 1e-3
    # GOF path merges too
    stat, df, p = stats.chi2_gof(a, probs)
    assert 1 <= df < 63


def test_assert_binom_fraction():
    # 950/1000 agreements is overwhelmingly above a coin-flip null
    stats.assert_binom_fraction(950, 1000, p_null=0.5, alpha=1e-6,
                                what="f8 argmax agreement")
    with pytest.raises(AssertionError, match="p_null"):
        stats.assert_binom_fraction(510, 1000, p_null=0.5, alpha=1e-3)
