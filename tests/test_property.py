"""Hypothesis property tests on system invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (see requirements.txt)
pytestmark = pytest.mark.stress
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clustering import agglomerative_to_count
from repro.core.robustness import kurtosis
from repro.core.similarity import coactivation_counts, router_distance
from repro.core.unstructured import mask_per_output, nm_rounding
from repro.models.ssm import linear_recurrence_chunked
from repro.optim.compress import compress_decompress, compression_init

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(2, 16), st.integers(1, 16), st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_clustering_is_partition(E, n_keep_raw, seed):
    n_keep = min(n_keep_raw, E)
    W = np.random.RandomState(seed).randn(E, 8)
    labels = agglomerative_to_count(router_distance(W), n_keep)
    assert labels.shape == (E,)
    assert labels.min() == 0
    assert labels.max() + 1 == n_keep
    assert set(labels.tolist()) == set(range(n_keep))


@given(st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_clustering_permutation_equivariant(seed):
    rs = np.random.RandomState(seed)
    W = rs.randn(8, 8)
    perm = rs.permutation(8)
    l1 = agglomerative_to_count(router_distance(W), 3)
    l2 = agglomerative_to_count(router_distance(W[perm]), 3)
    # partitions must match under the permutation
    part1 = {frozenset(np.where(l1 == c)[0].tolist()) for c in range(3)}
    part2 = {frozenset(perm[np.where(l2 == c)[0]].tolist()) for c in range(3)}
    assert part1 == part2


@given(st.integers(1, 64), st.integers(1, 8),
       st.floats(0.0, 0.95), st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_mask_sparsity_invariant(K, N, sparsity, seed):
    s = np.random.RandomState(seed).rand(K, N).astype(np.float32)
    m = mask_per_output(s, sparsity, 0)
    want_pruned = int(np.floor(sparsity * K))
    assert ((~m).sum(axis=0) == want_pruned).all()


@given(st.integers(4, 64), st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_nm_never_exceeds_n_per_group(K, seed):
    s = np.random.RandomState(seed).rand(K, 4).astype(np.float32)
    m = nm_rounding(s, in_axis=0, n=2, m=4)
    pad = (-K) % 4
    grp = np.pad(m, ((0, pad), (0, 0))).reshape(-1, 4, 4)
    assert (grp.sum(axis=1) <= 2).all()


@given(st.integers(10, 1000), st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_kurtosis_gaussian_near_3(n, seed):
    x = np.random.RandomState(seed).randn(n * 100)
    k = kurtosis(x)
    assert 2.0 < k < 4.5


@given(st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_kurtosis_zero_exclusion(seed):
    x = np.random.RandomState(seed).randn(5000)
    mask = np.abs(x) > np.quantile(np.abs(x), 0.5)
    pruned = x * mask
    # surviving weights are bimodal -> kurtosis below gaussian
    assert kurtosis(pruned, exclude_zeros=True) < kurtosis(x)


@given(st.integers(1, 6), st.integers(2, 8), st.integers(0, 10 ** 6))
@settings(**SETTINGS)
def test_coactivation_symmetry_and_bounds(T, k_raw, seed):
    E = 8
    k = min(k_raw, E)
    rs = np.random.RandomState(seed)
    top = np.stack([rs.choice(E, k, replace=False) for _ in range(T)])
    a = coactivation_counts(top, E)
    assert np.allclose(a, a.T)
    assert (np.diag(a) == 0).all()
    assert a.max() <= T


@given(st.integers(2, 64), st.integers(1, 4), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_linear_recurrence_matches_sequential(S, B, seed):
    rs = np.random.RandomState(seed)
    a = 1 / (1 + np.exp(-rs.randn(B, S, 4).astype(np.float32)))
    b = rs.randn(B, S, 4).astype(np.float32)
    chunk = max(1, S // 3)
    h, _ = linear_recurrence_chunked(jnp.asarray(a), jnp.asarray(b),
                                     jnp.zeros((B, 4)), chunk)
    hh = np.zeros((B, 4), np.float32)
    for t in range(S):
        hh = a[:, t] * hh + b[:, t]
    np.testing.assert_allclose(np.asarray(h[:, -1]), hh, atol=1e-4)


@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_compression_error_feedback_converges(seed):
    """Long-run sum of dequantized grads tracks the true sum (unbiasedness
    via error feedback)."""
    rs = np.random.RandomState(seed)
    g_true = jnp.asarray(rs.randn(32).astype(np.float32))
    err = {"w": jnp.zeros(32)}
    total = jnp.zeros(32)
    for _ in range(20):
        deq, err_new = compress_decompress({"w": g_true}, err)
        err = err_new
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / 20), np.asarray(g_true),
                               atol=0.05)
