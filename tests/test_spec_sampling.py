"""Distribution-equivalence oracle for speculative sampling (ISSUE 8).

Rejection-sampling verification (Leviathan accept/resample, SpecInfer
multi-round roots for trees) is *exactly* distribution-preserving: for
any drafter, the spec-served token stream must follow the same law as
plain temperature sampling from the dense model.  That claim cannot be
pinned token-by-token (acceptance consumes randomness differently), so
it is pinned statistically:

  * per-position next-token histograms over many identical-prompt
    requests, spec vs plain, must pass a χ² homogeneity test at an
    explicit ``alpha`` — across chain and tree drafts, all four drafter
    flavors (perturbed dense, expert-mask, weight-mask, packed sparse),
    and both schedules;
  * a deliberately-biased accept rule (force-accept every draft) must
    FAIL the same oracle — otherwise the harness has no power and the
    equivalence tests above are vacuous.

Plain and spec engines use DIFFERENT base seeds: χ² homogeneity assumes
independent samples, and with equal seeds the identity-drafter case
would be token-identical (dependence, not evidence).  The M requests
share one prompt but have distinct request ids, so their streams are
independent draws from the same per-position marginal.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import stats
from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import Request, ServeEngine, speculative

pytestmark = pytest.mark.stats

ALPHA = 1e-3     # per-position significance for every equivalence claim
TEMP = 0.7
MAX_NEW = 4      # positions tested per run
N_REQ = 288      # identical-prompt requests per histogram (36 waves of 8)


def _tiny_moe(seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


@pytest.fixture(scope="module")
def drafters(moe):
    """Engine kwargs for each drafter flavor of the oracle matrix."""
    from repro import sparse
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0
    dense = jax.tree.map(lambda x: x + 0.05 * jnp.ones_like(x), params)
    batches = calibration_batches(cfg, n_batches=2)
    _, wmasks, _ = unstructured_only(params, cfg, batches,
                                     target_sparsity=0.5, method="wanda")
    _, omasks, _ = unstructured_only(params, cfg, batches,
                                     target_sparsity=0.3, method="owl")
    plan = sparse.plan_sparse_ffn(omasks,
                                  sparse.ffn_weights_from_params(params, cfg),
                                  block=(8, 8), target_block_sparsity=0.2)
    packed, _ = sparse.pack_sparse_ffn(params, cfg, plan)
    base_masks = dict(omasks)
    base_masks.update(plan.element_masks())
    return {
        "dense": dict(draft_params=dense),
        "expert-mask": dict(expert_mask=mask),
        "weight-mask": dict(weight_masks=wmasks),
        "sparse": dict(weight_masks=base_masks, sparse_weights=packed),
    }


def _histograms(params, cfg, prompt, *, seed, schedule="interleaved", **kw):
    """Serve N_REQ identical-prompt sampled requests; bin next-token
    counts per position.  Returns [MAX_NEW, vocab] int64."""
    eng = ServeEngine(params, cfg, max_len=16, max_batch=8,
                      prefill_chunk=8, page_size=8, seed=seed,
                      schedule=schedule, **kw)
    outs = eng.generate([Request(prompt, MAX_NEW, temperature=TEMP)
                         for _ in range(N_REQ)])
    hist = np.zeros((MAX_NEW, cfg.vocab), np.int64)
    for out in outs:
        assert len(out) == MAX_NEW
        for pos, tok in enumerate(out):
            hist[pos, int(tok)] += 1
    return hist


_PLAIN_CACHE = {}


def _plain_histograms(moe, prompt, prompt_seed, schedule):
    key = (prompt_seed, schedule)
    if key not in _PLAIN_CACHE:
        cfg, params = moe
        _PLAIN_CACHE[key] = _histograms(params, cfg, prompt, seed=100,
                                        schedule=schedule)
    return _PLAIN_CACHE[key]


def _assert_positions_match(plain, spec, what):
    for pos in range(MAX_NEW):
        stats.assert_same_distribution(
            plain[pos], spec[pos], alpha=ALPHA,
            what=f"{what} @ position {pos} (n={N_REQ}/engine)")


def test_spec_chain_sampling_matches_plain(moe, drafters, seeded_tokens):
    """Fast fixed-seed oracle: chain drafts with the expert-mask drafter
    under the interleaved schedule vs plain sampling."""
    cfg, params = moe
    prompt = seeded_tokens(0, 6, cfg.vocab)
    plain = _plain_histograms(moe, prompt, 0, "interleaved")
    spec = _histograms(params, cfg, prompt, seed=101,
                       spec_decode="pruned", spec_k=3,
                       **drafters["expert-mask"])
    _assert_positions_match(plain, spec, "chain/expert-mask")


def test_spec_tree_sampling_matches_plain(moe, drafters, seeded_tokens):
    """Fast fixed-seed oracle: 2-branch tree drafts with the perturbed
    dense drafter — multi-round root rejection + winner compaction must
    keep the served distribution pinned."""
    cfg, params = moe
    prompt = seeded_tokens(0, 6, cfg.vocab)
    plain = _plain_histograms(moe, prompt, 0, "interleaved")
    spec = _histograms(params, cfg, prompt, seed=102,
                       spec_decode="pruned", spec_k=3, spec_tree=2,
                       **drafters["dense"])
    _assert_positions_match(plain, spec, "tree/dense")


@pytest.mark.stress
@pytest.mark.parametrize("schedule", ["interleaved", "blocking"])
@pytest.mark.parametrize("drafter",
                         ["dense", "expert-mask", "weight-mask", "sparse"])
def test_spec_sampling_matrix(moe, drafters, seeded_tokens, drafter,
                              schedule):
    """Wide oracle matrix: {chain, tree} x every drafter flavor x both
    schedules.  REPRO_STATS_WIDE=1 (set by the CI stress job) widens the
    prompt-seed axis."""
    cfg, params = moe
    wide = os.environ.get("REPRO_STATS_WIDE", "0") == "1"
    prompt_seeds = (0, 1) if wide else (0,)
    for prompt_seed in prompt_seeds:
        prompt = seeded_tokens(prompt_seed, 6, cfg.vocab)
        plain = _plain_histograms(moe, prompt, prompt_seed, schedule)
        for label, tree_kw in (("chain", {}), ("tree", dict(spec_tree=2))):
            seed = 103 + prompt_seed
            spec = _histograms(params, cfg, prompt, seed=seed,
                               schedule=schedule, spec_decode="pruned",
                               spec_k=3, **tree_kw, **drafters[drafter])
            _assert_positions_match(
                plain, spec,
                f"{label}/{drafter}/{schedule}/prompt{prompt_seed}")


def test_biased_accept_rule_fails_oracle(moe, seeded_tokens, monkeypatch):
    """Discrimination power: force-accepting every draft token (the
    classic broken 'speculative sampling' that silently serves the
    drafter's distribution) MUST fail the same χ² oracle the equivalence
    tests pass.  ``accept_block`` is a module-global looked up at trace
    time precisely so this patch lands inside the jitted verify."""
    cfg, params = moe
    prompt = seeded_tokens(0, 6, cfg.vocab)
    plain = _plain_histograms(moe, prompt, 0, "interleaved")

    real = speculative.accept_block

    def always_accept(logits, block, draft_logits, temps, base_key, rids,
                      counts, n_branches, k, vocab):
        winner, accept, next_tok = real(logits, block, draft_logits, temps,
                                        base_key, rids, counts, n_branches,
                                        k, vocab)
        accept = jnp.where(temps > 0.0, jnp.full_like(accept, k), accept)
        return winner, accept, next_tok

    monkeypatch.setattr(speculative, "accept_block", always_accept)
    # a strongly-perturbed drafter, k=MAX_NEW so every served position is
    # a force-accepted draft proposal (drafter law, not dense law)
    draft = jax.tree.map(lambda x: x + 0.25 * jnp.ones_like(x), params)
    biased = _histograms(params, cfg, prompt, seed=104,
                         spec_decode="pruned", spec_k=MAX_NEW,
                         draft_params=draft)
    pvals = [stats.chi2_homogeneity(plain[pos], biased[pos])[2]
             for pos in range(MAX_NEW)]
    assert min(pvals) < ALPHA, (
        f"biased accept rule was NOT detected (p-values {pvals}) — the "
        f"equivalence oracle has no power at n={N_REQ}, alpha={ALPHA}")
