"""Cancellation + latency-accounting coverage.

Fake-clock unit tests pin the two accounting fixes:

  * **spec-mode TPOT amortization** — ``on_tokens`` used to stamp every
    token of a verified block with one shared ``now``, recording
    zero-length intra-block gaps and deflating spec-mode p50/p95 TPOT;
    the block's wall interval is now amortized across the tokens it
    delivers.  The regression test replays the OLD stamping and shows it
    fails the no-zero-gaps assertion the new path satisfies.
  * **TTFT windowing** — first-token latency used to enter the
    percentile window only at request *completion*; it is now recorded
    at first-token time, so in-flight requests are visible to p95 TTFT.

Cancel coverage: scheduler-stage units (pending / prefilling / active /
finished / unknown), engine release-path units (lane + page bookkeeping
restored, late token delivery fails loudly), and a randomized
cancel-under-stress suite (mid-prefill, mid-decode, mid-spec-block,
already-finished) under the dispatch-race sanitizer asserting zero
page/refcount leaks and that surviving lanes' token streams are
unchanged versus a no-cancel twin engine (per-request PRNG key chains
make both greedy and sampled streams batch-composition-invariant, so
the twin comparison is exact).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import (PagedKVCache, Request, Scheduler, SchedulerError,
                           ServeEngine)


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


@pytest.fixture
def sanitized():
    """Run under the dispatch-race sanitizer (REPRO_SANITIZE=1
    equivalent)."""
    sanitizer.enable(True)
    try:
        yield
    finally:
        sanitizer.clear_override()


def _active_request(max_new_tokens=16, eos_id=None):
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1, 2], np.int32),
                               max_new_tokens=max_new_tokens,
                               eos_id=eos_id), now=0.0)
    sched.admit(slot=0)
    sched.activate(rid)
    return sched, rid


# ---------------------------------------------------------------------------
# spec-block TPOT amortization (fake clock)
# ---------------------------------------------------------------------------


def test_spec_block_gaps_amortized_over_wall_interval():
    """A 4-token verified block landing 2.0s after the previous token
    records four 0.5s gaps — the per-token pace a client draining the
    stream sees — not one 2.0s gap and three zeros."""
    sched, rid = _active_request()
    sched.on_token(rid, 7, now=1.0)
    consumed, done = sched.on_tokens(rid, [3, 4, 5, 6], now=3.0)
    assert (consumed, done) == (4, False)
    st = sched.active[rid]
    np.testing.assert_allclose(st.itl, [0.5, 0.5, 0.5, 0.5])
    np.testing.assert_allclose(list(sched._itl), [0.5, 0.5, 0.5, 0.5])
    assert st.t_last_token == pytest.approx(3.0)   # last token lands at now


def test_spec_block_regression_old_stamping_fails():
    """The pre-fix accounting — every block token stamped with the same
    ``now`` — produces zero-length intra-block gaps, which the
    amortized path must never record.  Replaying the old behavior shows
    the assertion it fails."""
    # old behavior: one shared timestamp per block token
    old, rid_o = _active_request()
    old.on_token(rid_o, 7, now=1.0)
    for tok in (3, 4, 5, 6):
        old.on_token(rid_o, tok, now=3.0)          # what on_tokens used to do
    old_gaps = np.asarray(old._itl)
    assert np.percentile(old_gaps, 50) == 0.0      # deflated: p50 TPOT = 0
    assert (old_gaps == 0.0).sum() == 3

    # fixed path over the identical delivery: no artificial zero gaps
    new, rid_n = _active_request()
    new.on_token(rid_n, 7, now=1.0)
    new.on_tokens(rid_n, [3, 4, 5, 6], now=3.0)
    new_gaps = np.asarray(new._itl)
    assert new_gaps.min() > 0.0
    assert np.percentile(new_gaps, 50) == pytest.approx(0.5)
    # both accountings agree on the total wall interval
    assert old_gaps.sum() == pytest.approx(new_gaps.sum())


def test_spec_block_amortizes_over_delivered_not_block_width():
    """EOS inside the block: the wall interval divides across the tokens
    actually delivered (2), not the block's full width (4)."""
    sched, rid = _active_request(eos_id=9)
    sched.on_token(rid, 7, now=1.0)
    consumed, done = sched.on_tokens(rid, [3, 9, 5, 6], now=2.0)
    assert (consumed, done) == (2, True)
    st = sched.finished[rid]
    np.testing.assert_allclose(st.itl, [0.5, 0.5])
    assert st.t_done == pytest.approx(2.0)


def test_spec_block_max_new_tokens_mid_block():
    sched, rid = _active_request(max_new_tokens=3)
    sched.on_token(rid, 7, now=1.0)
    consumed, done = sched.on_tokens(rid, [3, 4, 5, 6], now=2.0)
    assert (consumed, done) == (2, True)
    np.testing.assert_allclose(sched.finished[rid].itl, [0.5, 0.5])


def test_first_delivery_block_stamps_at_now():
    """A request whose FIRST delivery is a block (fully-prefix-cached
    prompt in spec mode) has no previous boundary: all tokens stamp at
    ``now`` — TTFT is exact, and that one block records zero gaps."""
    sched, rid = _active_request()
    consumed, done = sched.on_tokens(rid, [3, 4, 5], now=2.0)
    assert (consumed, done) == (3, False)
    st = sched.active[rid]
    assert st.t_first_token == pytest.approx(2.0)
    np.testing.assert_allclose(st.itl, [0.0, 0.0])
    assert sched.latencies()["p50_first_token_s"] == pytest.approx(2.0)


def test_on_tokens_empty_and_bad_rid():
    sched, rid = _active_request()
    assert sched.on_tokens(rid, [], now=1.0) == (0, False)
    with pytest.raises(SchedulerError, match="unknown"):
        sched.on_tokens(rid + 999, [1, 2], now=1.0)


# ---------------------------------------------------------------------------
# TTFT windowing (fake clock)
# ---------------------------------------------------------------------------


def test_ttft_recorded_at_first_token_not_completion():
    """An in-flight request's TTFT is visible in the window immediately,
    before it completes — exactly what an open-loop bench saturating
    the engine needs for honest p95 TTFT."""
    sched, rid = _active_request(max_new_tokens=16)
    sched.on_token(rid, 7, now=1.25)
    lat = sched.latencies()
    assert lat["p50_first_token_s"] == pytest.approx(1.25)
    assert lat["p95_first_token_s"] == pytest.approx(1.25)
    assert "p50_latency_s" not in lat        # nothing completed yet
    assert rid in sched.active


def test_per_request_itl_trace_matches_window():
    sched, rid = _active_request()
    for t in (1.0, 1.5, 3.5, 3.6):
        sched.on_token(rid, 7, now=t)
    st = sched.active[rid]
    np.testing.assert_allclose(st.itl, [0.5, 2.0, 0.1])
    np.testing.assert_allclose(list(sched._itl), st.itl)


def test_omitted_now_defaults_to_monotonic_not_epoch():
    """The old ``now: float = 0.0`` default recorded latencies against
    t=0 — a caller omitting ``now`` saw TTFTs of ~monotonic() seconds.
    Omitted timestamps now mean time.monotonic()."""
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1], np.int32), max_new_tokens=2))
    st = sched.pending[0]
    assert abs(st.t_submit - time.monotonic()) < 60.0
    sched.admit(slot=0)
    sched.activate(rid)
    sched.on_token(rid, 7)
    ttft = sched.latencies()["p50_first_token_s"]
    assert 0.0 <= ttft < 60.0                # epoch bug: would be ~1e4s


# ---------------------------------------------------------------------------
# scheduler cancel stages
# ---------------------------------------------------------------------------


def test_scheduler_cancel_stages():
    sched = Scheduler()
    rids = [sched.submit(Request(np.array([1, 2], np.int32), 4), now=0.0)
            for _ in range(3)]
    # pending
    stage, st = sched.cancel(rids[1])
    assert (stage, st.rid, st.canceled) == ("pending", rids[1], True)
    assert [s.rid for s in sched.pending] == [rids[0], rids[2]]
    # prefilling
    sched.admit(slot=0)
    stage, st = sched.cancel(rids[0])
    assert (stage, st.rid) == ("prefilling", rids[0])
    assert not sched.has_prefilling
    # active
    sched.admit(slot=1)
    sched.activate(rids[2])
    stage, st = sched.cancel(rids[2])
    assert (stage, st.rid) == ("active", rids[2])
    assert not sched.has_active
    # unknown / double-cancel
    assert sched.cancel(rids[2]) == (None, None)
    assert sched.cancel(999) == (None, None)


def test_scheduler_cancel_never_destroys_finished():
    sched, rid = _active_request(max_new_tokens=1)
    sched.on_token(rid, 7, now=1.0)
    assert sched.cancel(rid) == (None, None)
    assert sched.result(rid).tolist() == [7]


def test_token_after_cancel_raises():
    sched, rid = _active_request()
    sched.on_token(rid, 7, now=1.0)
    sched.cancel(rid)
    with pytest.raises(SchedulerError, match="unknown"):
        sched.on_token(rid, 8, now=2.0)


def test_state_lookup_across_stages():
    sched = Scheduler()
    rid = sched.submit(Request(np.array([1, 2], np.int32), 1), now=0.0)
    assert sched.state(rid).rid == rid           # pending
    sched.admit(slot=0)
    assert sched.state(rid).slot == 0            # prefilling
    sched.activate(rid)
    st = sched.state(rid)                        # active
    sched.on_token(rid, 7, now=1.0)
    assert sched.state(rid) is st and st.done    # finished, same object
    sched.result(rid)
    assert sched.state(rid) is None


# ---------------------------------------------------------------------------
# engine cancel: release-path units
# ---------------------------------------------------------------------------


def _leak_check(cache: PagedKVCache):
    """Every page is either free or accounted by a refcount; every lane
    is free once nothing is in flight."""
    assert len(cache._free_pages) + len(cache._refs) == cache.page_budget
    assert sorted(cache._free_slots) == list(range(cache.n_slots))
    assert not cache._pages_of and not cache._prefilling


def _drive_with_cancels(eng, reqs, cancel_at):
    """Step the engine to drain, canceling rid r before step i for every
    (i, r) in ``cancel_at``.  Returns {rid: tokens} for survivors."""
    rids = [eng.submit(Request(r.prompt.copy(), r.max_new_tokens,
                               eos_id=r.eos_id, temperature=r.temperature))
            for r in reqs]
    canceled = set()
    step_i = 0
    while eng.busy:
        for i, ridx in cancel_at:
            if i == step_i:
                if eng.cancel(rids[ridx]):
                    canceled.add(ridx)
        eng.step()
        step_i += 1
        assert step_i < 10_000
    return {i: eng.scheduler.result(rid).tolist()
            for i, rid in enumerate(rids)
            if i not in canceled and rid in eng.scheduler.finished}


def test_engine_cancel_mid_prefill_releases_everything(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=64, max_batch=2, prefill_chunk=8,
                      schedule="interleaved")
    prompt = np.arange(1, 33, dtype=np.int32)    # 4 chunks: stays mid-prefill
    rid = eng.submit(Request(prompt, 4))
    eng.step()                                   # admit + first chunk only
    assert rid in eng.scheduler.prefilling
    assert eng.cancel(rid) and eng.requests_canceled == 1
    assert not eng.busy
    _leak_check(eng.cache)
    assert rid not in eng._prefills


def test_engine_cancel_mid_decode_frees_lane_for_waiter(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=1, prefill_chunk=8)
    r1 = eng.submit(Request(np.array([1, 2, 3], np.int32), 8))
    r2 = eng.submit(Request(np.array([4, 5, 6], np.int32), 4))
    while r1 not in eng.scheduler.active:
        eng.step()
    assert eng.cancel(r1)                        # the only lane frees
    while eng.busy:
        eng.step()
    assert len(eng.scheduler.result(r2)) == 4    # waiter got the lane
    _leak_check(eng.cache)


def test_engine_cancel_finished_and_unknown_return_false(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=1, prefill_chunk=8)
    rid = eng.submit(Request(np.array([1, 2], np.int32), 2))
    eng.run()
    assert not eng.cancel(rid)                   # finished: tokens are ours
    assert not eng.cancel(rid + 1)               # unknown
    assert eng.requests_canceled == 0
    assert len(eng.scheduler.result(rid)) == 2


def test_engine_cancel_pending_only_dequeues(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=1, prefill_chunk=8)
    r1 = eng.submit(Request(np.array([1, 2], np.int32), 2))
    r2 = eng.submit(Request(np.array([3, 4], np.int32), 2))
    assert eng.cancel(r2)                        # never admitted
    eng.run()
    assert len(eng.scheduler.result(r1)) == 2
    _leak_check(eng.cache)


# ---------------------------------------------------------------------------
# randomized cancel-under-stress: sanitizer on, no-cancel twin oracle
# ---------------------------------------------------------------------------


def _stress_reqs(cfg, rs, n):
    reqs = []
    for i in range(n):
        prompt = rs.randint(0, cfg.vocab, rs.randint(3, 20)).astype(np.int32)
        temp = 0.7 if i % 3 == 0 else 0.0        # mix sampled + greedy lanes
        reqs.append(Request(prompt, int(rs.randint(3, 10)),
                            temperature=temp))
    return reqs


@pytest.mark.stress
@pytest.mark.parametrize("engine_kwargs", [
    {},                                                    # plain paged
    {"schedule": "blocking"},
    {"spec_decode": "pruned", "spec_k": 3},                # mid-spec-block
    {"prefix_cache": True},                                # shared pages
], ids=["interleaved", "blocking", "spec", "prefix_cache"])
def test_cancel_stress_no_leaks_survivors_unchanged(moe, sanitized,
                                                    engine_kwargs):
    """Random cancels at every lifecycle stage (pending, mid-prefill,
    mid-decode, mid-spec-block, already-finished), sanitizer on: the
    cache must end leak-free (pages + refcounts restored, lanes free)
    and every surviving request's token stream must equal the no-cancel
    twin's — cancellation must not perturb batchmates."""
    cfg, params = moe

    def mk():
        return ServeEngine(params, cfg, max_len=48, max_batch=3,
                           prefill_chunk=8, page_size=8, **engine_kwargs)

    for trial in range(3):
        rs = np.random.RandomState(100 + trial)
        reqs = _stress_reqs(cfg, rs, n=8)
        # twin: same requests, no cancels — the survivors' oracle
        twin = _drive_with_cancels(mk(), reqs, cancel_at=[])
        assert len(twin) == len(reqs)
        # random (step, request) cancel points; duplicates exercise the
        # already-canceled/already-finished paths
        cancel_at = [(int(rs.randint(0, 25)), int(rs.randint(0, len(reqs))))
                     for _ in range(4)]
        eng = mk()
        got = _drive_with_cancels(eng, reqs, cancel_at)
        for i, toks in got.items():
            assert toks == twin[i], \
                f"trial {trial}: survivor {i} diverged after cancels"
        _leak_check(eng.cache)
        if eng.prefix_cache is not None:
            # trie-held pages are exactly the refcounted remainder
            assert all(eng.cache.refcount(p) >= 1
                       for p in eng.prefix_cache.pages())


@pytest.mark.stress
def test_cancel_mid_spec_block_deterministic(moe, sanitized):
    """Cancel an active request right after a spec round delivered part
    of its block — the lane releases between rounds with zero leaks and
    the batchmate's stream is untouched."""
    cfg, params = moe
    reqs = [Request(np.arange(1, 6, dtype=np.int32), 12),
            Request(np.arange(6, 11, dtype=np.int32), 12)]

    def mk():
        return ServeEngine(params, cfg, max_len=48, max_batch=2,
                           prefill_chunk=8, page_size=8,
                           spec_decode="pruned", spec_k=4)

    twin = _drive_with_cancels(mk(), reqs, cancel_at=[])
    eng = mk()
    rids = [eng.submit(Request(r.prompt.copy(), r.max_new_tokens))
            for r in reqs]
    # step until the first request has consumed a partial block
    while not eng.scheduler.state(rids[0]).tokens:
        eng.step()
    assert rids[0] in eng.scheduler.active
    assert eng.cancel(rids[0])
    while eng.busy:
        eng.step()
    assert eng.scheduler.result(rids[1]).tolist() == twin[1]
    _leak_check(eng.cache)
