"""Prefix caching: radix-tree KV reuse on the paged cache.

Three layers of coverage for ``serving/prefix_cache.py`` + the
``PagedKVCache`` refcount/COW machinery behind it:

  * **Trie units** on a fake pool — longest page-aligned prefix match,
    refcount lifecycle, LRU eviction order (leaf-first, lane-referenced
    pages skipped), the ``max_pages`` cap — no engine, no device arrays.
  * **Pool units** on a real ``PagedKVCache`` — copy-on-write fork
    bookkeeping (the fork is private: never in the trie, invisible to
    sibling lanes), eviction under pool pressure, the shortfall rollback
    path, per-slot device-snapshot caching, and the degenerate
    ``page_budget=0`` gauges.
  * **Engine oracle + stress** — prefix-cache-on output streams must be
    token-identical to cache-off across {blocking, interleaved} × spec
    on/off × expert/weight masks on randomized shared-prefix workloads
    (including a warm second wave, where full hits take the zero-prefill
    replay path); a discrimination test proves a repeat prompt costs
    ZERO prefill dispatches while the cache-off twin re-prefills; and a
    randomized stress driver asserts the refcount invariant
    (``refcount(p) == referencing lane tables + trie entries``) after
    every step, under the dispatch-race sanitizer.

The stock ``test_paged_serving._check_invariants`` is deliberately NOT
used here: its "no page owned by two lanes" assertion is exactly what
prefix sharing relaxes.  ``_check_prefix_invariants`` below is the
sharing-aware replacement (and is strictly stronger on refcounts).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import PagedKVCache, PrefixCache, Request, ServeEngine


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


@pytest.fixture
def sanitized():
    """Run a test under the dispatch-race sanitizer (REPRO_SANITIZE=1
    equivalent): zero-copy aliasing of a guarded buffer into a device
    view + a later mutation becomes a deterministic error."""
    sanitizer.enable(True)
    try:
        yield
    finally:
        sanitizer.clear_override()


# ---------------------------------------------------------------------------
# trie units (fake pool — no engine, no device arrays)
# ---------------------------------------------------------------------------


class FakePool:
    """Duck-typed pool: refcounts + a log of pages freed (refcount 0)."""

    def __init__(self):
        self.refs = {}
        self.freed = []

    def retain_page(self, p):
        self.refs[p] = self.refs.get(p, 0) + 1

    def release_page(self, p):
        n = self.refs[p]
        if n == 1:
            del self.refs[p]
            self.freed.append(p)
        else:
            self.refs[p] = n - 1


    def refcount(self, p):
        return self.refs.get(p, 0)


def _toks(*ints):
    return np.asarray(ints, np.int32)


def test_match_longest_page_aligned_prefix():
    pool = FakePool()
    pc = PrefixCache(pool, page_size=4)
    prompt = _toks(1, 2, 3, 4, 5, 6, 7, 8, 9, 10)   # 2 full pages + 2 tail
    assert pc.insert(prompt, pages=[11, 12]) == 2   # tail page never cached
    assert pc.n_nodes == 2

    # full prompt: both pages; the partial tail is not matchable
    assert pc.match(prompt) == (8, [11, 12])
    # 6 tokens: only the first full page
    assert pc.match(prompt[:6]) == (4, [11])
    # divergence inside the second chunk: first page only
    assert pc.match(_toks(1, 2, 3, 4, 5, 6, 99, 8)) == (4, [11])
    # divergence inside the first chunk: miss
    assert pc.match(_toks(9, 2, 3, 4)) == (0, [])
    # longer prompt sharing the cached prefix: same two pages
    longer = np.concatenate([prompt[:8], _toks(20, 21, 22, 23)])
    assert pc.match(longer) == (8, [11, 12])
    # sub-page prompts can never match
    assert pc.match(_toks(1, 2, 3)) == (0, [])


def test_insert_refcount_lifecycle_and_idempotence():
    pool = FakePool()
    pc = PrefixCache(pool, page_size=2)
    a = _toks(1, 2, 3, 4)
    assert pc.insert(a, pages=[5, 6]) == 2
    assert pool.refcount(5) == 1 and pool.refcount(6) == 1

    # re-inserting the same prompt (a concurrent identical admission)
    # touches, never replaces: the latecomer's pages stay private
    assert pc.insert(a, pages=[7, 8]) == 0
    assert pc.match(a) == (4, [5, 6])
    assert pool.refcount(7) == 0 and pool.refcount(8) == 0

    # extending the prompt adds only the new suffix nodes
    ab = _toks(1, 2, 3, 4, 9, 10)
    assert pc.insert(ab, pages=[5, 6, 11]) == 1
    assert pc.match(ab) == (6, [5, 6, 11])
    assert pc.n_nodes == 3 and pool.refcount(11) == 1

    # eviction releases trie references; refcount 0 pages are freed
    assert pc.evict(3) == 3
    assert pc.n_nodes == 0 and pool.refs == {}
    assert sorted(pool.freed) == [5, 6, 11]
    assert pc.match(a) == (0, [])


def test_lru_eviction_order_follows_touches():
    pool = FakePool()
    pc = PrefixCache(pool, page_size=2)
    pc.insert(_toks(1, 1), pages=[3])       # A (oldest)
    pc.insert(_toks(2, 2), pages=[4])       # B
    pc.insert(_toks(5, 5), pages=[6])       # C (newest)
    pc.match(_toks(1, 1))                   # touch A: now B is LRU
    assert pc.evict(2) == 2
    assert pool.freed == [4, 6]             # B then C, never A
    assert pc.match(_toks(1, 1)) == (2, [3])
    assert pc.evictable_pages() == 1


def test_eviction_is_leaf_first_and_skips_lane_referenced_pages():
    pool = FakePool()
    pc = PrefixCache(pool, page_size=2)
    pc.insert(_toks(1, 2, 3, 4, 5, 6), pages=[7, 8, 9])   # chain 7 -> 8 -> 9

    # a lane claiming a cached path retains EVERY page on it (exactly
    # what PagedKVCache.alloc does with shared_pages) — that upward
    # closure is what makes evictable_pages() exact
    for p in (7, 8, 9):
        pool.retain_page(p)
    # every node is pinned at refcount 2: nothing is evictable — pool
    # pressure can never touch pages a live lane maps
    assert pc.evictable_pages() == 0
    assert pc.evict(3) == 0 and pc.n_nodes == 3

    for p in (7, 8, 9):                     # lane finished
        pool.release_page(p)
    assert pc.evictable_pages() == 3
    # leaf-first drain: evicting 9 exposes 8, then 8 exposes 7
    assert pc.evict(2) == 2
    assert pool.freed == [9, 8]
    assert pc.n_nodes == 1 and pc.match(_toks(1, 2)) == (2, [7])


def test_max_pages_cap_trims_lru_after_insert():
    pool = FakePool()
    pc = PrefixCache(pool, page_size=2, max_pages=2)
    pc.insert(_toks(1, 1), pages=[3])
    pc.insert(_toks(2, 2), pages=[4])
    pc.insert(_toks(5, 5), pages=[6])       # over cap: LRU (A) trimmed
    assert pc.n_nodes == 2
    assert pool.freed == [3]
    assert pc.match(_toks(1, 1)) == (0, [])
    assert pc.match(_toks(5, 5)) == (2, [6])


def test_claim_stats_and_reset_keep_trie():
    pool = FakePool()
    pc = PrefixCache(pool, page_size=2)
    pc.insert(_toks(1, 2, 3, 4), pages=[5, 6])
    pc.note_claim(cached_len=4, prompt_len=6)
    pc.note_claim(cached_len=0, prompt_len=4)
    st = pc.stats()
    assert st["prefix_lookups"] == 2.0 and st["prefix_hits"] == 1.0
    assert st["prefix_hit_rate"] == 0.5
    assert st["prefix_claimed_tokens"] == 4.0
    assert st["prefix_token_savings"] == pytest.approx(0.4)
    assert st["prefix_cached_pages"] == 2.0
    pc.reset_stats()
    assert pc.stats()["prefix_lookups"] == 0.0
    assert pc.match(_toks(1, 2)) == (2, [5])    # trie survives the reset


# ---------------------------------------------------------------------------
# pool units (real PagedKVCache: COW forks, eviction, rollback, snapshots)
# ---------------------------------------------------------------------------


def test_cow_fork_bookkeeping_and_sibling_invisibility(moe):
    cfg, _ = moe
    cache = PagedKVCache(cfg, n_slots=3, max_len=16, page_size=4)
    pc = PrefixCache(cache, 4)
    cache.attach_prefix_cache(pc)
    prompt = _toks(1, 2, 3, 4, 5, 6, 7, 8)

    slot = cache.alloc(8)
    p1, p2 = cache.lane_pages(slot)
    pc.insert(prompt, [p1, p2])
    assert cache.refcount(p1) == 2 and cache.refcount(p2) == 2
    cache.release(slot)
    # cached pages survive the lane: resident at refcount 1 (trie only)
    assert cache.refcount(p1) == 1 and cache.refcount(p2) == 1
    assert p1 not in cache._free_pages and p2 not in cache._free_pages

    # full hit: last shared page is COW-forked into a private copy
    cached_len, shared = pc.match(prompt)
    assert (cached_len, shared) == (8, [p1, p2])
    s2 = cache.alloc(8, shared_pages=shared, fork_last=True)
    fork2 = cache.lane_pages(s2)[-1]
    assert cache.cow_forks == 1
    assert cache.lane_pages(s2) == [p1, fork2] and fork2 != p2
    assert cache.lane_shared(s2) == 1           # only p1 is borrowed
    assert cache.refcount(p1) == 2              # trie + this lane
    assert cache.refcount(p2) == 1              # trie only — claim dropped
    assert cache.refcount(fork2) == 1           # private, trie-free
    assert fork2 not in pc.pages()
    np.testing.assert_array_equal(cache.page_table[s2, :2], [p1, fork2])

    # a sibling full hit gets its OWN fork — never sees fork2, and the
    # trie still serves the original p2
    s3 = cache.alloc(8, shared_pages=list(pc.match(prompt)[1]),
                     fork_last=True)
    fork3 = cache.lane_pages(s3)[-1]
    assert fork3 not in (p2, fork2)
    assert fork2 not in cache.lane_pages(s3)
    assert cache.refcount(p1) == 3 and cache.refcount(p2) == 1
    assert cache.gauges()["shared_pages"] == 1.0    # p1 (refcount 3)
    assert cache.gauges()["cow_forks"] == 2.0

    cache.release(s2)
    cache.release(s3)
    assert dict(cache._refs) == {p1: 1, p2: 1}      # trie-only again


def test_alloc_evicts_under_pressure_and_rolls_back_on_shortfall(moe):
    cfg, _ = moe
    cache = PagedKVCache(cfg, n_slots=3, max_len=16, page_size=4,
                         page_budget=4)
    pc = PrefixCache(cache, 4)
    cache.attach_prefix_cache(pc)

    pinned = cache.alloc(4)                     # 1 page a lane keeps
    donor = cache.alloc(12)
    trie_pages = cache.lane_pages(donor)
    pc.insert(np.arange(12, dtype=np.int32), trie_pages)
    cache.release(donor)
    assert cache.free_pages == 0 and pc.n_nodes == 3

    # authoritative shortfall: 3 shared + 1 fresh needed, but the only
    # evictable pages ARE the ones this claim just pinned (can_admit is
    # documented optimistic here) — alloc must roll back cleanly
    assert cache.can_admit(16, n_shared=3)
    assert cache.alloc(16, shared_pages=trie_pages) is None
    assert all(cache.refcount(p) == 1 for p in trie_pages)
    assert cache.n_free == 2 and pc.n_nodes == 3

    # with a free page, a cold 4-page alloc succeeds by evicting the
    # whole (unreferenced) trie
    cache.release(pinned)
    slot = cache.alloc(16)
    assert slot is not None
    assert pc.n_nodes == 0 and pc.evicted_pages == 3
    assert cache.free_pages == 0 and len(cache.lane_pages(slot)) == 4


def test_page_table_device_caches_per_slot_snapshots(moe):
    cfg, _ = moe
    cache = PagedKVCache(cfg, n_slots=2, max_len=16, page_size=4)
    s0, s1 = cache.alloc(8), cache.alloc(8)
    d0, d1 = cache.page_table_device(s0), cache.page_table_device(s1)
    full = cache.page_table_device()
    # repeat calls return the SAME cached snapshot object
    assert cache.page_table_device(s0) is d0
    assert cache.page_table_device(s1) is d1
    assert cache.page_table_device() is full
    # a mutation of s1 invalidates s1's row and the full table, NOT s0's
    cache.release(s1)
    assert cache.page_table_device(s0) is d0
    assert cache.page_table_device(s1) is not d1
    assert cache.page_table_device() is not full
    np.testing.assert_array_equal(np.asarray(cache.page_table_device(s1)), 0)


def test_gauges_zero_budget_and_prefix_keys(moe):
    cfg, _ = moe
    g = PagedKVCache(cfg, n_slots=1, max_len=8, page_size=8,
                     page_budget=0).gauges()
    assert g["page_utilization"] == 0.0          # no ZeroDivisionError
    assert g["cache_hit_rate"] == 0.0            # no prefix cache attached
    assert g["shared_pages"] == 0.0 and g["cow_forks"] == 0.0


# ---------------------------------------------------------------------------
# engine harness: shared-prefix workloads
# ---------------------------------------------------------------------------


def _shared_prefix_workload(cfg, rs, n=8, max_new=6):
    """Requests drawn from two shared system prompts (8 and 16 tokens —
    page-aligned and not-chunk-aligned both appear) plus a random
    private suffix; suffix length 0 makes exact repeats (full hits)."""
    prefixes = [rs.randint(0, cfg.vocab, L).astype(np.int32)
                for L in (8, 16)]
    reqs = []
    for _ in range(n):
        pre = prefixes[int(rs.randint(len(prefixes)))]
        sfx = rs.randint(0, cfg.vocab,
                         int(rs.randint(0, 6))).astype(np.int32)
        reqs.append(Request(np.concatenate([pre, sfx]),
                            int(rs.randint(1, max_new + 1))))
    return reqs


def _clone(reqs):
    return [Request(r.prompt, r.max_new_tokens, eos_id=r.eos_id,
                    temperature=r.temperature) for r in reqs]


def _drive_bursty(eng, reqs, rs):
    pending = list(reqs)
    rids = []
    while pending or eng.busy:
        while pending and rs.rand() < 0.6:
            rids.append(eng.submit(pending.pop(0)))
        eng.step()
    return [eng.scheduler.result(rid) for rid in rids]


def _engine(params, cfg, spec=False, **kw):
    kwargs = dict(max_len=32, max_batch=3, prefill_chunk=8,
                  kv_layout="paged", page_size=8, page_budget=12)
    if spec:
        mask = np.ones(cfg.n_experts, np.float32)
        mask[-cfg.n_experts // 4:] = 0.0
        kwargs.update(spec_decode="pruned", spec_k=3, expert_mask=mask)
    kwargs.update(kw)
    return ServeEngine(params, cfg, **kwargs)


def _check_prefix_invariants(cache, pc):
    """The sharing-aware page invariants (kv_cache.py docstring):
    ``refcount(p) == referencing lane tables + trie entries`` exactly,
    sharing only through the trie, at most one lane holding any page
    outside its read-only shared-prefix region, sentinel untouched,
    and free pool + referenced pages partitioning the budget."""
    lane_refs = {}
    for slot, pages in cache._pages_of.items():
        assert 0 not in pages, f"sentinel mapped by lane {slot}"
        assert len(set(pages)) == len(pages), "page twice in one lane"
        width = len(pages)
        np.testing.assert_array_equal(cache.page_table[slot, :width], pages)
        assert (cache.page_table[slot, width:] == 0).all()
        assert int(cache.seq_lens[slot]) <= width * cache.page_size
        assert 0 <= cache.lane_shared(slot) <= width
        for p in pages:
            lane_refs[p] = lane_refs.get(p, 0) + 1
    trie_pages = pc.pages()
    assert len(set(trie_pages)) == len(trie_pages) == pc.n_nodes
    assert 0 not in trie_pages
    expected = dict(lane_refs)
    for p in trie_pages:
        expected[p] = expected.get(p, 0) + 1
    assert dict(cache._refs) == expected, "refcount != lanes + trie"
    trie_set = set(trie_pages)
    for p, n in lane_refs.items():
        if n > 1:                      # lanes share ONLY via the trie
            assert p in trie_set, f"page {p} lane-shared but not cached"
        writers = sum(1 for s, pages in cache._pages_of.items()
                      if p in pages[cache.lane_shared(s):])
        assert writers <= 1, f"page {p} writable from {writers} lanes"
    free = set(cache._free_pages)
    assert 0 not in free and not (free & set(expected))
    assert len(free) + len(expected) == cache.page_budget
    for slot in cache._free_slots:
        assert (cache.page_table[slot] == 0).all()


# ---------------------------------------------------------------------------
# discrimination: repeats cost zero prefill; cache-off re-prefills
# ---------------------------------------------------------------------------


def test_repeat_prompt_costs_zero_prefill_dispatches(moe):
    cfg, params = moe
    rs = np.random.RandomState(11)
    req = Request(rs.randint(0, cfg.vocab, 16).astype(np.int32), 4)

    on = _engine(params, cfg, prefix_cache=True)
    first = on.generate(_clone([req]))[0]
    p_cold = on.prefill_dispatches
    assert p_cold == 2                            # ceil(16/8) chunks
    d0 = on.decode_dispatches
    repeat = on.generate(_clone([req]))[0]
    np.testing.assert_array_equal(first, repeat)  # replay path is exact
    assert on.prefill_dispatches == p_cold, \
        "fully cached prompt must dispatch ZERO prefill chunks"
    assert on.decode_dispatches > d0              # tokens came from decode
    assert on.cache.cow_forks == 1
    st = on.latency_stats()
    assert st["prefix_hits"] == 1.0 and st["prefix_hit_rate"] == 0.5
    assert st["prefix_claimed_tokens"] == 16.0
    assert st["cache_hit_rate"] == 0.5
    assert "prefix_lookups" not in _engine(params, cfg).latency_stats()

    # the discrimination half: a cache-off engine re-prefills every time
    off = _engine(params, cfg)
    off.generate(_clone([req]))
    p1 = off.prefill_dispatches
    off.generate(_clone([req]))
    assert off.prefill_dispatches == 2 * p1, \
        "cache-off engine should pay the full prefill again"


def test_partial_hit_resumes_prefill_past_claimed_pages(moe):
    cfg, params = moe
    rs = np.random.RandomState(12)
    base = rs.randint(0, cfg.vocab, 13).astype(np.int32)
    on = _engine(params, cfg, prefix_cache=True)
    off = _engine(params, cfg)

    a_on = on.generate([Request(base, 3)])[0]
    assert on.prefill_dispatches == 2             # ceil(13/8) cold
    # only the 8-token page is cached (13 rounds down to one page, which
    # is also the claim grain): the repeat prefills ONE chunk, not two
    b_on = on.generate([Request(base, 3)])[0]
    assert on.prefill_dispatches == 3
    ref = off.generate([Request(base, 3)])[0]
    np.testing.assert_array_equal(a_on, ref)
    np.testing.assert_array_equal(b_on, ref)
    st = on.latency_stats()
    assert st["prefix_hits"] == 1.0 and st["prefix_claimed_tokens"] == 8.0


# ---------------------------------------------------------------------------
# equivalence oracle: cache-on == cache-off, cold AND warm
# ---------------------------------------------------------------------------


@pytest.mark.stress
@pytest.mark.parametrize("schedule,spec", [("blocking", False),
                                           ("interleaved", False),
                                           ("interleaved", True)])
def test_cache_on_token_identical_to_cache_off(moe, schedule, spec):
    """Randomized shared-prefix workload with mid-stream EOS: the
    prefix-cache-on engine must reproduce the cache-off engine's outputs
    token for token — on a cold trie AND on a warm second wave where
    repeats take the zero-prefill COW/replay path — through both
    schedules, with speculative decode on the interleaved one."""
    cfg, params = moe
    seed = {("blocking", False): 700, ("interleaved", False): 800,
            ("interleaved", True): 900}[(schedule, spec)]
    rs = np.random.RandomState(seed)
    reqs = _shared_prefix_workload(cfg, rs, n=7)

    harvest = _engine(params, cfg, spec,
                      schedule="blocking").generate(_clone(reqs))
    for i in range(0, len(reqs), 3):              # EOS fires mid-stream
        out = harvest[i]
        if len(out) >= 3:
            reqs[i].eos_id = int(out[len(out) // 2])

    off = _engine(params, cfg, spec, schedule="blocking")
    outs_off = off.generate(_clone(reqs))
    on = _engine(params, cfg, spec, schedule=schedule, prefix_cache=True)
    if schedule == "blocking":
        outs_cold = on.generate(_clone(reqs))
        outs_warm = on.generate(_clone(reqs))
    else:
        outs_cold = _drive_bursty(on, _clone(reqs), rs)
        outs_warm = _drive_bursty(on, _clone(reqs), rs)

    for r, a, b, c in zip(reqs, outs_off, outs_cold, outs_warm):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
        assert len(a) <= r.max_new_tokens
    st = on.latency_stats()
    assert st["prefix_lookups"] == 2.0 * len(reqs)
    assert st["prefix_hits"] >= len(reqs), \
        "warm wave saw no cache hits — the trie isn't being consulted"
    assert not on.busy and on.cache.n_free == on.cache.n_slots
    _check_prefix_invariants(on.cache, on.prefix_cache)


@pytest.mark.stress
def test_cache_equivalence_with_pruned_serving(moe):
    """The masks axes of the oracle: runtime ``expert_mask`` and stage-2
    ``weight_masks`` engines must stay cache-on == cache-off (warm wave
    included)."""
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    rs = np.random.RandomState(13)
    reqs = _shared_prefix_workload(cfg, rs, n=5)
    emask = np.ones(cfg.n_experts, np.float32)
    emask[-cfg.n_experts // 4:] = 0.0
    batches = calibration_batches(cfg, n_batches=2)
    _, wmasks, _ = unstructured_only(params, cfg, batches,
                                     target_sparsity=0.4, method="wanda")
    for kwargs in ({"expert_mask": emask}, {"weight_masks": wmasks}):
        off = _engine(params, cfg, schedule="blocking",
                      **kwargs).generate(_clone(reqs))
        on = _engine(params, cfg, schedule="interleaved",
                     prefix_cache=True, **kwargs)
        for wave in range(2):
            outs = _drive_bursty(on, _clone(reqs), rs)
            for a, b in zip(off, outs):
                np.testing.assert_array_equal(a, b)
        assert on.latency_stats()["prefix_hits"] >= 1.0


# ---------------------------------------------------------------------------
# randomized stress: page invariants under churn (sanitizer on)
# ---------------------------------------------------------------------------


def _prefix_stress_drive(params, cfg, seed, spec=False, max_pages=None,
                         n=10):
    rs = np.random.RandomState(seed)
    reqs = _shared_prefix_workload(cfg, rs, n=n)
    eng = _engine(params, cfg, spec, schedule="interleaved",
                  prefix_cache=True, prefix_cache_max_pages=max_pages)
    pending = list(reqs)
    rids = []
    n_steps = 0
    while pending or eng.busy:
        while pending and rs.rand() < 0.5:
            rids.append(eng.submit(pending.pop(0)))
        eng.step()
        n_steps += 1
        assert n_steps < 10_000, "engine failed to drain"
        _check_prefix_invariants(eng.cache, eng.prefix_cache)
        if max_pages is not None:
            assert eng.prefix_cache.n_nodes <= max_pages
    assert len(rids) == len(reqs) and len(set(rids)) == len(rids)
    for req, rid in zip(reqs, rids):
        out = eng.scheduler.result(rid)        # KeyError here == lost
        assert 1 <= len(out) <= req.max_new_tokens
    # drained: every surviving page reference is a trie entry at
    # refcount 1, and free pool + trie partition the budget exactly
    assert eng.cache.n_free == eng.cache.n_slots
    assert sorted(eng.cache._refs) == sorted(eng.prefix_cache.pages())
    assert all(n == 1 for n in eng.cache._refs.values())
    assert eng.cache.free_pages + eng.prefix_cache.n_nodes == \
        eng.cache.page_budget


@pytest.mark.stress
@pytest.mark.parametrize("seed", [0, 1])
def test_prefix_stress_invariants_sanitized(moe, sanitized, seed):
    cfg, params = moe
    _prefix_stress_drive(params, cfg, seed)


@pytest.mark.stress
def test_prefix_stress_invariants_spec_sanitized(moe, sanitized):
    cfg, params = moe
    _prefix_stress_drive(params, cfg, 2, spec=True, n=8)


@pytest.mark.stress
def test_prefix_stress_invariants_with_trie_cap(moe, sanitized):
    """A tight ``max_pages`` cap forces trie trims mid-churn; the
    refcount invariants must survive the extra eviction pressure."""
    cfg, params = moe
    _prefix_stress_drive(params, cfg, 3, max_pages=3)


# ---------------------------------------------------------------------------
# constructor validation
# ---------------------------------------------------------------------------


def test_engine_rejects_bad_prefix_cache_args(moe):
    cfg, params = moe
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(params, cfg, max_len=16, kv_layout="slot",
                    prefix_cache=True)
    with pytest.raises(ValueError, match="prefix_cache_max_pages"):
        ServeEngine(params, cfg, max_len=16, prefix_cache_max_pages=4)
    with pytest.raises(ValueError, match="page_size"):
        PrefixCache(FakePool(), page_size=0)
    with pytest.raises(ValueError, match="max_pages"):
        PrefixCache(FakePool(), page_size=4, max_pages=-1)
