"""Pallas kernels vs pure-jnp oracles — interpret mode, shape/dtype sweeps
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import random

from repro.kernels import ref
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               build_block_mask)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.wanda_score import wanda_mask_apply

RNG = random.PRNGKey(0)
TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,hd,bq,bk", [
    (1, 2, 128, 64, 32, 32),
    (2, 3, 256, 64, 64, 64),
    (1, 1, 128, 128, 128, 64),
])
def test_flash_attention_sweep(dtype, B, H, S, hd, bq, bk):
    q = random.normal(RNG, (B, H, S, hd), dtype)
    k = random.normal(random.fold_in(RNG, 1), (B, H, S, hd), dtype)
    v = random.normal(random.fold_in(RNG, 2), (B, H, S, hd), dtype)
    o = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=TOL[dtype])


def test_flash_attention_window():
    q = random.normal(RNG, (1, 2, 128, 32), jnp.float32)
    o = flash_attention(q, q, q, window=32, block_q=32, block_k=32,
                        interpret=True)
    r = ref.flash_attention_ref(q, q, q, window=32)
    np.testing.assert_allclose(o, r, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,n_pages,ps,mp", [
    (3, 4, 2, 32, 16, 8, 4),      # GQA, ragged lengths
    (2, 8, 8, 64, 12, 16, 3),     # MHA-ish, bigger pages
    (1, 2, 1, 128, 8, 8, 5),      # MQA, single lane
])
def test_paged_decode_attention_sweep(dtype, B, H, K, hd, n_pages, ps, mp):
    q = random.normal(RNG, (B, 1, H, hd), dtype)
    kp = random.normal(random.fold_in(RNG, 1), (n_pages, ps, K, hd), dtype)
    vp = random.normal(random.fold_in(RNG, 2), (n_pages, ps, K, hd), dtype)
    rs = np.random.RandomState(B * H)
    # page tables may repeat physical pages across *inactive* tail entries
    # (the engine's sentinel); valid rows make every table prefix distinct
    tbl = jnp.asarray(rs.choice(n_pages, (B, mp)), jnp.int32)
    lens = jnp.asarray(rs.randint(1, mp * ps + 1, B), jnp.int32)
    o = paged_decode_attention(q, kp, vp, tbl, lens, interpret=True)
    r = ref.paged_decode_attention_ref(q, kp, vp, tbl, lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=TOL[dtype])


@pytest.mark.parametrize("window,softcap", [(5, None), (None, 20.0),
                                            (16, 30.0)])
def test_paged_decode_attention_window_softcap(window, softcap):
    B, H, K, hd, n_pages, ps, mp = 4, 4, 2, 32, 10, 8, 4
    q = random.normal(RNG, (B, 1, H, hd), jnp.float32)
    kp = random.normal(random.fold_in(RNG, 3), (n_pages, ps, K, hd),
                       jnp.float32)
    vp = random.normal(random.fold_in(RNG, 4), (n_pages, ps, K, hd),
                       jnp.float32)
    rs = np.random.RandomState(7)
    tbl = jnp.asarray(rs.choice(n_pages, (B, mp)), jnp.int32)
    lens = jnp.asarray(rs.randint(1, mp * ps + 1, B), jnp.int32)
    o = paged_decode_attention(q, kp, vp, tbl, lens, window=window,
                               softcap=softcap, interpret=True)
    r = ref.paged_decode_attention_ref(q, kp, vp, tbl, lens, window=window,
                                       softcap=softcap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_paged_decode_matches_contiguous_decode():
    """A paged cache whose pages happen to be contiguous must reproduce
    ``models.layers.attention_decode`` on the equivalent [B,T,K,hd] cache
    — the slot-engine decode the serving stack is tested against."""
    from repro.models.layers import attention_decode

    B, H, K, hd, ps, mp = 2, 4, 2, 16, 8, 3
    T = mp * ps
    q = random.normal(RNG, (B, 1, H, hd), jnp.float32)
    cache_k = random.normal(random.fold_in(RNG, 5), (B, T, K, hd),
                            jnp.float32)
    cache_v = random.normal(random.fold_in(RNG, 6), (B, T, K, hd),
                            jnp.float32)
    lens = jnp.asarray([T - 3, 9], jnp.int32)
    # lay lane b's rows out as pages 1+b*mp .. (identity page table)
    kp = jnp.concatenate([jnp.zeros((1, ps, K, hd)),
                          cache_k.reshape(B * mp, ps, K, hd)])
    vp = jnp.concatenate([jnp.zeros((1, ps, K, hd)),
                          cache_v.reshape(B * mp, ps, K, hd)])
    tbl = jnp.asarray(1 + np.arange(B * mp).reshape(B, mp), jnp.int32)
    want = attention_decode(q, cache_k, cache_v, lens)
    got_k = paged_decode_attention(q, kp, vp, tbl, lens, interpret=True)
    got_r = ref.paged_decode_attention_ref(q, kp, vp, tbl, lens)
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want),
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want),
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(2, 32, 32, 32), (4, 64, 96, 80),
                                     (8, 16, 128, 64)])
def test_moe_gmm_sweep(dtype, E, C, D, F):
    buf = random.normal(RNG, (E, C, D), dtype)
    w = random.normal(random.fold_in(RNG, 1), (E, D, F), dtype)
    o = moe_gmm(buf, w, block_c=16, block_f=16, block_d=16, interpret=True)
    r = ref.moe_gmm_ref(buf, w)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype] * D ** 0.5)


@pytest.mark.parametrize("density", [0.0, 0.4, 1.0])
def test_block_sparse_matmul(density):
    M, K, N, bk, bn = 64, 128, 96, 32, 32
    x = random.normal(RNG, (M, K), jnp.float32)
    w = np.array(random.normal(random.fold_in(RNG, 1), (K, N)))
    bm = np.random.RandomState(0).rand(K // bk, N // bn) < density
    for i in range(K // bk):
        for j in range(N // bn):
            if not bm[i, j]:
                w[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn] = 0
    w = jnp.asarray(w)
    o = block_sparse_matmul(x, w, jnp.asarray(bm), block_m=32, block_n=bn,
                            block_k=bk, interpret=True)
    r = ref.block_sparse_matmul_ref(x, w, jnp.asarray(bm), bk, bn)
    np.testing.assert_allclose(o, r, atol=1e-4)


def _random_packed(rs, K, N, bk, bn, density):
    """Random block pool + index with slot 0 the zero sentinel."""
    Kb, Nb = K // bk, N // bn
    live = rs.rand(Kb, Nb) < density
    index = np.zeros((Kb, Nb), np.int32)
    index[live] = np.arange(1, int(live.sum()) + 1)
    pool = np.zeros((int(live.sum()) + 1, bk, bn), np.float32)
    pool[1:] = rs.randn(int(live.sum()), bk, bn)
    return jnp.asarray(pool), jnp.asarray(index)


@pytest.mark.parametrize("density", [0.0, 0.4, 1.0])
@pytest.mark.parametrize("M,K,N,bk,bn", [(64, 128, 96, 32, 32),
                                         (48, 64, 32, 16, 8),
                                         (7, 32, 64, 8, 16)])
def test_block_sparse_gather_matmul(density, M, K, N, bk, bn):
    """The pool-gather kernel (scalar-prefetched block index selects the
    pool block to DMA) matches the unpack-then-matmul reference."""
    from repro.kernels.block_sparse_matmul import block_sparse_gather_matmul
    from repro.kernels.ops import choose_block_m

    rs = np.random.RandomState(int(density * 10) + M)
    pool, index = _random_packed(rs, K, N, bk, bn, density)
    x = jnp.asarray(rs.randn(M, K), jnp.float32)
    o = block_sparse_gather_matmul(x, pool, index,
                                   block_m=choose_block_m(M),
                                   interpret=True)
    r = ref.block_sparse_gather_matmul_ref(x, pool, index)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-4)


def test_choose_block_m():
    from repro.kernels.ops import choose_block_m
    assert choose_block_m(256) == 128          # capped at the MXU tile
    assert choose_block_m(96) == 96
    assert choose_block_m(48, cap=32) == 24    # largest divisor <= cap
    assert choose_block_m(7) == 7
    assert choose_block_m(97, cap=32) == 1     # prime beyond cap


@pytest.mark.parametrize("M,K,N,bk,bn", [
    (40, 96, 48, 32, 16),       # uneven M: chooser must pick 40, not 32
    (24, 64, 80, 16, 16),
    (12, 48, 32, 16, 32),
    (100, 32, 64, 8, 64),
])
def test_sparse_matmul_op_chooser_parity(M, K, N, bk, bn):
    """The unified shape-driven tile chooser: ops.sparse_matmul_op in
    interpret mode must agree with the jnp reference on uneven M/K/N
    (the old hardcoded block_m=32 interpret branch failed whenever
    32 did not divide M)."""
    from repro.kernels import ops

    rs = np.random.RandomState(M + K)
    x = jnp.asarray(rs.randn(M, K), jnp.float32)
    w = rs.randn(K, N)
    bm_mask = rs.rand(K // bk, N // bn) < 0.5
    for i in range(K // bk):
        for j in range(N // bn):
            if not bm_mask[i, j]:
                w[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn] = 0
    w = jnp.asarray(w, jnp.float32)
    r = ref.block_sparse_matmul_ref(x, w, jnp.asarray(bm_mask), bk, bn)
    o = ops.sparse_matmul_op(x, w, jnp.asarray(bm_mask), block_k=bk,
                             block_n=bn, force="interpret")
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-4)
    o_ref = ops.sparse_matmul_op(x, w, jnp.asarray(bm_mask), block_k=bk,
                                 block_n=bn)                # ref on CPU
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(r), atol=0)


def test_sparse_gather_op_dispatch():
    """ops.sparse_gather_matmul_op: CPU ref vs interpreted kernel."""
    from repro.kernels import ops

    rs = np.random.RandomState(5)
    pool, index = _random_packed(rs, 64, 32, 16, 8, 0.5)
    x = jnp.asarray(rs.randn(20, 64), jnp.float32)
    a = ops.sparse_gather_matmul_op(x, pool, index)
    b = ops.sparse_gather_matmul_op(x, pool, index, force="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_build_block_mask():
    m = np.zeros((64, 64), bool)
    m[0, 0] = True          # one nonzero in block (0,0)
    m[40, 50] = True        # one in block (1,1) at 32-blocking
    bm = build_block_mask(m, 32, 32)
    assert bm.tolist() == [[True, False], [False, True]]


@pytest.mark.parametrize("K,N", [(128, 64), (256, 256)])
def test_wanda_mask_apply(K, N):
    w = random.normal(RNG, (K, N), jnp.float32)
    xn = jnp.abs(random.normal(random.fold_in(RNG, 1), (K,)))
    th = jnp.abs(random.normal(random.fold_in(RNG, 2), (N,)))
    o = wanda_mask_apply(w, xn, th, block_k=64, block_n=64, interpret=True)
    r = ref.wanda_mask_apply_ref(w, xn, th)
    np.testing.assert_allclose(o, r, atol=0)


@pytest.mark.parametrize("S,sub", [(64, 16), (128, 64)])
def test_rglru_scan(S, sub):
    B, W = 2, 64
    a = jax.nn.sigmoid(random.normal(RNG, (B, S, W), jnp.float32))
    b = random.normal(random.fold_in(RNG, 1), (B, S, W), jnp.float32)
    o = rglru_scan(a, b, block_w=32, sub=sub, interpret=True)
    r = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(o, r, atol=1e-5)


def test_ops_fallback_dispatch():
    """ops.py wrappers pick the jnp ref on CPU and agree with interpret."""
    from repro.kernels import ops
    q = random.normal(RNG, (1, 2, 64, 32), jnp.float32)
    a = ops.attention_op(q, q, q)                     # ref path on CPU
    b = ops.attention_op(q, q, q, force="interpret")  # kernel, interpreted
    np.testing.assert_allclose(a, b, atol=2e-5)


def test_paged_ops_fallback_dispatch():
    from repro.kernels import ops
    qd = random.normal(RNG, (2, 1, 4, 32), jnp.float32)
    kp = random.normal(random.fold_in(RNG, 9), (6, 8, 2, 32), jnp.float32)
    tbl = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    lens = jnp.asarray([5, 14], jnp.int32)
    a = ops.paged_attention_op(qd, kp, kp, tbl, lens)
    b = ops.paged_attention_op(qd, kp, kp, tbl, lens, force="interpret")
    np.testing.assert_allclose(a, b, atol=2e-5)
