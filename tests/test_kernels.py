"""Pallas kernels vs pure-jnp oracles — interpret mode, shape/dtype sweeps
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import random

from repro.kernels import ref
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               build_block_mask)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.wanda_score import wanda_mask_apply

RNG = random.PRNGKey(0)
TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,hd,bq,bk", [
    (1, 2, 128, 64, 32, 32),
    (2, 3, 256, 64, 64, 64),
    (1, 1, 128, 128, 128, 64),
])
def test_flash_attention_sweep(dtype, B, H, S, hd, bq, bk):
    q = random.normal(RNG, (B, H, S, hd), dtype)
    k = random.normal(random.fold_in(RNG, 1), (B, H, S, hd), dtype)
    v = random.normal(random.fold_in(RNG, 2), (B, H, S, hd), dtype)
    o = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=TOL[dtype])


def test_flash_attention_window():
    q = random.normal(RNG, (1, 2, 128, 32), jnp.float32)
    o = flash_attention(q, q, q, window=32, block_q=32, block_k=32,
                        interpret=True)
    r = ref.flash_attention_ref(q, q, q, window=32)
    np.testing.assert_allclose(o, r, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(2, 32, 32, 32), (4, 64, 96, 80),
                                     (8, 16, 128, 64)])
def test_moe_gmm_sweep(dtype, E, C, D, F):
    buf = random.normal(RNG, (E, C, D), dtype)
    w = random.normal(random.fold_in(RNG, 1), (E, D, F), dtype)
    o = moe_gmm(buf, w, block_c=16, block_f=16, block_d=16, interpret=True)
    r = ref.moe_gmm_ref(buf, w)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype] * D ** 0.5)


@pytest.mark.parametrize("density", [0.0, 0.4, 1.0])
def test_block_sparse_matmul(density):
    M, K, N, bk, bn = 64, 128, 96, 32, 32
    x = random.normal(RNG, (M, K), jnp.float32)
    w = np.array(random.normal(random.fold_in(RNG, 1), (K, N)))
    bm = np.random.RandomState(0).rand(K // bk, N // bn) < density
    for i in range(K // bk):
        for j in range(N // bn):
            if not bm[i, j]:
                w[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn] = 0
    w = jnp.asarray(w)
    o = block_sparse_matmul(x, w, jnp.asarray(bm), block_m=32, block_n=bn,
                            block_k=bk, interpret=True)
    r = ref.block_sparse_matmul_ref(x, w, jnp.asarray(bm), bk, bn)
    np.testing.assert_allclose(o, r, atol=1e-4)


def test_build_block_mask():
    m = np.zeros((64, 64), bool)
    m[0, 0] = True          # one nonzero in block (0,0)
    m[40, 50] = True        # one in block (1,1) at 32-blocking
    bm = build_block_mask(m, 32, 32)
    assert bm.tolist() == [[True, False], [False, True]]


@pytest.mark.parametrize("K,N", [(128, 64), (256, 256)])
def test_wanda_mask_apply(K, N):
    w = random.normal(RNG, (K, N), jnp.float32)
    xn = jnp.abs(random.normal(random.fold_in(RNG, 1), (K,)))
    th = jnp.abs(random.normal(random.fold_in(RNG, 2), (N,)))
    o = wanda_mask_apply(w, xn, th, block_k=64, block_n=64, interpret=True)
    r = ref.wanda_mask_apply_ref(w, xn, th)
    np.testing.assert_allclose(o, r, atol=0)


@pytest.mark.parametrize("S,sub", [(64, 16), (128, 64)])
def test_rglru_scan(S, sub):
    B, W = 2, 64
    a = jax.nn.sigmoid(random.normal(RNG, (B, S, W), jnp.float32))
    b = random.normal(random.fold_in(RNG, 1), (B, S, W), jnp.float32)
    o = rglru_scan(a, b, block_w=32, sub=sub, interpret=True)
    r = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(o, r, atol=1e-5)


def test_ops_fallback_dispatch():
    """ops.py wrappers pick the jnp ref on CPU and agree with interpret."""
    from repro.kernels import ops
    q = random.normal(RNG, (1, 2, 64, 32), jnp.float32)
    a = ops.attention_op(q, q, q)                     # ref path on CPU
    b = ops.attention_op(q, q, q, force="interpret")  # kernel, interpreted
    np.testing.assert_allclose(a, b, atol=2e-5)
