"""Telemetry tests: metrics-schema governance, zero-cost disabled
tracing, traced == untraced token streams, Chrome-trace validity, and
the reconciliation pin holding span args equal to the scheduler's
latency windows.

The expensive engine tests share one module-scoped tiny MoE (the
test_serving.py idiom); the schema / workload / fence tests are pure
and run on fake clocks.
"""
import dataclasses
import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.models import abstract_params
from repro.models import param as pm
from repro.serving import (METRICS_SCHEMA, NULL_TRACER, MetricsSchemaError,
                           Request, ServeEngine, Tracer, load_workload,
                           stage_timeline, validate_metrics)
from repro.serving import telemetry
from repro.serving.telemetry import (NULL_SPAN, prompt_seed, schema_table)

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


def _requests(cfg, n=4, seed=3):
    rs = np.random.RandomState(seed)
    return [Request(rs.randint(0, cfg.vocab,
                               int(rs.randint(4, 14))).astype(np.int32),
                    int(rs.randint(3, 9)))
            for _ in range(n)]


def _fake_clock(start=100.0, step=0.125):
    t = [start - step]

    def clock():
        t[0] += step
        return t[0]
    return clock


def _load_validate_trace():
    spec = importlib.util.spec_from_file_location(
        "validate_trace", ROOT / "tools" / "validate_trace.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# schema governance
# ---------------------------------------------------------------------------


def test_schema_matches_docs_table():
    """The table in docs/serving.md between the metrics-schema markers
    is generated from METRICS_SCHEMA — adding/renaming a metric without
    regenerating the docs fails here."""
    text = (ROOT / "docs" / "serving.md").read_text()
    begin = "<!-- metrics-schema:begin -->"
    end = "<!-- metrics-schema:end -->"
    assert begin in text and end in text
    documented = text.split(begin, 1)[1].split(end, 1)[0].strip()
    assert documented == schema_table().strip()


def test_validate_metrics_rejects_undeclared_key():
    ok = {"p50_latency_s": 0.1, "pages_in_use": 2.0}
    assert validate_metrics(ok, "test") is ok
    with pytest.raises(MetricsSchemaError, match="made_up_metric"):
        validate_metrics({"made_up_metric": 1.0}, "test")


def test_live_metrics_are_schema_subsets(moe):
    """Every emitting surface (latency_stats and the wider metrics())
    stays inside the declared schema across engine configs."""
    cfg, params = moe
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-2:] = 0.0
    for kwargs in ({}, {"kv_layout": "slot"},
                   {"prefix_cache": True},
                   {"spec_decode": "pruned", "spec_k": 3,
                    "expert_mask": mask}):
        eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                          prefill_chunk=8, **kwargs)
        eng.generate(_requests(cfg, n=2))
        assert set(eng.latency_stats()) <= set(METRICS_SCHEMA)
        assert set(eng.metrics()) <= set(METRICS_SCHEMA)


def test_schema_kinds_are_closed():
    assert {s.kind for s in METRICS_SCHEMA.values()} <= {
        "histogram", "gauge", "counter"}
    assert all(s.doc for s in METRICS_SCHEMA.values())


# ---------------------------------------------------------------------------
# disabled path: zero allocations, shared singletons
# ---------------------------------------------------------------------------


def test_disabled_tracer_allocates_no_spans(moe, monkeypatch):
    """The engine default is the shared NULL_TRACER and a full serving
    run constructs zero Span objects (trace points cost one lookup +
    one call)."""
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8)
    assert eng.tracer is NULL_TRACER
    assert eng.tracer.span("decode") is NULL_SPAN
    assert eng.tracer.span("x") is eng.tracer.span("y")

    def boom(*a, **k):
        raise AssertionError("Span allocated with tracing disabled")

    monkeypatch.setattr(telemetry.Span, "__init__", boom)
    outs = eng.generate(_requests(cfg, n=3))
    assert all(len(o) > 0 for o in outs)


def test_null_span_protocol():
    with NULL_SPAN as sp:
        assert sp is NULL_SPAN
        payload = object()
        assert sp.fence(payload) is payload
        sp.set(anything=1)


# ---------------------------------------------------------------------------
# traced == untraced token streams (per engine family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kwargs", [
    {},                                       # paged + interleaved
    {"schedule": "blocking"},
    {"prefix_cache": True},
    {"spec": True},
], ids=["paged", "blocking", "prefix", "spec"])
def test_tracing_leaves_streams_bit_identical(moe, kwargs):
    cfg, params = moe
    kwargs = dict(kwargs)
    if kwargs.pop("spec", False):
        mask = np.ones(cfg.n_experts, np.float32)
        mask[-2:] = 0.0
        kwargs.update(spec_decode="pruned", spec_k=3, expert_mask=mask)
    reqs = _requests(cfg, n=4, seed=11)

    def run(trace):
        eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                          prefill_chunk=8, page_size=8, seed=7,
                          trace=trace, **kwargs)
        return eng.generate([Request(r.prompt.copy(), r.max_new_tokens)
                             for r in reqs]), eng

    refs, _ = run(None)
    # fence_rate=1.0 blocks on every registered dispatch — the
    # strongest perturbation tracing can apply
    outs, eng = run(Tracer(fence_rate=1.0))
    for ref, out in zip(refs, outs):
        np.testing.assert_array_equal(ref, out)
    assert eng.tracer.n_spans > 0
    assert eng.tracer.n_fences > 0


# ---------------------------------------------------------------------------
# trace structure: validity + reconciliation with latency_stats
# ---------------------------------------------------------------------------


def test_chrome_trace_valid_and_reconciles(moe, tmp_path):
    """The exported trace passes tools/validate_trace.py AND the
    retroactive lifecycle spans carry exactly the floats the scheduler
    pooled into its latency windows — traces and latency_stats() are
    two views of the same stamps, not two clocks."""
    cfg, params = moe
    tracer = Tracer()
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                      prefill_chunk=8, trace=tracer)
    reqs = _requests(cfg, n=4, seed=5)
    eng.generate(reqs)

    trace = tracer.chrome_trace()
    vt = _load_validate_trace()
    assert vt.validate(trace) == []
    out = tmp_path / "trace.json"
    tracer.export(str(out))
    assert vt.main([str(out)]) == 0

    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"admission", "decode", "prefill_chunk", "prefill"} <= names

    req_spans = [e for e in trace["traceEvents"]
                 if e["ph"] == "X" and e["name"].startswith("request rid=")]
    assert len(req_spans) == len(reqs)
    sched = eng.scheduler
    ttfts = sorted(e["args"]["ttft_s"] for e in req_spans)
    assert ttfts == sorted(sched._ttft)          # exact floats
    gaps = sorted(g for e in req_spans for g in e["args"]["itl_gaps"])
    assert gaps == sorted(sched._itl)
    # span durations are the same stamps scaled to microseconds
    for e in req_spans:
        assert e["dur"] == pytest.approx(
            (e["args"]["prefill_s"] + e["args"]["decode_s"]) * 1e6)


def test_queue_span_and_tracks():
    """Retroactive lifecycle spans land on the right tracks and nest by
    time containment (fake scheduler stamps, no engine)."""
    from repro.serving.scheduler import Scheduler

    clock = _fake_clock()
    tracer = Tracer(clock=clock)
    sched = Scheduler()
    sched.on_finish = tracer.request_done
    rid = sched.submit(Request(np.array([1, 2, 3], np.int32), 2),
                       now=10.0)
    sched.admit(slot=1, now=10.5)
    sched.activate(rid, now=11.0)
    sched.on_token(rid, 4, now=11.25)
    assert sched.on_token(rid, 5, now=11.5)

    by_name = {e["name"]: e for e in tracer.events if e["ph"] == "X"}
    assert by_name[f"queue rid={rid}"]["tid"] == tracer._tids["queue"]
    lane = tracer._tids["lane 1"]
    assert by_name[f"request rid={rid}"]["tid"] == lane
    req = by_name[f"request rid={rid}"]
    for child in ("prefill", "decode"):
        assert by_name[child]["tid"] == lane
        assert by_name[child]["ts"] >= req["ts"]
        assert (by_name[child]["ts"] + by_name[child]["dur"]
                <= req["ts"] + req["dur"] + 1e-6)
    assert req["args"]["n_tokens"] == 2
    assert req["args"]["ttft_s"] == pytest.approx(1.25)


def test_validate_trace_catches_malformed():
    vt = _load_validate_trace()
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 0, "tid": 0, "ts": 1.0},  # no dur
        {"ph": "Z", "name": "b", "pid": 0, "tid": 0},             # bad ph
        {"ph": "X", "name": "c", "pid": 0, "tid": 9,              # unnamed
         "ts": 0.0, "dur": 1.0},                                  # tid
    ]}
    errs = vt.validate(bad)
    assert len(errs) >= 3
    assert vt.validate({"traceEvents": []}) == []
    assert vt.validate([]) != []                                  # not dict


# ---------------------------------------------------------------------------
# fence sampling: deterministic accumulator
# ---------------------------------------------------------------------------


def test_fence_accumulator_deterministic():
    tracer = Tracer(fence_rate=0.5, clock=_fake_clock())
    payload = np.zeros(1, np.float32)
    for _ in range(6):
        with tracer.span("d") as sp:
            sp.fence(payload)
    # acc: .5, 1.0*, .5, 1.0*, .5, 1.0* -> every 2nd close fences
    assert tracer.n_fences == 3
    fenced = [bool(e["args"].get("fenced"))
              for e in tracer.events if e["ph"] == "X"]
    assert fenced == [False, True] * 3

    off = Tracer(fence_rate=0.0, clock=_fake_clock())
    with off.span("d") as sp:
        sp.fence(payload)
    assert off.n_fences == 0

    always = Tracer(fence_rate=1.0, clock=_fake_clock())
    for _ in range(3):
        with always.span("d") as sp:
            sp.fence(payload)
        with always.span("no-payload"):
            pass                    # nothing registered -> never fences
    assert always.n_fences == 3

    with pytest.raises(ValueError):
        Tracer(fence_rate=1.5)


# ---------------------------------------------------------------------------
# stage timelines
# ---------------------------------------------------------------------------


def test_stage_timeline_requires_full_stamps():
    class St:
        t_submit, t_admit, t_active, t_done = 1.0, 2.0, 3.5, 6.0
        t_first_token = 4.0
        tokens = [7, 8, 9]

    tl = stage_timeline(St())
    assert tl == {"queue_s": 1.0, "prefill_s": 1.5, "decode_s": 2.5,
                  "total_s": 5.0, "ttft_s": 3.0, "n_tokens": 3}

    class Canceled(St):
        t_done = None

    class NeverAdmitted(St):
        t_admit = None

    assert stage_timeline(Canceled()) is None
    assert stage_timeline(NeverAdmitted()) is None


def test_frontend_stream_timeline(moe):
    """AsyncFrontend publishes the stage split on the TokenStream at
    completion."""
    import asyncio

    from repro.serving.frontend import AsyncFrontend

    cfg, params = moe

    async def main():
        eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                          prefill_chunk=8)
        async with AsyncFrontend(eng) as fe:
            stream = await fe.submit(
                Request(np.array([1, 2, 3, 4], np.int32), 5))
            toks = await stream.drain()
            return stream, toks

    stream, toks = asyncio.run(main())
    tl = stream.timeline
    assert tl is not None
    assert tl["n_tokens"] == len(toks)
    assert tl["queue_s"] >= 0 and tl["prefill_s"] >= 0
    assert tl["decode_s"] >= 0 and tl["ttft_s"] > 0
    assert tl["total_s"] == pytest.approx(
        tl["queue_s"] + tl["prefill_s"] + tl["decode_s"])


# ---------------------------------------------------------------------------
# workload traces: record -> dump -> load roundtrip, committed example
# ---------------------------------------------------------------------------


def test_workload_roundtrip(tmp_path):
    tracer = Tracer(clock=_fake_clock(start=5.0, step=0.25))
    tracer.record_request(0, np.array([3, 1, 4, 1, 5], np.int32), 8)
    tracer.record_request(1, [2, 7, 1], 4, temperature=0.7)
    path = tmp_path / "wl.jsonl"
    tracer.dump_workload(str(path))

    back = load_workload(str(path))
    assert [r["prompt_len"] for r in back] == [5, 3]
    assert [r["max_new_tokens"] for r in back] == [8, 4]
    assert back[1]["temperature"] == 0.7
    assert back[0]["arrival_offset_s"] < back[1]["arrival_offset_s"]
    assert back[0]["seed"] == prompt_seed([3, 1, 4, 1, 5])


def test_prompt_seed_content_sensitive():
    assert prompt_seed([1, 2, 3]) == prompt_seed(
        np.array([1, 2, 3], np.int32))
    assert prompt_seed([1, 2, 3]) != prompt_seed([1, 2, 4])
    assert prompt_seed([1, 2, 3]) != prompt_seed([1, 2])


def test_load_workload_validation(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"arrival_offset_s": 0.0, "prompt_len": 4})
                 + "\n")
    with pytest.raises(ValueError, match="missing"):
        load_workload(str(p))
    p.write_text(json.dumps({"arrival_offset_s": 0.0, "prompt_len": 0,
                             "max_new_tokens": 4, "seed": 1}) + "\n")
    with pytest.raises(ValueError, match="non-positive"):
        load_workload(str(p))
    p.write_text("\n")
    with pytest.raises(ValueError, match="empty"):
        load_workload(str(p))
    # out-of-order arrivals are sorted, blank lines skipped
    recs = [{"arrival_offset_s": t, "prompt_len": 2,
             "max_new_tokens": 2, "seed": 0} for t in (0.5, 0.1)]
    p.write_text("\n".join(json.dumps(r) for r in recs) + "\n\n")
    assert [r["arrival_offset_s"]
            for r in load_workload(str(p))] == [0.1, 0.5]


def test_committed_bursty_trace():
    """The checked-in replay trace stays loadable, bursty, and sized
    for the trace-smoke engine config (max_len=64)."""
    recs = load_workload(str(ROOT / "benchmarks" / "traces"
                             / "bursty_small.jsonl"))
    assert len(recs) == 24
    assert all(r["prompt_len"] + r["max_new_tokens"] <= 64 for r in recs)
    arrivals = np.array([r["arrival_offset_s"] for r in recs])
    gaps = np.diff(np.concatenate([[0.0], arrivals]))
    cv = float(np.std(gaps) / np.mean(gaps))
    assert cv > 1.5          # bursty: far above Poisson's CV ~= 1


# ---------------------------------------------------------------------------
# sanitizer compatibility (CI stress job runs REPRO_SANITIZE=1)
# ---------------------------------------------------------------------------


@pytest.fixture
def sanitized():
    sanitizer.enable(True)
    try:
        yield
    finally:
        sanitizer.clear_override()


@pytest.mark.stress
def test_traced_run_under_sanitizer(moe, sanitized):
    """Tracing (including fenced closes) under the dispatch-race
    sanitizer: no DispatchRaceError, streams identical to untraced."""
    cfg, params = moe
    reqs = _requests(cfg, n=4, seed=17)

    def run(trace):
        eng = ServeEngine(params, cfg, max_len=32, max_batch=2,
                          prefill_chunk=8, page_size=8, trace=trace)
        return eng.generate([Request(r.prompt.copy(), r.max_new_tokens)
                             for r in reqs])

    refs = run(None)
    outs = run(Tracer(fence_rate=0.5))
    for ref, out in zip(refs, outs):
        np.testing.assert_array_equal(ref, out)
