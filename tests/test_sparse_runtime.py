"""Sparse pruned-artifact runtime: plan/pack/execute contracts.

The load-bearing property is the **exactness chain**: the pool stores the
masked weight values verbatim (pack is pure data movement), ``densify``
reconstructs elementwise-equal dense matrices (gather + transpose +
inverse permutation — no arithmetic), and the "exact" execute mode
replays the dense path's einsum on that operand — so packed serving is
*bit-identical* to dense-masked serving with the plan's masks.  The
FLOP-skipping paths (jnp gather, Pallas kernel in interpret mode) are
pinned allclose against the same oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.configs import get_config, reduced
from repro.core.stun import unstructured_only
from repro.core.unstructured import _get_path
from repro.data.synthetic import calibration_batches
from repro.models import abstract_params, decode_step_ragged, forward
from repro.models import init_cache
from repro.models import param as pm
from repro.serving import Request, ServeEngine
from repro.serving.engine import apply_weight_masks

BLOCK = (8, 8)


def _tiny_moe(seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def pruned():
    """(cfg, params, masks, weights) with stage-2 masks on the tiny MoE."""
    cfg, params = _tiny_moe()
    batches = calibration_batches(cfg, n_batches=2)
    _, masks, _ = unstructured_only(params, cfg, batches,
                                    target_sparsity=0.3, method="owl")
    return cfg, params, masks, sparse.ffn_weights_from_params(params, cfg)


@pytest.fixture(scope="module")
def planned(pruned):
    """A representative full plan: permutation + expert fold + block
    re-rounding, packed and installed."""
    cfg, params, masks, weights = pruned
    em = np.ones(cfg.n_experts, np.float32)
    em[-2:] = 0.0
    plan = sparse.plan_sparse_ffn(masks, weights, block=BLOCK,
                                  expert_mask=em,
                                  target_block_sparsity=0.4)
    packed, report = sparse.pack_sparse_ffn(params, cfg, plan)
    base_masks = dict(masks)
    base_masks.update(plan.element_masks())
    dense_masked = apply_weight_masks(params, cfg, base_masks)
    return cfg, params, em, plan, packed, report, base_masks, dense_masked


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------


def test_plan_block_mask_matches_element_mask(planned):
    cfg, params, em, plan, *_ = planned
    for (l, path), mp in plan.matrices.items():
        bk, bn = mp.block
        m = mp.permuted_mask()
        E, K, N = m.shape
        blocks = m.reshape(E, K // bk, bk, N // bn, bn).any(axis=(2, 4))
        np.testing.assert_array_equal(blocks, mp.block_mask)
        assert 0.0 <= mp.block_sparsity <= 1.0


def test_plan_expert_mask_folding(planned):
    """Pruned experts contribute only dead blocks: block sparsity >= the
    expert drop fraction, and their element masks are all-False."""
    cfg, params, em, plan, *_ = planned
    for mp in plan.matrices.values():
        assert not mp.element_mask[-2:].any()
        assert not mp.block_mask[-2:].any()
        assert mp.block_sparsity >= 0.25
    assert plan.report["block_sparsity"] >= 0.25


def test_plan_reround_preserves_nonzeros(pruned):
    """Block re-rounding reallocates the element budget — the total
    kept-element count must not change, while dead blocks increase."""
    cfg, params, masks, weights = pruned
    base = sparse.plan_sparse_ffn(masks, weights, block=BLOCK)
    rer = sparse.plan_sparse_ffn(masks, weights, block=BLOCK,
                                 target_block_sparsity=0.25)
    for key in base.matrices:
        n0 = int(base.matrices[key].element_mask.sum())
        n1 = int(rer.matrices[key].element_mask.sum())
        assert n0 == n1, key
    assert rer.report["block_sparsity"] > base.report["block_sparsity"]
    assert rer.report["blocks_rerounded"] > 0
    # the target is a ceiling request: achieved yield may fall short when
    # revival capacity (pruned slots in surviving blocks) runs out, but
    # must get most of the way there at this sparsity
    assert rer.report["block_sparsity"] >= 0.20


def test_plan_nm_rounding_subsets_mask(pruned):
    cfg, params, masks, weights = pruned
    plain = sparse.plan_sparse_ffn(masks, weights, block=BLOCK)
    nm = sparse.plan_sparse_ffn(masks, weights, block=BLOCK, nm=(2, 4))
    for key in plain.matrices:
        m_plain = plain.matrices[key].element_mask
        m_nm = nm.matrices[key].element_mask
        assert not (m_nm & ~m_plain).any(), "N:M must never revive"
        # keep-at-most-n per m consecutive inputs along the K axis
        E, K, N = m_nm.shape
        grp = m_nm.reshape(E, K // 4, 4, N).sum(axis=2)
        assert grp.max() <= 2


def test_plan_requires_weights_for_lossy_transforms(pruned):
    cfg, params, masks, _ = pruned
    with pytest.raises(ValueError, match="weights"):
        sparse.plan_sparse_ffn(masks, None, nm=(2, 4))
    with pytest.raises(ValueError, match="weights"):
        sparse.plan_sparse_ffn(masks, None, target_block_sparsity=0.5)
    with pytest.raises(ValueError, match="divide"):
        sparse.plan_sparse_ffn(masks, None, block=(7, 8))


# ---------------------------------------------------------------------------
# pack
# ---------------------------------------------------------------------------


def test_pack_sentinel_and_index_invariants(planned):
    cfg, params, em, plan, packed, report, *_ = planned
    for name, entry in packed.items():
        pool, index = np.asarray(entry["pool"]), np.asarray(entry["index"])
        L = index.shape[0]
        for l in range(L):
            assert not pool[l, 0].any(), "slot 0 must be the zero sentinel"
            mp = plan.matrices[(l, ("moe", name))]
            # index is 0 exactly on dead blocks, and live slots are
            # unique (each block owns its storage)
            np.testing.assert_array_equal(index[l] > 0, mp.block_mask)
            live = index[l][index[l] > 0]
            assert len(np.unique(live)) == len(live)
    assert report["packed_bytes"] < report["dense_bytes"]
    assert report["bytes_ratio"] < 0.95


def test_densify_is_bitwise_masked_weight(planned):
    """The whole exactness chain: pool -> densify == W * planned_mask."""
    cfg, params, em, plan, packed, *_ = planned
    installed = sparse.install_sparse_ffn(params, cfg, packed)
    for name in ("we_gate", "we_up", "we_down"):
        W = np.asarray(_get_path(params["layers"], ("moe", name)))
        entry = installed["layers"]["moe"][name]
        # the runtime entry strips fully-dead experts (2 of 8 here) —
        # densify_full scatters them back as exact zeros
        assert "alive_e" in entry and entry["index"].shape[1] == 6
        for l in range(cfg.n_layers):
            rt = {k: v[l] for k, v in entry.items()}
            got = np.asarray(sparse.densify_full(rt, cfg.n_experts))
            want = W[l] * plan.matrices[(l, ("moe", name))].element_mask
            np.testing.assert_array_equal(got, want)


def test_install_drops_identity_perms(pruned):
    cfg, params, masks, weights = pruned
    plan = sparse.plan_sparse_ffn(masks, weights, block=BLOCK,
                                  permute=False)
    packed, _ = sparse.pack_sparse_ffn(params, cfg, plan)
    installed = sparse.install_sparse_ffn(params, cfg, packed)
    entry = installed["layers"]["moe"]["we_gate"]
    assert "perm_k" not in entry and "inv_perm_n" not in entry
    # and densify still reconstructs exactly
    rt = {k: v[0] for k, v in entry.items()}
    W = np.asarray(params["layers"]["moe"]["we_gate"])[0]
    np.testing.assert_array_equal(
        np.asarray(sparse.densify(rt)),
        W * plan.matrices[(0, ("moe", "we_gate"))].element_mask)


def test_install_keeps_perms_when_only_some_layers_permute(pruned):
    """Key presence is pytree structure, so the identity-perm drop must
    be uniform across stacked layers: if any layer's permutation is
    real, every layer stores one (regression: per-layer dropping let
    the stacking comprehension discard or KeyError on the others)."""
    cfg, params, masks, weights = pruned
    plan = sparse.plan_sparse_ffn(masks, weights, block=BLOCK,
                                  permute=True)
    packed, _ = sparse.pack_sparse_ffn(params, cfg, plan)
    name = "we_gate"
    # force layer 0's permutations to identity, keep layer 1's real
    E, K = np.asarray(packed[name]["perm_k"]).shape[1:]
    N = np.asarray(packed[name]["perm_n"]).shape[-1]
    pk = np.asarray(packed[name]["perm_k"]).copy()
    pn = np.asarray(packed[name]["perm_n"]).copy()
    assert not np.array_equal(pk[1], np.broadcast_to(np.arange(K), (E, K)))
    pk[0] = np.arange(K, dtype=pk.dtype)
    pn[0] = np.arange(N, dtype=pn.dtype)
    forced = dict(packed)
    forced[name] = {**packed[name], "perm_k": pk, "perm_n": pn}
    # ...and make the plan's masks consistent with the forced perms:
    # simplest is to check install-level reconstruction directly
    installed = sparse.install_sparse_ffn(params, cfg, forced)
    entry = installed["layers"]["moe"][name]
    # perm_k must survive for BOTH layers (layer 1's is real); perm_n is
    # identity in every layer here (per-output pruning gives uniform
    # column occupancy) so its drop is legitimate
    assert "perm_k" in entry
    for l in range(cfg.n_layers):
        rt = {k: v[l] for k, v in entry.items()}
        pool, index = np.asarray(rt["pool"]), np.asarray(rt["index"])
        # reconstruct by hand from the forced artifact and compare
        got = np.asarray(sparse.densify_full(rt, cfg.n_experts))
        bk, bn = pool.shape[-2:]
        Kb, Nb = index.shape[-2:]
        for e in range(E):
            wperm = pool[index[e]].transpose(0, 2, 1, 3).reshape(
                Kb * bk, Nb * bn)
            want = np.empty_like(wperm)
            want[np.ix_(pk[l, e], pn[l, e])] = wperm
            np.testing.assert_array_equal(got[e], want)


def test_pack_rejects_partial_plans(pruned):
    cfg, params, masks, weights = pruned
    partial = {k: v for k, v in masks.items() if k[0] == 0}
    plan = sparse.plan_sparse_ffn(partial, weights, block=BLOCK)
    with pytest.raises(ValueError, match="missing layer"):
        sparse.pack_sparse_ffn(params, cfg, plan)


# ---------------------------------------------------------------------------
# execute
# ---------------------------------------------------------------------------

SPECS_X = {
    "bsd,edf->bsef": lambda rs, cfg: rs.randn(2, 3, cfg.d_model),
    "gecd,edf->gecf": lambda rs, cfg: rs.randn(2, cfg.n_experts, 3,
                                               cfg.d_model),
    "bsef,efd->bsed": lambda rs, cfg: rs.randn(2, 3, cfg.n_experts,
                                               cfg.moe_d_ff),
    "gecf,efd->gecd": lambda rs, cfg: rs.randn(2, cfg.n_experts, 3,
                                               cfg.moe_d_ff),
}


def _entry_for(planned, spec, layer=0):
    cfg, params, em, plan, packed, *_ = planned
    name = "we_down" if spec.split(",")[1].startswith("ef") else "we_gate"
    installed = sparse.install_sparse_ffn(params, cfg, packed)
    return {k: v[layer]
            for k, v in installed["layers"]["moe"][name].items()}


@pytest.mark.parametrize("spec", sorted(SPECS_X))
def test_exact_mode_is_bitwise(planned, spec):
    cfg, *_ = planned
    entry = _entry_for(planned, spec)
    x = jnp.asarray(SPECS_X[spec](np.random.RandomState(0), cfg),
                    jnp.float32)
    want = jnp.einsum(spec, x, sparse.densify_full(entry, cfg.n_experts))
    got = sparse.expert_einsum(spec, x, entry, n_experts=cfg.n_experts,
                               force="exact")
    assert bool(jnp.all(want == got))


@pytest.mark.parametrize("mode", ["gather", "interpret"])
@pytest.mark.parametrize("spec", sorted(SPECS_X))
def test_flop_skipping_modes_allclose(planned, spec, mode):
    cfg, *_ = planned
    entry = _entry_for(planned, spec)
    x = jnp.asarray(SPECS_X[spec](np.random.RandomState(1), cfg),
                    jnp.float32)
    want = np.asarray(jnp.einsum(spec, x,
                                 sparse.densify_full(entry, cfg.n_experts)))
    got = np.asarray(sparse.expert_einsum(spec, x, entry,
                                          n_experts=cfg.n_experts,
                                          force=mode))
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_execute_rejects_unknown(planned):
    entry = _entry_for(planned, "bsd,edf->bsef")
    x = jnp.zeros((1, 1, entry["index"].shape[1] * entry["pool"].shape[-2]))
    with pytest.raises(ValueError, match="unsupported"):
        sparse.expert_einsum("bd,edf->bef", x, entry)
    with pytest.raises(ValueError, match="mode"):
        sparse.expert_einsum("bsd,edf->bsef", x, entry, n_experts=8,
                             force="fused")
    # the "bsd" spec carries no expert axis: with dead experts stripped,
    # the caller must say how many experts the output has
    with pytest.raises(ValueError, match="n_experts"):
        sparse.expert_einsum("bsd,edf->bsef", x, entry)


# ---------------------------------------------------------------------------
# model + engine integration (the serving oracle's fast-tier edition;
# the full {layout} x {spec} matrix lives in test_disaggregation.py)
# ---------------------------------------------------------------------------


def test_forward_and_decode_bitwise_vs_dense_masked(planned):
    cfg, params, em, plan, packed, report, base_masks, dense_masked = planned
    installed = sparse.install_sparse_ffn(dense_masked, cfg, packed)
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(2).randint(0, cfg.vocab, (2, 8)))}
    a = forward(dense_masked, cfg, batch, expert_mask=em)
    b = forward(installed, cfg, batch, expert_mask=em)
    assert bool(jnp.all(a == b)), "packed forward must be bit-identical"

    cache = init_cache(cfg, 2, 16)
    toks = jnp.asarray([[3], [7]], jnp.int32)
    lens = jnp.asarray([0, 5], jnp.int32)
    la, _ = decode_step_ragged(dense_masked, cfg, cache, toks, lens,
                               expert_mask=em)
    lb, _ = decode_step_ragged(installed, cfg, cache, toks, lens,
                               expert_mask=em)
    assert bool(jnp.all(la == lb))


def test_engine_packed_token_identical(planned):
    cfg, params, em, plan, packed, report, base_masks, _ = planned
    rs = np.random.RandomState(3)
    reqs = lambda: [Request(np.array(p, np.int32), n)  # noqa: E731
                    for p, n in zip(
                        [rs2.randint(0, cfg.vocab, 9) for rs2 in
                         [np.random.RandomState(i) for i in range(4)]],
                        [6, 4, 7, 5])]
    kwargs = dict(max_len=32, max_batch=3, prefill_chunk=8,
                  expert_mask=em, weight_masks=base_masks)
    outs_dense = ServeEngine(params, cfg, **kwargs).generate(reqs())
    eng = ServeEngine(params, cfg, sparse_weights=packed, **kwargs)
    outs_packed = eng.generate(reqs())
    for a, b in zip(outs_dense, outs_packed):
        np.testing.assert_array_equal(a, b)


def test_engine_sparse_validation(planned):
    cfg, params, *_ = planned
    with pytest.raises(ValueError, match="sparse_exec"):
        ServeEngine(params, cfg, max_len=16, sparse_exec="exact")
    dense_cfg = reduced(get_config("qwen2-7b"))
    with pytest.raises(ValueError, match="family"):
        ServeEngine(params, dense_cfg, max_len=16, sparse_weights={})


# ---------------------------------------------------------------------------
# checkpoint artifact roundtrip
# ---------------------------------------------------------------------------


def test_checkpoint_artifact_roundtrip(planned, tmp_path):
    from repro.checkpoint import (masks_from_tree, masks_to_tree,
                                  restore_checkpoint, save_checkpoint)

    cfg, params, em, plan, packed, report, base_masks, _ = planned
    tree = {"params": jax.tree.map(np.asarray, params),
            "masks": masks_to_tree(base_masks),
            "sparse_ffn": packed}
    save_checkpoint(str(tmp_path), 7, tree)
    step, back = restore_checkpoint(str(tmp_path))
    assert step == 7
    masks_back = masks_from_tree(back["masks"])
    assert set(masks_back) == set(base_masks)
    for key in base_masks:
        np.testing.assert_array_equal(masks_back[key], base_masks[key])
    # the restored artifact installs and reconstructs bit-identically
    installed = sparse.install_sparse_ffn(params, cfg, back["sparse_ffn"])
    for name in ("we_gate", "we_up", "we_down"):
        W = np.asarray(params["layers"]["moe"][name])
        for l in range(cfg.n_layers):
            rt = {k: v[l] for k, v in
                  installed["layers"]["moe"][name].items()}
            np.testing.assert_array_equal(
                np.asarray(sparse.densify_full(rt, cfg.n_experts)),
                W[l] * plan.matrices[(l, ("moe", name))].element_mask)
    assert sparse.sparse_ffn_bytes(back["sparse_ffn"]) == \
        report["packed_bytes"]
