"""Per-arch smoke tests: reduced config, one forward + train step + decode
step on CPU, asserting output shapes and no NaNs (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import (abstract_params, decode_step, forward, init_cache,
                          loss_fn)
from repro.models import param as pm
from repro.optim import AdamWConfig, adamw_init, adamw_update

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    t = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    if cfg.frontend_stub:
        e = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.bfloat16)
        return {"embeds": e, "labels": t}
    return {"tokens": t, "labels": t}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(get_config(arch))
    params = pm.init_params(abstract_params(cfg), RNG)
    batch = _batch(cfg)
    logits = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    params = pm.init_params(abstract_params(cfg), RNG)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert jnp.isfinite(loss)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)
    opt = adamw_init(params)
    new_params, _, m = adamw_update(params, grads, opt, AdamWConfig())
    # params changed
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(arch):
    cfg = reduced(get_config(arch))
    params = pm.init_params(abstract_params(cfg), RNG)
    B = 2
    cache = init_cache(cfg, B, 16)
    toks = jnp.zeros((B, 1), jnp.int32)
    for t in range(3):
        logits, cache = decode_step(params, cfg, cache, toks, jnp.int32(t))
        assert logits.shape == (B, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen2-7b", "olmoe-1b-7b",
                                  "falcon-mamba-7b", "recurrentgemma-2b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward logits."""
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32",
                              moe_impl="dense")
    params = pm.init_params(abstract_params(cfg), RNG)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    B, S = 2, 8
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    full = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cfg, cache, toks[:, t: t + 1],
                                jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 2e-3, err
