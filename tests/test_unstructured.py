"""Wanda / OWL / magnitude / N:M mask semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (mask_per_output, nm_rounding, owl_layer_sparsities,
                        sparsify_model, unstructured_only, wanda_scores)
from repro.data import calibration_batches
from repro.models import abstract_params, loss_fn
from repro.models import param as pm

RNG = jax.random.PRNGKey(0)


def test_mask_per_output_exact_sparsity():
    s = np.random.RandomState(0).rand(64, 16).astype(np.float32)
    m = mask_per_output(s, 0.5, in_axis=0)
    # exactly 32 pruned per column
    assert (m.sum(axis=0) == 32).all()


def test_mask_keeps_highest_scores():
    s = np.arange(8, dtype=np.float32)[:, None] * np.ones((8, 3), np.float32)
    m = mask_per_output(s, 0.5, in_axis=0)
    assert m[:4].sum() == 0 and m[4:].all()


def test_mask_tuple_axis():
    s = np.random.RandomState(0).rand(4, 8, 6).astype(np.float32)
    m = mask_per_output(s, 0.25, in_axis=(0, 1))
    assert m.shape == s.shape
    assert (m.reshape(32, 6).sum(axis=0) == 24).all()


def test_wanda_scores_scale_with_activation():
    W = np.ones((4, 4), np.float32)
    xn = np.array([1.0, 2, 3, 4], np.float32)
    s = wanda_scores(W, xn, 0)
    np.testing.assert_allclose(s[:, 0], xn)


def test_nm_rounding_pattern():
    s = np.random.RandomState(0).rand(16, 8).astype(np.float32)
    m = nm_rounding(s, in_axis=0, n=2, m=4)
    grp = m.reshape(4, 4, 8)
    assert (grp.sum(axis=1) == 2).all()  # exactly 2 of every 4 kept


def test_owl_budget_and_bounds():
    ratios = [0.1, 0.5, 0.2, 0.9]
    s = owl_layer_sparsities(ratios, target=0.6, lam=0.08)
    assert abs(s.mean() - 0.6) < 1e-9
    assert (s <= 0.6 + 0.08 + 1e-9).all() and (s >= 0.6 - 0.08 - 1e-9).all()
    # more outliers -> lower sparsity (keep more)
    assert s[3] == s.min() and s[0] == s.max()


def test_owl_uniform_when_no_signal():
    s = owl_layer_sparsities([0.3, 0.3, 0.3], target=0.5)
    np.testing.assert_allclose(s, 0.5)


@pytest.mark.parametrize("method", ["wanda", "owl", "magnitude"])
def test_sparsify_model_achieves_target(method):
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b"), n_layers=2),
                              dtype="float32")
    params = pm.init_params(abstract_params(cfg), RNG)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    batches = calibration_batches(cfg, n_batches=1, batch=2, seq=16)
    new_params, masks, rep = unstructured_only(params, cfg, batches,
                                               target_sparsity=0.5,
                                               method=method)
    assert abs(rep["achieved_sparsity"] - 0.5) < 0.02, rep
    # masked weights actually zero
    for (l, path), m in masks.items():
        W = new_params["layers"]
        for k in ("attn", "mlp"):
            pass
    # model still runs
    loss = loss_fn(new_params, cfg, batches[0])
    assert jnp.isfinite(loss)


def test_sparsify_moe_per_expert_groups():
    cfg = dataclasses.replace(
        reduced(get_config("olmoe-1b-7b"), n_layers=1, n_experts=4, top_k=2),
        moe_impl="dense", dtype="float32")
    params = pm.init_params(abstract_params(cfg), RNG)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    batches = calibration_batches(cfg, n_batches=1, batch=2, seq=16)
    new_params, masks, rep = unstructured_only(params, cfg, batches,
                                               target_sparsity=0.5,
                                               method="wanda")
    m = masks[(0, ("moe", "we_gate"))]
    assert m.shape == (4, cfg.d_model, cfg.moe_d_ff)
    # per (expert, output) group sparsity exact
    kept = m.sum(axis=1)
    assert (kept == cfg.d_model // 2).all()
