"""Continuous-batching serving engine tests.

Covers the rebuilt serving stack: single-dispatch chunked prefill against
the token-by-token decode reference, pad invariance for mixed-length
batches, runtime expert_mask vs compacted-checkpoint equivalence, slot
reuse across request waves, and per-request termination.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.expert_prune import expert_prune_moe
from repro.models import (abstract_params, decode_step, init_cache,
                          prefill_step)
from repro.models import param as pm
from repro.serving import Request, ServeEngine, SlotKVCache


def _tiny_moe(n_experts=8, top_k=2, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2,
                  n_experts=n_experts, top_k=top_k)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


def _tiny_dense(seed=0):
    cfg = reduced(get_config("qwen2-7b"), n_layers=2)
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    return cfg, jax.tree.map(lambda x: x.astype(jnp.float32), params)


@pytest.fixture(scope="module")
def moe():
    return _tiny_moe()


# ---------------------------------------------------------------------------
# chunked prefill vs token-by-token reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,C", [(13, 8), (16, 8), (5, 16)])
def test_prefill_matches_token_by_token_reference(moe, S, C):
    cfg, params = moe
    T = 32
    rs = np.random.RandomState(S)
    toks = rs.randint(0, cfg.vocab, (1, S)).astype(np.int32)

    cache_ref = init_cache(cfg, 1, T)
    ref = []
    for t in range(S):
        lg, cache_ref = decode_step(params, cfg, cache_ref,
                                    jnp.asarray(toks[:, t: t + 1]),
                                    jnp.int32(t))
        ref.append(np.asarray(lg[0]))
    ref = np.stack(ref)

    cache = init_cache(cfg, 3, T)     # prefill lands in slot 1 of 3
    n_pad = ((S + C - 1) // C) * C
    buf = np.zeros(n_pad, np.int32)
    buf[:S] = toks[0]
    chunks = []
    for c0 in range(0, n_pad, C):
        lg, cache = prefill_step(params, cfg, cache,
                                 jnp.asarray(buf[None, c0: c0 + C]),
                                 jnp.int32(1), jnp.int32(c0))
        chunks.append(np.asarray(lg[0]))
    got = np.concatenate(chunks)[:S]

    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)
    # the written cache rows must match the reference cache exactly
    np.testing.assert_allclose(np.asarray(cache["k"][:, 1, :S]),
                               np.asarray(cache_ref["k"][:, 0, :S]),
                               atol=1e-5, rtol=1e-5)


def test_prefill_dispatch_count_independent_of_prompt_length(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=64, max_batch=1, prefill_chunk=16)
    eng.generate([Request(np.arange(3, dtype=np.int32) + 1, 1)])
    assert eng.prefill_dispatches == 1            # ceil(3/16)
    eng.prefill_dispatches = 0
    eng.generate([Request(np.arange(33, dtype=np.int32) % cfg.vocab, 1)])
    assert eng.prefill_dispatches == 3            # ceil(33/16), not 33


# ---------------------------------------------------------------------------
# pad invariance / mixed-length batches
# ---------------------------------------------------------------------------


def test_mixed_length_batch_is_pad_invariant(moe):
    cfg, params = moe
    rs = np.random.RandomState(0)
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in [(3, 4), (11, 6), (7, 5), (16, 3)]]
    eng = ServeEngine(params, cfg, max_len=48, max_batch=4, prefill_chunk=8)
    batched = eng.generate(reqs)
    for r, got in zip(reqs, batched):
        solo = ServeEngine(params, cfg, max_len=48, max_batch=1,
                           prefill_chunk=8)
        alone = solo.generate([Request(r.prompt, r.max_new_tokens)])[0]
        np.testing.assert_array_equal(got, alone)


def test_dense_family_serves(moe):
    cfg, params = _tiny_dense()
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2, prefill_chunk=8)
    outs = eng.generate([Request(np.array([1, 2, 3], np.int32), 4),
                         Request(np.array([5, 6], np.int32), 6)])
    assert outs[0].shape == (4,) and outs[1].shape == (6,)
    for o in outs:
        assert (o >= 0).all() and (o < cfg.vocab).all()


# ---------------------------------------------------------------------------
# pruned serving: runtime expert_mask == compacted checkpoint
# ---------------------------------------------------------------------------


def test_expert_mask_matches_compacted_model(moe):
    cfg, params = moe
    masked_p, _, keep, _ = expert_prune_moe(params, cfg, ratio=0.25,
                                            mode="mask")
    compact_p, compact_cfg, _, _ = expert_prune_moe(params, cfg, ratio=0.25,
                                                    mode="compact")
    rs = np.random.RandomState(3)
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), 6)
            for n in (5, 9)]
    e_mask = ServeEngine(jax.tree.map(jnp.asarray, masked_p), cfg,
                         max_len=32, max_batch=2, prefill_chunk=8,
                         expert_mask=keep)
    e_comp = ServeEngine(jax.tree.map(jnp.asarray, compact_p), compact_cfg,
                         max_len=32, max_batch=2, prefill_chunk=8)
    out_mask = e_mask.generate([Request(r.prompt, r.max_new_tokens)
                                for r in reqs])
    out_comp = e_comp.generate([Request(r.prompt, r.max_new_tokens)
                                for r in reqs])
    for a, b in zip(out_mask, out_comp):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# scheduler: slot reuse, per-request termination
# ---------------------------------------------------------------------------


def test_slot_reuse_across_request_waves(moe):
    cfg, params = moe
    rs = np.random.RandomState(7)
    specs = [(6, 5), (13, 9), (3, 2), (9, 7), (5, 4), (4, 8)]
    reqs = [Request(rs.randint(0, cfg.vocab, n).astype(np.int32), m)
            for n, m in specs]
    # 2 slots for 6 requests -> slots must be vacated and re-filled
    eng = ServeEngine(params, cfg, max_len=48, max_batch=2, prefill_chunk=8)
    outs = eng.generate(reqs)
    assert eng.cache.n_free == eng.cache.n_slots      # all returned
    for (n, m), o in zip(specs, outs):
        assert o.shape == (m,)
    # greedy determinism: same results generated one at a time
    for r, got in zip(reqs, outs):
        solo = ServeEngine(params, cfg, max_len=48, max_batch=1,
                           prefill_chunk=8)
        np.testing.assert_array_equal(
            got, solo.generate([Request(r.prompt, r.max_new_tokens)])[0])


def test_per_request_termination_no_post_eos(moe):
    cfg, params = moe
    rs = np.random.RandomState(11)
    prompt = rs.randint(0, cfg.vocab, 9).astype(np.int32)
    eng = ServeEngine(params, cfg, max_len=48, max_batch=2, prefill_chunk=8)
    free_run = eng.generate([Request(prompt, 8)])[0]
    eos = int(free_run[3])
    eng2 = ServeEngine(params, cfg, max_len=48, max_batch=2, prefill_chunk=8)
    stopped = eng2.generate([Request(prompt, 8, eos_id=eos)])[0]
    assert len(stopped) == 4 and stopped[-1] == eos
    assert not np.any(stopped[:-1] == eos)
    np.testing.assert_array_equal(stopped, free_run[:4])
    # a finished request stops burning decode steps: batchmate with
    # max_new=1 must not inflate the longer one's dispatches
    eng3 = ServeEngine(params, cfg, max_len=48, max_batch=2, prefill_chunk=8)
    outs = eng3.generate([Request(prompt, 1), Request(prompt, 6)])
    assert len(outs[0]) == 1 and len(outs[1]) == 6
    assert eng3.decode_dispatches == 5     # only the 6-token request decodes


def test_temperature_sampling_and_stats(moe):
    cfg, params = moe
    prompt = np.array([1, 2, 3, 4], np.int32)
    eng = ServeEngine(params, cfg, max_len=32, max_batch=2, prefill_chunk=8,
                      seed=5)
    outs = eng.generate([Request(prompt, 6, temperature=1.0),
                         Request(prompt, 6)])
    assert outs[0].shape == (6,) and outs[1].shape == (6,)
    assert (outs[0] < cfg.vocab).all() and (outs[0] >= 0).all()
    stats = eng.latency_stats()
    assert set(stats) == {"p50_latency_s", "p95_latency_s",
                          "p50_first_token_s", "p95_first_token_s",
                          "p50_inter_token_s", "p95_inter_token_s",
                          "p50_queue_s", "p95_queue_s",
                          "p50_prefill_s", "p95_prefill_s",
                          "p50_decode_s", "p95_decode_s",
                          "pages_in_use", "pages_total",
                          "page_utilization", "kv_fragmentation",
                          "lanes_prefilling", "prefill_pages_in_use",
                          "cache_hit_rate", "shared_pages", "cow_forks"}
    assert all(v >= 0 for v in stats.values())
    # all requests finished -> every page back in the pool
    assert stats["pages_in_use"] == 0 and stats["page_utilization"] == 0


def test_windowed_config_prefill_decode_consistent():
    """Sliding-window dense config: engine generation must equal a full
    forward() replay (prefill window mask and decode window mask agree)."""
    from repro.models import forward

    cfg = reduced(get_config("qwen2-7b"), n_layers=2)
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="full",
                              local_window=8)
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(2))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rs = np.random.RandomState(5)
    prompt = rs.randint(0, cfg.vocab, 13).astype(np.int32)

    seq = list(prompt)
    ref = []
    for _ in range(5):                       # teacher-forced full forward
        lg = forward(params, cfg, {"tokens": jnp.asarray([seq])})
        tok = int(jnp.argmax(lg[0, -1, : cfg.vocab]))
        ref.append(tok)
        seq.append(tok)

    eng = ServeEngine(params, cfg, max_len=32, max_batch=1, prefill_chunk=4)
    got = eng.generate([Request(prompt, 5)])[0]
    np.testing.assert_array_equal(got, np.asarray(ref, np.int32))


def test_weight_masks_match_presparsified_params(moe):
    """Serving dense params + stage-2 masks == serving the sparsified
    checkpoint (the runtime block-sparse pruned path)."""
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg, params = moe
    batches = calibration_batches(cfg, n_batches=2)
    sparse_p, masks, _ = unstructured_only(params, cfg, batches,
                                           target_sparsity=0.4,
                                           method="wanda")
    prompt = np.array([1, 2, 3, 4, 5], np.int32)
    e_pre = ServeEngine(jax.tree.map(jnp.asarray, sparse_p), cfg,
                        max_len=32, max_batch=1, prefill_chunk=8)
    e_masked = ServeEngine(params, cfg, max_len=32, max_batch=1,
                           prefill_chunk=8, weight_masks=masks)
    np.testing.assert_array_equal(e_pre.generate([Request(prompt, 6)])[0],
                                  e_masked.generate([Request(prompt, 6)])[0])


def test_slot_kv_cache_alloc_free():
    cfg, _ = _tiny_moe()
    c = SlotKVCache(cfg, n_slots=2, max_len=16)
    a, b = c.alloc(), c.alloc()
    assert {a, b} == {0, 1} and c.alloc() is None and c.n_free == 0
    c.seq_lens[a] = 5
    c.release(a)
    assert c.n_free == 1 and c.seq_lens[a] == 0
    assert c.alloc() == a


def test_max_len_guard(moe):
    cfg, params = moe
    eng = ServeEngine(params, cfg, max_len=16, max_batch=1, prefill_chunk=8)
    with pytest.raises(ValueError):
        eng.submit(Request(np.zeros(12, np.int32), 8))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(np.array([], np.int32), 4))
    assert eng.cache.n_free == eng.cache.n_slots   # nothing leaked


def test_prefill_chunk_overrunning_max_len_is_safe(moe):
    """Prompt whose chunk padding extends past max_len must not corrupt
    already-written cache rows (dynamic_update_slice clamps silently)."""
    cfg, params = moe
    rs = np.random.RandomState(0)
    prompt = rs.randint(0, cfg.vocab, 17).astype(np.int32)
    tight = ServeEngine(params, cfg, max_len=20, max_batch=1,
                        prefill_chunk=8)      # n_pad=24 > max_len=20
    roomy = ServeEngine(params, cfg, max_len=24, max_batch=1,
                        prefill_chunk=8)
    np.testing.assert_array_equal(tight.generate([Request(prompt, 1)])[0],
                                  roomy.generate([Request(prompt, 1)])[0])
