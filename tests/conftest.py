import os

# smoke tests and benches must see exactly ONE device — never set the
# 512-device flag here (that is launch/dryrun.py's job, in its own process)
assert "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must not run under the dry-run XLA_FLAGS"

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # tier-1 runs everything; CI splits it into a fast job (-m "not
    # stress") and a stress job (-m stress) with per-test timeouts
    config.addinivalue_line(
        "markers",
        "stress: randomized/property stress tests (separate CI job)")
    config.addinivalue_line(
        "markers",
        "stats: statistical tests with explicit alpha/n (tests/stats.py); "
        "fixed-seed subset runs in test-fast, REPRO_STATS_WIDE=1 widens "
        "the seed matrix in the stress job; `make test-stats` runs them "
        "alone")


def pytest_collection_modifyitems(config, items):
    """Fail collection if an unmarked test uses tests/stats.py.

    Every statistical claim must be auditable through the ``stats``
    marker (so CI can run/report them as a family and the fast job can
    keep a fixed-seed subset).  A test function that references names
    imported from ``stats`` without carrying ``@pytest.mark.stats`` is a
    collection error, not a silent pass.
    """
    offenders = []
    for item in items:
        mod = getattr(item, "module", None)
        fn = getattr(item, "function", None)
        if mod is None or fn is None:
            continue
        stats_names = {
            name for name, val in vars(mod).items()
            if getattr(val, "__module__", None) == "stats"
            or getattr(val, "__name__", None) == "stats"
        }
        if not stats_names:
            continue
        used = stats_names & set(fn.__code__.co_names)
        if used and item.get_closest_marker("stats") is None:
            offenders.append(f"{item.nodeid} (uses {sorted(used)})")
    if offenders:
        raise pytest.UsageError(
            "tests using tests/stats.py must be marked @pytest.mark.stats:\n"
            + "\n".join(f"  {o}" for o in offenders))


@pytest.fixture()
def seeded_tokens():
    """Deterministic token-id generator for statistical suites.

    Returns ``make(seed, n, vocab)`` -> np.int32 [n]; same (seed, n,
    vocab) always yields the same prompt, independent of call order.
    """
    def make(seed: int, n: int, vocab: int) -> np.ndarray:
        rs = np.random.RandomState(seed)
        return rs.randint(0, vocab, size=n).astype(np.int32)
    return make
