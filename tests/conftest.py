import os

# smoke tests and benches must see exactly ONE device — never set the
# 512-device flag here (that is launch/dryrun.py's job, in its own process)
assert "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must not run under the dry-run XLA_FLAGS"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # tier-1 runs everything; CI splits it into a fast job (-m "not
    # stress") and a stress job (-m stress) with per-test timeouts
    config.addinivalue_line(
        "markers",
        "stress: randomized/property stress tests (separate CI job)")
