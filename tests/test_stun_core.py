"""STUN core: clustering (Alg 1), representatives (Alg 2), greedy (Eq 5-7),
reconstruction-loss quality vs baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (agglomerative_threshold, agglomerative_to_count,
                        behavioral_distance, cluster_experts,
                        combinatorial_prune_layer, dsatur_to_count,
                        expert_prune_moe, greedy_prune_sequence,
                        layer_reconstruction_loss, n_combinations,
                        representatives, router_distance)
from repro.models import abstract_params
from repro.models import param as pm

RNG = jax.random.PRNGKey(0)


def _clustered_routers(E=8, D=16, n_groups=4, noise=0.01, seed=0):
    """Router rows with planted cluster structure."""
    rs = np.random.RandomState(seed)
    centers = rs.randn(n_groups, D) * 2
    rows, labels = [], []
    for i in range(E):
        g = i % n_groups
        rows.append(centers[g] + rs.randn(D) * noise)
        labels.append(g)
    return np.stack(rows), np.array(labels)


def test_router_distance_properties():
    W, _ = _clustered_routers()
    d = router_distance(W)
    assert np.allclose(d, d.T)
    assert np.allclose(np.diag(d), 0)
    assert (d >= 0).all()


def test_agglomerative_recovers_planted_clusters():
    W, truth = _clustered_routers(E=12, n_groups=4, noise=0.01)
    dist = behavioral_distance(W)
    labels = cluster_experts(dist, n_keep=4)
    assert labels.max() + 1 == 4
    # same planted group -> same cluster
    for g in range(4):
        members = labels[truth == g]
        assert len(set(members.tolist())) == 1


def test_agglomerative_threshold_semantics():
    W, _ = _clustered_routers(noise=0.01)
    dist = behavioral_distance(W)
    # t below min inter-cluster distance: merges only within groups
    labels_lo = agglomerative_threshold(dist, t=0.5)
    assert labels_lo.max() + 1 == 4
    # huge threshold: everything merges
    labels_hi = agglomerative_threshold(dist, t=1e9)
    assert labels_hi.max() + 1 == 1
    # zero threshold: nothing merges
    labels_z = agglomerative_threshold(dist, t=0.0)
    assert labels_z.max() + 1 == len(W)


@pytest.mark.parametrize("n_keep", [2, 4, 6])
def test_exact_cluster_count(n_keep):
    W, _ = _clustered_routers(E=8, noise=0.3)
    dist = behavioral_distance(W)
    for method in ("agglomerative", "dsatur"):
        labels = cluster_experts(dist, n_keep, method)
        assert labels.max() + 1 == n_keep, method


def test_coactivation_breaks_ties():
    W = np.ones((4, 8))  # identical routers: distance alone can't decide
    coact = np.zeros((4, 4))
    coact[0, 1] = coact[1, 0] = 100.0  # 0,1 always co-fire -> similar
    d_with = behavioral_distance(W, coact, lam1=1.0, lam2=1.0)
    assert d_with[0, 1] < d_with[0, 2]


def test_representatives_closest_to_mean():
    flat = np.array([[0.0, 0], [1, 0], [10, 0], [11, 0]], np.float32)
    labels = np.array([0, 0, 1, 1])
    reps, reconstruct, means = representatives(flat, labels, kappa=3)
    assert reconstruct  # 2 clusters < kappa=3
    assert set(reps.tolist()) <= {0, 1, 2, 3}
    # each rep is a member of its cluster closest to the mean
    for c in (0, 1):
        members = np.where(labels == c)[0]
        dists = [np.linalg.norm(flat[m] - means[c]) for m in members]
        assert reps[c] == members[int(np.argmin(dists))]


def test_greedy_sequence_equals_nonreps():
    labels = np.array([0, 0, 1, 1, 2])
    reps = np.array([0, 2, 4])
    seq = greedy_prune_sequence(labels, reps)
    assert set(seq) == {1, 3}  # exactly the non-representatives


def _tiny_moe(E=8, seed=0):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=1, n_experts=E,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(seed))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    return cfg, params


def test_o1_beats_random_on_reconstruction():
    """The paper's core quality claim: when the MoE has latent expert
    structure (trained MoEs do — that's §4.3's premise), greedy-clustered
    pruning reconstructs better than random expert pruning."""
    cfg, params = _tiny_moe()
    # plant structure: experts 2i and 2i+1 are near-duplicates, router rows
    # likewise (the latent clusters the paper exploits)
    moe = jax.tree.map(np.array, params["layers"]["moe"])
    rs = np.random.RandomState(0)
    for i in range(0, cfg.n_experts, 2):
        for key in ("we_gate", "we_up", "we_down"):
            moe[key][0, i + 1] = moe[key][0, i] + 0.01 * rs.randn(
                *moe[key][0, i].shape).astype(np.float32)
        moe["router"][0, i + 1] = moe["router"][0, i] + 0.01 * rs.randn(
            cfg.d_model).astype(np.float32)
    params = {**params, "layers": {**params["layers"],
                                   "moe": jax.tree.map(jnp.asarray, moe)}}
    lp = jax.tree.map(lambda w: w[0], params["layers"]["moe"])
    x = jax.random.normal(RNG, (4, 32, cfg.d_model), jnp.float32)

    _, _, keep_mask, rep = expert_prune_moe(params, cfg, ratio=0.25,
                                            mode="mask")
    ours = layer_reconstruction_loss(x, lp, cfg, keep_mask[0])

    rs = np.random.RandomState(1)
    rand_losses = []
    for _ in range(8):
        m = np.ones(cfg.n_experts, np.float32)
        m[rs.choice(cfg.n_experts, 2, replace=False)] = 0
        rand_losses.append(layer_reconstruction_loss(x, lp, cfg, m))
    assert ours < np.mean(rand_losses), (ours, rand_losses)
    # with planted duplicates we should in fact prune one of each pair
    kept = np.where(keep_mask[0] > 0)[0]
    pairs_with_both = sum(1 for i in range(0, cfg.n_experts, 2)
                          if i in kept and i + 1 in kept)
    assert pairs_with_both <= 2


def test_combinatorial_is_lower_bound_per_layer():
    """Exhaustive search minimizes Eq. 4 — ours should be close but can't
    beat it on the same objective; also check the forward-pass count."""
    cfg, params = _tiny_moe()
    lp = jax.tree.map(lambda w: w[0], params["layers"]["moe"])
    x = jax.random.normal(RNG, (2, 16, cfg.d_model), jnp.float32)
    best_mask, best_loss, n_calls = combinatorial_prune_layer(x, lp, cfg, 2)
    assert n_calls == n_combinations(8, 0.25) == 28
    _, _, keep_mask, _ = expert_prune_moe(params, cfg, ratio=0.25,
                                          mode="mask")
    ours = layer_reconstruction_loss(x, lp, cfg, keep_mask[0])
    assert best_loss <= ours + 1e-6
    assert ours <= 3.0 * best_loss + 1e-6  # same ballpark at O(1) cost


def test_compact_mode_shapes_and_topk():
    cfg, params = _tiny_moe()
    new_params, new_cfg, keep_mask, rep = expert_prune_moe(params, cfg,
                                                           ratio=0.5,
                                                           mode="compact")
    assert new_cfg.n_experts == 4
    moe = new_params["layers"]["moe"]
    assert moe["router"].shape == (1, 4, cfg.d_model)
    assert moe["we_gate"].shape == (1, 4, cfg.d_model, cfg.moe_d_ff)
    assert new_cfg.top_k == min(cfg.top_k, 4)
    assert keep_mask.sum() == 4


def test_o1_no_forward_passes():
    """λ=(1,0): the whole expert-pruning decision uses zero forward passes
    (the O(1) claim)."""
    cfg, params = _tiny_moe()
    _, _, _, rep = expert_prune_moe(params, cfg, ratio=0.25, lam2=0.0)
    assert rep.router_forward_passes == 0


def test_selective_reconstruction_branches():
    cfg, params = _tiny_moe()
    # kappa above cluster count -> reconstruct (theta = cluster mean)
    _, _, _, rep_hi = expert_prune_moe(params, cfg, ratio=0.25, kappa=100)
    assert all(rep_hi.reconstructed)
    # kappa = 0 -> never reconstruct
    _, _, _, rep_lo = expert_prune_moe(params, cfg, ratio=0.25, kappa=0)
    assert not any(rep_lo.reconstructed)
