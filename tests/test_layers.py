"""Attention / norm / rope / recurrence correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import random

from repro.models.layers import (apply_rope, attention_chunked,
                                 attention_decode, attention_naive, rmsnorm,
                                 rope_tables)
from repro.models.ssm import causal_conv1d, linear_recurrence_chunked

RNG = random.PRNGKey(0)


def _qkv(B=2, S=64, H=8, K=2, hd=16):
    q = random.normal(RNG, (B, S, H, hd), jnp.float32)
    k = random.normal(random.fold_in(RNG, 1), (B, S, K, hd), jnp.float32)
    v = random.normal(random.fold_in(RNG, 2), (B, S, K, hd), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("window", [None, 12])
def test_chunked_matches_naive(chunk, window):
    q, k, v = _qkv()
    pos = jnp.arange(64)
    o1 = attention_naive(q, k, v, pos, pos, window=window)
    o2 = attention_chunked(q, k, v, pos, pos, window=window, chunk=chunk)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


def test_chunked_unroll_matches_scan():
    q, k, v = _qkv()
    pos = jnp.arange(64)
    o1 = attention_chunked(q, k, v, pos, pos, chunk=16, unroll=False)
    o2 = attention_chunked(q, k, v, pos, pos, chunk=16, unroll=True)
    np.testing.assert_allclose(o1, o2, atol=1e-6)


def test_decode_matches_naive_rows():
    q, k, v = _qkv()
    B, S = 2, 64
    full = attention_naive(q, k, v, jnp.arange(S), jnp.arange(S))
    kc = jnp.zeros_like(k)
    vc = jnp.zeros_like(v)
    for t in range(6):
        kc = kc.at[:, t].set(k[:, t])
        vc = vc.at[:, t].set(v[:, t])
        o = attention_decode(q[:, t: t + 1], kc, vc, jnp.full((B,), t + 1))
        np.testing.assert_allclose(o[:, 0], full[:, t], atol=1e-5)


def test_softmax_rows_sum_to_one_property():
    # fully-masked rows guard: row 0 attends only to itself
    q, k, v = _qkv(S=8)
    o = attention_chunked(q, k, v, jnp.arange(8), jnp.arange(8), chunk=4)
    np.testing.assert_allclose(o[:, 0], v[:, 0].repeat(4, axis=1), atol=1e-5)


def test_rope_preserves_norm_and_relative_phase():
    x = random.normal(RNG, (2, 16, 4, 32), jnp.float32)
    sin, cos = rope_tables(jnp.arange(16), 32, 10000.0)
    y = apply_rope(x, sin, cos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(y[:, 0], x[:, 0], atol=1e-6)


def test_rmsnorm_unit_scale():
    x = random.normal(RNG, (4, 64), jnp.float32) * 10
    y = rmsnorm(x, jnp.zeros(64))
    rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_causal_conv1d_matches_numpy():
    x = random.normal(RNG, (2, 16, 8), jnp.float32)
    w = random.normal(random.fold_in(RNG, 3), (8, 4), jnp.float32)
    b = jnp.zeros(8)
    y, state = causal_conv1d(x, w, b)
    xp = np.pad(np.asarray(x), ((0, 0), (3, 0), (0, 0)))
    want = sum(xp[:, i: i + 16] * np.asarray(w)[:, i] for i in range(4))
    np.testing.assert_allclose(y, want, atol=1e-5)
    np.testing.assert_allclose(state, x[:, -3:], atol=0)


@pytest.mark.parametrize("chunk", [4, 8, 32])
@pytest.mark.parametrize("unroll", [False, True])
def test_linear_recurrence(chunk, unroll):
    B, S, W = 2, 32, 8
    a = jax.nn.sigmoid(random.normal(RNG, (B, S, W)))
    b = random.normal(random.fold_in(RNG, 5), (B, S, W))
    h, h_last = linear_recurrence_chunked(a, b, jnp.zeros((B, W)), chunk,
                                          unroll=unroll)
    # sequential oracle
    hh = np.zeros((B, W))
    for t in range(S):
        hh = np.asarray(a[:, t]) * hh + np.asarray(b[:, t])
        np.testing.assert_allclose(h[:, t], hh, atol=1e-5)
    np.testing.assert_allclose(h_last, hh, atol=1e-5)
