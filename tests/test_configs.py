"""Assigned architecture configs: exact spec values + registry."""
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, list_configs

SPEC = {
    # arch: (family, L, d_model, H, KV, d_ff_or_expert_ff, vocab)
    "recurrentgemma-2b": ("hybrid", 26, 2560, 10, 1, 7680, 256000),
    "falcon-mamba-7b": ("ssm", 64, 4096, 1, 1, 0, 65024),
    "command-r-plus-104b": ("dense", 64, 12288, 96, 8, 33792, 256000),
    "qwen1.5-4b": ("dense", 40, 2560, 20, 20, 6912, 151936),
    "qwen2-7b": ("dense", 28, 3584, 28, 4, 18944, 152064),
    "deepseek-67b": ("dense", 95, 8192, 64, 8, 22016, 102400),
    "moonshot-v1-16b-a3b": ("moe", 48, 2048, 16, 16, 1408, 163840),
    "olmoe-1b-7b": ("moe", 16, 2048, 16, 16, 1024, 50304),
    "musicgen-medium": ("audio", 48, 1536, 24, 24, 6144, 2048),
    "internvl2-2b": ("vlm", 24, 2048, 16, 8, 8192, 92553),
}


def test_all_assigned_registered():
    known = set(list_configs())
    for a in ASSIGNED_ARCHS:
        assert a in known


@pytest.mark.parametrize("arch", list(SPEC))
def test_exact_spec(arch):
    fam, L, d, H, KV, ff, vocab = SPEC[arch]
    cfg = get_config(arch)
    assert cfg.family == fam
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.vocab == vocab
    if fam == "moe":
        assert cfg.moe_d_ff == ff
        assert cfg.n_experts == 64
        assert cfg.top_k == {"moonshot-v1-16b-a3b": 6, "olmoe-1b-7b": 8}[arch]
    else:
        assert cfg.d_ff == ff
    if arch == "falcon-mamba-7b":
        assert cfg.ssm_state == 16
    if arch == "recurrentgemma-2b":
        assert cfg.layer_pattern == ("rec", "rec", "attn")
        assert cfg.local_window == 2048


def test_param_counts_in_ballpark():
    # analytic param counts should be near the public model sizes
    expect = {
        "command-r-plus-104b": (90e9, 120e9),
        "qwen2-7b": (6e9, 9e9),
        "deepseek-67b": (60e9, 72e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "falcon-mamba-7b": (6e9, 9e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        # the assigned spec (48L × 64e × d_ff 1408) arithmetically totals
        # ~28B with ~3.3B active; we implement the assigned numbers verbatim
        "moonshot-v1-16b-a3b": (26e9, 30e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_active_params_below_total():
    cfg = get_config("olmoe-1b-7b")
    assert cfg.active_param_count() < cfg.param_count() / 4


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_vocab_padding():
    cfg = get_config("internvl2-2b")
    assert cfg.padded_vocab % 512 == 0
    assert cfg.padded_vocab >= cfg.vocab
    assert get_config("qwen2-7b").padded_vocab == 152064  # already aligned


def test_long_context_applicability():
    from repro.configs import shape_applicable
    ok, _ = shape_applicable(get_config("falcon-mamba-7b"), SHAPES["long_500k"])
    assert ok
    ok, _ = shape_applicable(get_config("recurrentgemma-2b"), SHAPES["long_500k"])
    assert ok
    ok, why = shape_applicable(get_config("qwen2-7b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
