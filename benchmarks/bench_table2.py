"""Table 2 analogue: our O(1) expert pruning vs Lu et al. combinatorial.

Reports eval loss, per-layer reconstruction loss, and the COST column the
paper emphasizes: forward passes used (O(1) -> 0; combinatorial ->
C(n, φn) per layer) + wall-clock.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (Timer, calib, emit, eval_loss, tiny_moe_cfg,
                               train_tiny)
from repro.core import expert_prune_moe, n_combinations
from repro.core.calibration import moe_layer_inputs, run_calibration
from repro.core.combinatorial import combinatorial_prune
from repro.models.moe import moe_apply


def _apply_mask_eval(params, cfg, keep_mask):
    """Eval with router-masked experts (mask mode, no weight surgery)."""
    import dataclasses

    from repro.models import loss_fn
    from repro.data.synthetic import SyntheticLM, make_batch
    from benchmarks.common import DATA_SEED
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    masks = jnp.asarray(keep_mask)

    def masked_loss(p, b):
        # evaluate with expert masks by suppressing router rows of pruned
        # experts (softmax renormalizes over the alive ones)
        moe = dict(p["layers"]["moe"])
        moe["router"] = jnp.where(masks[:, :, None] > 0,
                                  moe["router"].astype(jnp.float32), -1e4)
        p2 = {**p, "layers": {**p["layers"], "moe": moe}}
        return loss_fn(p2, cfg, b)

    fn = jax.jit(masked_loss)
    tot = 0.0
    for i in range(8):
        b = make_batch(lm, 8, 64, step=10_000 + i)
        tot += float(fn(params, b))
    return tot / 8


def main():
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    batches = calib(cfg)
    base = eval_loss(params, cfg)
    ratio = 0.25

    # ours: O(1)
    with Timer() as t:
        p1, c1, keep1, rep = expert_prune_moe(params, cfg, ratio,
                                              mode="compact")
    l1 = eval_loss(p1, c1)
    emit("table2/ours_o1", t.seconds * 1e6,
         f"eval_loss={l1:.4f};fwd_passes={rep.router_forward_passes};"
         f"cost=O(1)")

    # Lu et al.: exhaustive reconstruction-loss search
    stats = run_calibration(params, cfg, batches[:1], collect_inputs=True)
    x_per_layer = moe_layer_inputs(stats, cfg)
    with Timer() as t:
        keep2, n_calls = combinatorial_prune(params, cfg,
                                             jnp.asarray(x_per_layer), ratio)
    l2 = _apply_mask_eval(params, cfg, keep2)
    emit("table2/lu_combinatorial", t.seconds * 1e6,
         f"eval_loss={l2:.4f};fwd_passes={n_calls};"
         f"cost=O(k^n/sqrt(n))={n_combinations(cfg.n_experts, ratio)}/layer")

    # random baseline
    rs = np.random.RandomState(0)
    losses = []
    for s in range(4):
        m = np.ones((cfg.n_layers, cfg.n_experts), np.float32)
        for l in range(cfg.n_layers):
            m[l, rs.choice(cfg.n_experts, 2, replace=False)] = 0
        losses.append(_apply_mask_eval(params, cfg, m))
    emit("table2/random_expert", 0.0,
         f"eval_loss={np.mean(losses):.4f};fwd_passes=0;"
         f"unpruned={base:.4f}")


if __name__ == "__main__":
    main()
