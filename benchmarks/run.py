"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
  table1   — STUN vs unstructured-only (paper Table 1)
  table2   — O(1) vs Lu et al. combinatorial expert pruning (Table 2)
  fig1     — eval loss vs sparsity curve (Figure 1)
  fig2     — expert-count trend, RQ3 (Figure 2)
  table3   — clustering + reconstruction ablations (Tables 3/4/5)
  kurtosis — §5 robustness probe
  scaling  — O(1) cost claim vs n experts (footnote 2)
  kernels  — kernel micro-benchmarks (jnp ref path on CPU)
  serving  — chunked prefill vs seed engine; dense vs pruned serving
  slo      — open-loop wall-clock load: max sustainable QPS at SLO
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (bench_fig1, bench_fig2, bench_kernels,
                        bench_kurtosis, bench_scaling, bench_serving,
                        bench_slo, bench_table1, bench_table2, bench_table3)

ALL = {
    "table1": bench_table1.main,
    "table2": bench_table2.main,
    "fig1": bench_fig1.main,
    "fig2": bench_fig2.main,
    "table3": bench_table3.main,
    "kurtosis": bench_kurtosis.main,
    "scaling": bench_scaling.main,
    "kernels": bench_kernels.main,
    "serving": bench_serving.main,
    "slo": bench_slo.main,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in which:
        try:
            ALL[name]()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
