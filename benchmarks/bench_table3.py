"""Table 3/4/5 analogue (RQ4 ablations): clustering algorithm and
selective-reconstruction κ for the expert-pruning stage at 50% experts."""
from __future__ import annotations

from benchmarks.common import emit, eval_loss, tiny_moe_cfg, train_tiny
from repro.core import expert_prune_moe


def main():
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    base = eval_loss(params, cfg)
    emit("table3/unpruned", 0.0, f"eval_loss={base:.4f}")

    # clustering ablation (Table 4)
    for method in ("agglomerative", "dsatur"):
        p, c, _, _ = expert_prune_moe(params, cfg, 0.5, method=method)
        emit(f"table3/cluster_{method}", 0.0,
             f"eval_loss={eval_loss(p, c):.4f}")

    # selective reconstruction ablation (Table 5): never / selective / always
    for name, kappa in (("never_k0", 0), ("selective_k3", 3),
                        ("always_k99", 99)):
        p, c, _, rep = expert_prune_moe(params, cfg, 0.5, kappa=kappa)
        emit(f"table3/reconstruct_{name}", 0.0,
             f"eval_loss={eval_loss(p, c):.4f};"
             f"reconstructed_layers={sum(rep.reconstructed)}")


if __name__ == "__main__":
    main()
