"""Open-loop SLO benchmark: max sustainable QPS at a latency SLO.

``bench_serving`` is closed-loop (the next request waits for the last)
and step-indexed; production capacity claims need the opposite: an
**open-loop** generator whose Poisson arrivals keep coming at the
offered rate whether or not the engine keeps up — the regime where
queueing delay explodes past saturation — measured in **wall-clock**
seconds.  This module:

  * drives ``ServeEngine.submit/step`` from a wall-clock arrival
    schedule (requests are submitted at their arrival instant between
    engine steps; an idle engine sleeps until the next arrival, a busy
    one steps flat out),
  * scores **per-request** SLO attainment — TTFT measured from the
    request's *arrival* (so time spent queueing behind a saturated
    engine counts against it, which is the whole point) and the
    request's own p95 inter-token gap (from ``RequestState.itl``, the
    per-request TPOT trace the scheduler keeps) — plus **goodput**:
    SLO-meeting requests per second,
  * calibrates the SLO targets from an unloaded reference run (p95 x a
    slack factor, shared by every config so the comparison is honest),
  * **bisects** offered QPS to the highest rate each engine config
    sustains at ``ATTAINMENT_TARGET`` attainment — exponential
    expansion to bracket saturation, then binary search — for the
    {blocking, interleaved} x {spec off, on} matrix,
  * checks attainment degrades monotonically with offered load (a
    2-point low/high sweep per config, asserted),

and merges everything into the ``slo`` section of
``BENCH_serving.json`` (schema in docs/serving.md).  Run via
``make bench-slo`` or ``python benchmarks/run.py slo``.

``--replay trace.jsonl`` switches to **workload-trace replay**: instead
of Poisson arrivals, the recorded ``(arrival_offset_s, prompt_len,
max_new_tokens, seed)`` schedule (dumped by
``Tracer.dump_workload``, or the committed
``benchmarks/traces/bursty_small.jsonl``) drives the same open-loop
harness — production-shaped bursts are burstier than Poisson
(inter-arrival CV > 1), which is exactly the regime where queue depth
and stage timing diverge from the Poisson numbers.  The scored trial —
including the per-stage queue/prefill/decode split — lands in the
``trace_replay`` section of ``BENCH_serving.json``; ``--trace out.json``
additionally exports the replay's Chrome-trace spans (``make
trace-smoke`` validates that export in CI).

The substrate is the TRAINED tiny MoE from ``benchmarks.common`` (the
spec-decode drafter must be faithful for spec configs to mean
anything), with in-distribution prompts from the synthetic Markov LM.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import DATA_SEED, emit, tiny_moe_cfg, train_tiny
from repro.data.synthetic import SyntheticLM
from repro.serving import Request, ServeEngine, Tracer, load_workload

JSON_OUT = "BENCH_serving.json"

# workload shape: small enough that one trial is seconds on CPU, big
# enough that attainment is a fraction with useful resolution
N_REQUESTS = 16
PROMPT_LEN = 16
NEW_TOKENS = 12
MAX_LEN = 64
MAX_BATCH = 4
PREFILL_CHUNK = 16
PAGE_SIZE = 16
SPEC_K = 4
EXPERT_DROP = 0.25          # spec drafter: 25% of experts masked

ATTAINMENT_TARGET = 0.9
TTFT_SLACK = 4.0            # SLO = unloaded p95 x slack
TPOT_SLACK = 2.5
BISECT_ITERS = 3
MAX_EXPANSIONS = 8
# monotonicity tolerance: one request of attainment — shared-machine
# noise must not flip the low/high comparison on a 1/N_REQUESTS grid
MONO_TOL = 1.0 / N_REQUESTS + 1e-9

CONFIGS = {
    "blocking": {"schedule": "blocking", "spec": False},
    "interleaved": {"schedule": "interleaved", "spec": False},
    "blocking_spec": {"schedule": "blocking", "spec": True},
    "interleaved_spec": {"schedule": "interleaved", "spec": True},
}


def _workload(cfg, seed: int) -> List[Request]:
    """In-distribution prompts (the spec drafter's accept rate depends on
    them) with a fixed per-seed shape, fresh per trial."""
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    prompts = lm.sample(N_REQUESTS, PROMPT_LEN,
                        step=30_000 + seed * N_REQUESTS).astype(np.int32)
    return [Request(p, NEW_TOKENS) for p in prompts]


def _arrivals(qps: float, n: int, seed: int) -> np.ndarray:
    """Poisson process: cumulative sum of Exp(1/qps) inter-arrival gaps,
    as offsets (seconds) from the trial start."""
    rs = np.random.RandomState(1000 + seed)
    return np.cumsum(rs.exponential(1.0 / qps, size=n))


def drive_open_loop(eng: ServeEngine, reqs: List[Request],
                    arrivals: np.ndarray):
    """Submit each request at its wall-clock arrival offset while
    stepping the engine; returns ``(records, wall_s, t0)`` with one
    ``(rid, arrival_offset_s)`` record per request and ``t0`` the
    monotonic trial origin (for scoring against absolute timestamps).

    Open-loop semantics: arrivals never wait for the engine.  A request
    whose instant passes while ``step()`` runs is submitted at the next
    between-steps point, but its latency clock (the caller scores TTFT
    against ``arrival_offset``) started at the arrival — the queueing
    delay of a saturated engine is charged to it, unlike the
    closed-loop driver, which would have slowed the arrival down."""
    i, records = 0, []
    t0 = time.monotonic()
    while i < len(reqs) or eng.busy:
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            records.append((eng.submit(reqs[i]), float(arrivals[i])))
            i += 1
        if eng.busy:
            eng.step()
        elif i < len(reqs):
            time.sleep(max(0.0, arrivals[i] - (time.monotonic() - t0)))
    return records, time.monotonic() - t0, t0


def score_trial(eng: ServeEngine, records, t0: float, wall: float,
                slo_ttft: Optional[float], slo_tpot: Optional[float]):
    """Per-request SLO scoring over a drained trial.  A request meets the
    SLO iff its arrival-to-first-token time is within ``slo_ttft`` AND
    its own p95 inter-token gap is within ``slo_tpot`` (vacuously true
    for single-token streams).  Returns the trial metrics dict,
    including the disaggregated JetStream-style stage split from the
    scheduler's stamps: **queue** (arrival to lane admission — open-loop
    pre-submit lag plus FIFO wait, charged to the request exactly like
    TTFT), **prefill** (admission to activation) and **decode**
    (activation to completion)."""
    sched = eng.scheduler
    ttfts, tpots, met = [], [], 0
    stage_vals = {"queue": [], "prefill": [], "decode": []}
    for rid, arr in records:
        st = sched.finished[rid]
        ttft = st.t_first_token - (t0 + arr)
        tpot = float(np.percentile(st.itl, 95)) if st.itl else 0.0
        ttfts.append(ttft)
        tpots.append(tpot)
        if st.t_admit is not None and st.t_active is not None:
            stage_vals["queue"].append(st.t_admit - (t0 + arr))
            stage_vals["prefill"].append(st.t_active - st.t_admit)
            stage_vals["decode"].append(st.t_done - st.t_active)
        ok = (slo_ttft is None or ttft <= slo_ttft) and \
             (slo_tpot is None or tpot <= slo_tpot)
        met += bool(ok)
        sched.result(rid)              # pop state; long runs stay bounded
    n = len(records)
    out = {
        "n_requests": n,
        "wall_s": wall,
        "attainment": met / n,
        "goodput_rps": met / wall,
        "p50_ttft_s": float(np.percentile(ttfts, 50)),
        "p95_ttft_s": float(np.percentile(ttfts, 95)),
        "p95_tpot_s": float(np.percentile(tpots, 95)),
    }
    for name, vals in stage_vals.items():
        if vals:
            out[f"p50_{name}_s"] = float(np.percentile(vals, 50))
            out[f"p95_{name}_s"] = float(np.percentile(vals, 95))
    return out


def make_engine(params, cfg, schedule: str, spec: bool) -> ServeEngine:
    kwargs = {}
    if spec:
        mask = np.ones(cfg.n_experts, np.float32)
        n_drop = int(cfg.n_experts * EXPERT_DROP)
        mask[-n_drop:] = 0.0
        kwargs = {"spec_decode": "pruned", "spec_k": SPEC_K,
                  "expert_mask": mask}
    return ServeEngine(params, cfg, max_len=MAX_LEN, max_batch=MAX_BATCH,
                       prefill_chunk=PREFILL_CHUNK, page_size=PAGE_SIZE,
                       schedule=schedule, **kwargs)


def run_trial(eng: ServeEngine, cfg, qps: float, seed: int,
              slo_ttft: Optional[float], slo_tpot: Optional[float]):
    eng.reset_stats()
    reqs = _workload(cfg, seed)
    records, wall, t0 = drive_open_loop(eng, reqs,
                                        _arrivals(qps, len(reqs), seed))
    out = score_trial(eng, records, t0, wall, slo_ttft, slo_tpot)
    out["qps_offered"] = qps
    return out


def calibrate(eng: ServeEngine, cfg) -> Dict[str, float]:
    """Unloaded reference: requests one at a time (each arrives after the
    last could possibly finish), so the p95s reflect pure service time.
    The SLOs are those p95s x a slack factor — loose enough that the
    unloaded engine passes with margin, tight enough that queueing past
    saturation fails.  Also times a closed-loop burst (everything at
    once, engine flat out) — the service-rate estimate that seeds the
    QPS search near capacity instead of expanding up from ~0."""
    trial = run_trial(eng, cfg, qps=0.5, seed=0,
                      slo_ttft=None, slo_tpot=None)
    t0 = time.monotonic()
    outs = eng.generate(_workload(cfg, seed=998))
    closed_loop_rps = len(outs) / (time.monotonic() - t0)
    return {
        "p95_ttft_unloaded_s": trial["p95_ttft_s"],
        "p95_tpot_unloaded_s": trial["p95_tpot_s"],
        "closed_loop_rps": closed_loop_rps,
        "ttft_slack": TTFT_SLACK,
        "tpot_slack": TPOT_SLACK,
    }


def search_max_qps(eng: ServeEngine, cfg, qps0: float, slo_ttft: float,
                   slo_tpot: float):
    """Highest offered QPS with attainment >= ATTAINMENT_TARGET:
    exponential expansion from ``qps0`` until a trial fails, then
    ``BISECT_ITERS`` rounds of bisection inside the bracket.  Returns
    (max_qps, trials) — ``trials`` records every (qps, attainment,
    goodput) point the search visited, in order."""
    trials = []

    def attain(qps, seed):
        t = run_trial(eng, cfg, qps, seed, slo_ttft, slo_tpot)
        trials.append(t)
        return t["attainment"]

    lo, hi = None, None
    qps, seed = qps0, 1
    for _ in range(MAX_EXPANSIONS):
        if attain(qps, seed) >= ATTAINMENT_TARGET:
            lo, qps, seed = qps, qps * 2.0, seed + 1
        else:
            hi = qps
            break
    if lo is None:                      # qps0 already fails: search down
        for _ in range(MAX_EXPANSIONS):
            qps, seed = qps / 2.0, seed + 1
            if attain(qps, seed) >= ATTAINMENT_TARGET:
                lo, hi = qps, qps * 2.0
                break
        if lo is None:                  # degenerate: nothing sustains
            return 0.0, trials
    if hi is None:                      # never failed inside the cap
        return lo, trials
    for _ in range(BISECT_ITERS):
        mid, seed = (lo + hi) / 2.0, seed + 1
        if attain(mid, seed) >= ATTAINMENT_TARGET:
            lo = mid
        else:
            hi = mid
    return lo, trials


def check_monotonic(eng: ServeEngine, cfg, max_qps: float, slo_ttft: float,
                    slo_tpot: float) -> Dict[str, float]:
    """2-point sweep: attainment at light load must be >= attainment at
    heavy (8x — deep saturation, the whole wave arrives as a burst and
    queues) load, within one request's worth of tolerance — if
    saturating the engine does not degrade attainment, the harness is
    not measuring queueing."""
    lo_q = max(0.25 * max_qps, 0.1)
    hi_q = max(8.0 * max_qps, 2.0)
    lo = run_trial(eng, cfg, lo_q, seed=90, slo_ttft=slo_ttft,
                   slo_tpot=slo_tpot)
    hi = run_trial(eng, cfg, hi_q, seed=91, slo_ttft=slo_ttft,
                   slo_tpot=slo_tpot)
    return {
        "qps_low": lo_q, "attainment_low": lo["attainment"],
        "qps_high": hi_q, "attainment_high": hi["attainment"],
        "monotonic": lo["attainment"] >= hi["attainment"] - MONO_TOL,
    }


def main():
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")

    engines = {name: make_engine(params, cfg, c["schedule"], c["spec"])
               for name, c in CONFIGS.items()}
    for eng in engines.values():       # compile outside every timed trial
        eng.generate(_workload(cfg, seed=999))

    # one shared SLO, calibrated on the blocking no-spec reference —
    # every config is scored against the same bar, so max-QPS ranks them
    cal = calibrate(engines["blocking"], cfg)
    slo_ttft = cal["p95_ttft_unloaded_s"] * TTFT_SLACK
    slo_tpot = cal["p95_tpot_unloaded_s"] * TPOT_SLACK

    results = {
        "workload": {"n_requests": N_REQUESTS, "prompt_len": PROMPT_LEN,
                     "new_tokens": NEW_TOKENS, "max_batch": MAX_BATCH,
                     "max_len": MAX_LEN, "prefill_chunk": PREFILL_CHUNK,
                     "page_size": PAGE_SIZE, "arrivals": "poisson",
                     "spec_k": SPEC_K, "expert_drop": EXPERT_DROP},
        "slo_ttft_s": slo_ttft,
        "slo_tpot_s": slo_tpot,
        "attainment_target": ATTAINMENT_TARGET,
        "calibration": cal,
        "configs": {},
        "monotonic_load_degradation": {},
    }
    # seed the search at half the closed-loop service rate: close enough
    # to capacity that a few doublings bracket saturation
    qps0 = max(0.5, 0.5 * cal["closed_loop_rps"])
    for name, eng in engines.items():
        max_qps, trials = search_max_qps(eng, cfg, qps0, slo_ttft, slo_tpot)
        at_max = next((t for t in reversed(trials)
                       if t["qps_offered"] == max_qps), trials[-1])
        results["configs"][name] = {
            "schedule": CONFIGS[name]["schedule"],
            "spec_decode": CONFIGS[name]["spec"],
            "max_qps_at_slo": max_qps,
            "attainment_at_max": at_max["attainment"],
            "goodput_rps_at_max": at_max["goodput_rps"],
            "p95_ttft_s_at_max": at_max["p95_ttft_s"],
            "p95_tpot_s_at_max": at_max["p95_tpot_s"],
            "trials": trials,
        }
        emit(f"slo_{name}", at_max["wall_s"] * 1e6,
             f"max_qps={max_qps:.2f} "
             f"attain={at_max['attainment']:.2f} "
             f"goodput={at_max['goodput_rps']:.2f}rps "
             f"p95_ttft={at_max['p95_ttft_s'] * 1e3:.0f}ms "
             f"p95_tpot={at_max['p95_tpot_s'] * 1e3:.1f}ms")

    for name, eng in engines.items():
        mono = check_monotonic(eng, cfg,
                               results["configs"][name]["max_qps_at_slo"]
                               or qps0, slo_ttft, slo_tpot)
        results["monotonic_load_degradation"][name] = mono
        emit(f"slo_monotonic_{name}", 0.0,
             f"attain@{mono['qps_low']:.2f}qps={mono['attainment_low']:.2f} "
             f">= attain@{mono['qps_high']:.2f}qps="
             f"{mono['attainment_high']:.2f} (target monotonic)")
        assert mono["monotonic"], (
            f"{name}: attainment did not degrade with offered load: {mono}")

    # sanity: spec-mode TPOT must not be deflated by zero intra-block
    # gaps — amortized per-token pace can't beat wall-clock physics by
    # orders of magnitude (the pre-fix accounting reported ~0)
    for name in ("blocking_spec", "interleaved_spec"):
        at = results["configs"][name]
        assert at["p95_tpot_s_at_max"] > 0.0, \
            f"{name}: spec TPOT is zero — block amortization regressed"

    existing = {}
    if os.path.exists(JSON_OUT):
        with open(JSON_OUT) as f:
            existing = json.load(f)
    existing["slo"] = results
    with open(JSON_OUT, "w") as f:
        json.dump(existing, f, indent=2)
    print(f"# wrote {JSON_OUT} (slo section)")


def _replay_requests(cfg, entries):
    """Reconstruct the recorded workload: each trace record regenerates
    its prompt deterministically from ``seed`` (prompts are not stored in
    the trace — ``Tracer.record_request`` keeps only the shape and a
    content checksum), so a replay exercises the recorded *schedule* with
    in-distribution token content."""
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    reqs = []
    for e in entries:
        if e["prompt_len"] + e["max_new_tokens"] > MAX_LEN:
            raise ValueError(
                f"trace entry needs {e['prompt_len']} + "
                f"{e['max_new_tokens']} tokens > max_len={MAX_LEN}")
        prompt = lm.sample(1, int(e["prompt_len"]),
                           step=50_000 + int(e["seed"]) % 9973)[0]
        reqs.append(Request(prompt.astype(np.int32),
                            int(e["max_new_tokens"]),
                            temperature=float(e.get("temperature", 0.0))))
    arrivals = np.asarray([float(e["arrival_offset_s"]) for e in entries])
    return reqs, arrivals


def _burstiness_cv(arrivals: np.ndarray) -> float:
    """Coefficient of variation of inter-arrival gaps (first gap from
    t=0).  Poisson arrivals sit near 1.0; recorded bursts land above —
    the property that makes replay a different test than ``--qps``."""
    gaps = np.diff(np.concatenate([[0.0], np.asarray(arrivals, float)]))
    mean = float(gaps.mean())
    return float(gaps.std() / mean) if mean > 0 else 0.0


def run_replay(trace_path: str, trace_out: Optional[str] = None) -> Dict:
    """Drive the open-loop harness from a recorded workload trace and
    merge the scored trial into the ``trace_replay`` section of
    ``BENCH_serving.json``.  ``trace_out`` additionally attaches a fresh
    :class:`Tracer` (after the compile wave, so the export holds only
    steady-state spans) and writes its Chrome-trace JSON there."""
    entries = load_workload(trace_path)
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    eng = make_engine(params, cfg, "interleaved", spec=False)
    eng.generate(_workload(cfg, seed=999))     # compile outside the trial
    eng.reset_stats()
    tracer = None
    if trace_out is not None:
        tracer = Tracer()
        eng.set_tracer(tracer)

    reqs, arrivals = _replay_requests(cfg, entries)
    records, wall, t0 = drive_open_loop(eng, reqs, arrivals)
    trial = score_trial(eng, records, t0, wall, None, None)
    section = {
        "source": os.path.basename(trace_path),
        "arrivals": "replay",
        "burstiness_cv": _burstiness_cv(arrivals),
        "schedule": "interleaved",
        "spec_decode": False,
        **trial,
    }
    if tracer is not None:
        tracer.export(trace_out)
        section["trace_events"] = len(tracer.events)
        print(f"# wrote {trace_out} ({len(tracer.events)} trace events)")

    existing = {}
    if os.path.exists(JSON_OUT):
        with open(JSON_OUT) as f:
            existing = json.load(f)
    existing["trace_replay"] = section
    with open(JSON_OUT, "w") as f:
        json.dump(existing, f, indent=2)
    stages = " ".join(
        f"p95_{s}={section[f'p95_{s}_s'] * 1e3:.0f}ms"
        for s in ("queue", "prefill", "decode") if f"p95_{s}_s" in section)
    emit("slo_replay", wall * 1e6,
         f"n={section['n_requests']} cv={section['burstiness_cv']:.2f} "
         f"p95_ttft={section['p95_ttft_s'] * 1e3:.0f}ms {stages}")
    print(f"# wrote {JSON_OUT} (trace_replay section)")
    return section


def cli(argv=None):
    """Argparse entry for direct invocation.  Kept separate from
    ``main()`` so ``benchmarks/run.py`` (which calls ``main`` with its
    own sys.argv still in place) never sees these flags."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--replay", metavar="TRACE_JSONL", default=None,
                    help="replay a recorded workload trace instead of "
                         "running the Poisson QPS search")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="with --replay: export the replay's Chrome-trace "
                         "span JSON (load in Perfetto / chrome://tracing)")
    args = ap.parse_args(argv)
    if args.trace and not args.replay:
        ap.error("--trace requires --replay")
    if args.replay:
        run_replay(args.replay, args.trace)
    else:
        main()


if __name__ == "__main__":
    cli()
