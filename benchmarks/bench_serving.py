"""Serving benchmark: chunked prefill vs the seed token-by-token engine,
paged vs slot KV-cache serving throughput (dense and STUN-pruned), and
self-speculative decoding vs plain paged decode.

Measures, on the mixtral proxy (reduced to CPU scale):

  * prefill dispatch count + wall time at S=128 — the seed engine replayed
    prompts through the jitted decode step (S dispatches); the rebuilt
    engine issues one jitted call per ``prefill_chunk`` tokens, so the
    dispatch count is independent of the token count per dispatch.
  * end-to-end serving tokens/s, p50/p95 request latency, dispatch
    counts, pages/request and KV bytes resident for the paged engine vs
    the PR-1 slot engine at equal concurrency (the paged page budget is
    sized to the workload's live working set, so it holds fewer KV bytes
    for the same batch), and for the paged engine with 25% of experts
    pruned at runtime (``expert_mask``) — STUN's serving payoff.
  * speculative decode (on the TRAINED tiny MoE from benchmarks.common,
    so the expert-pruned drafter is actually faithful — the STUN premise):
    accept-rate, emitted tokens per verify dispatch, and end-to-end tok/s
    vs plain paged decode on the same workload and params.
  * sparse pruned-artifact runtime (``sparse_runtime`` section): the
    40%-total-sparsity STUN artifact served dense-masked vs packed
    (block-compressed expert FFN pools, ``repro.sparse``) — tok/s,
    resident expert-FFN weight bytes, and planned block sparsity per
    layer.  Targets: packed weight bytes <= 0.75x dense, tok/s >= the
    dense-masked engine, outputs bit-identical.
  * prefix caching (``prefix_cache`` section): a shared 96-token system
    prompt served cold then repeated — repeat prefill dispatches
    (asserted 0), first-vs-repeat TTFT against the cache-off engine
    (target repeat <= 0.3x), hit rate and COW forks — plus paired
    cache-on/off tok/s on a no-sharing workload (overhead target:
    median ratio >= 0.97).

Writes every metric to ``BENCH_serving.json`` (uploaded as a CI
artifact; schema documented in docs/serving.md) so trend reporting has
machine-readable data per commit.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.models import abstract_params, decode_step, init_cache
from repro.models import param as pm
from repro.serving import Request, ServeEngine

S_PROMPT = 128
PREFILL_CHUNK = 32
PAGE_SIZE = 16
SERVE_MAX_LEN = 80
SERVE_MAX_BATCH = 4
SERVE_CHUNK = 16
JSON_OUT = "BENCH_serving.json"


def _proxy_cfg():
    cfg = reduced(get_config("mixtral-8x7b-proxy"), n_layers=2,
                  n_experts=8, top_k=2)
    return dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                               remat_policy="full")


def _params(cfg):
    p = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    return jax.tree.map(lambda x: x.astype(jnp.float32), p)


def _seed_style_prefill(params, cfg, toks, max_len):
    """The seed engine's prefill: one jitted decode dispatch per token."""
    step = jax.jit(lambda p, c, t, n: decode_step(p, cfg, c, t, n))
    cache = init_cache(cfg, 1, max_len)
    logits = None
    for t in range(toks.shape[1]):
        logits, cache = step(params, cache, toks[:, t: t + 1], jnp.int32(t))
    jax.block_until_ready(logits)
    return toks.shape[1]  # dispatches


def bench_prefill(params, cfg):
    max_len = S_PROMPT + 16
    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(0, cfg.vocab, (1, S_PROMPT)), jnp.int32)

    _seed_style_prefill(params, cfg, toks, max_len)          # compile
    t0 = time.monotonic()
    seed_dispatches = _seed_style_prefill(params, cfg, toks, max_len)
    dt_seed = time.monotonic() - t0

    eng = ServeEngine(params, cfg, max_len=max_len, max_batch=1,
                      prefill_chunk=PREFILL_CHUNK)
    prompt = np.asarray(toks[0])
    eng.generate([Request(prompt, 1)])                       # compile
    eng.reset_stats()
    t0 = time.monotonic()
    eng.generate([Request(prompt, 1)])
    dt_chunked = time.monotonic() - t0
    chunked_dispatches = eng.prefill_dispatches

    emit(f"serve_prefill_seed_S{S_PROMPT}", dt_seed * 1e6,
         f"dispatches={seed_dispatches}")
    emit(f"serve_prefill_chunked_S{S_PROMPT}", dt_chunked * 1e6,
         f"dispatches={chunked_dispatches} chunk={PREFILL_CHUNK} "
         f"speedup={dt_seed / dt_chunked:.1f}x")
    assert chunked_dispatches == S_PROMPT // PREFILL_CHUNK
    return {
        "seed_dispatches": seed_dispatches,
        "chunked_dispatches": chunked_dispatches,
        "seed_s": dt_seed,
        "chunked_s": dt_chunked,
        "speedup": dt_seed / dt_chunked,
    }


N_REQUESTS = 12


def _workload(cfg):
    rs = np.random.RandomState(1)
    lens = rs.randint(8, 48, size=N_REQUESTS)
    news = rs.randint(4, 16, size=N_REQUESTS)
    return [Request(rs.randint(0, cfg.vocab, l).astype(np.int32), int(n))
            for l, n in zip(lens, news)]


def bench_engine(params, cfg, *, kv_layout="paged", expert_mask=None,
                 tag="paged"):
    reqs = _workload(cfg)
    kwargs = {}
    if kv_layout == "paged":
        # budget for the live working set: every lane can hold the
        # workload's biggest request, nothing is provisioned for max_len
        biggest = max(-(-(len(r.prompt) + r.max_new_tokens) // PAGE_SIZE)
                      for r in reqs)
        kwargs = {"page_size": PAGE_SIZE,
                  "page_budget": SERVE_MAX_BATCH * biggest}
    eng = ServeEngine(params, cfg, max_len=SERVE_MAX_LEN,
                      max_batch=SERVE_MAX_BATCH, prefill_chunk=SERVE_CHUNK,
                      expert_mask=expert_mask, kv_layout=kv_layout,
                      **kwargs)
    eng.generate([Request(r.prompt, r.max_new_tokens) for r in reqs])
    eng.reset_stats()                                        # drop compile
    t0 = time.monotonic()
    outs = eng.generate([Request(r.prompt, r.max_new_tokens) for r in reqs])
    dt = time.monotonic() - t0
    n_tok = sum(len(o) for o in outs)
    stats = eng.latency_stats()
    pages_per_req = (eng.pages_allocated / eng.requests_admitted
                     if eng.requests_admitted else 0.0)
    metrics = {
        "kv_layout": kv_layout,
        "tok_per_s": n_tok / dt,
        "wall_s": dt,
        "p50_latency_s": stats["p50_latency_s"],
        "p95_latency_s": stats["p95_latency_s"],
        "prefill_dispatches": eng.prefill_dispatches,
        "decode_dispatches": eng.decode_dispatches,
        "pages_per_request": pages_per_req,
        "kv_bytes_resident": eng.cache.bytes_resident(),
    }
    emit(f"serve_{tag}", dt * 1e6,
         f"tok/s={metrics['tok_per_s']:.1f} "
         f"p50={stats['p50_latency_s'] * 1e3:.0f}ms "
         f"p95={stats['p95_latency_s'] * 1e3:.0f}ms "
         f"decode_disp={eng.decode_dispatches} "
         f"pages/req={pages_per_req:.1f} "
         f"kv_bytes={metrics['kv_bytes_resident']}")
    return metrics


SPEC_K = 4
SPEC_NEW_TOKENS = 24
SPEC_N_REQUESTS = 8


SPEC_MAX_BATCH = 2


def bench_spec_decode():
    """Self-speculative decode (pruned draft -> dense verify) vs plain
    paged decode.  Uses the trained tiny-MoE substrate and in-distribution
    prompts from the synthetic Markov LM: the drafter must be *faithful*
    for speculation to pay, which is exactly STUN's pruning claim.

    Measured at low concurrency (max_batch=2) — the latency-bound regime
    speculation targets, where per-dispatch overhead dominates and
    ``2 / (accept + 1)`` dispatches per token is the win.  At large batch
    the CPU is compute-bound and plain batched decode is already
    efficient (docs/serving.md discusses the tradeoff)."""
    from benchmarks.common import DATA_SEED, tiny_moe_cfg, train_tiny
    from repro.data.synthetic import SyntheticLM

    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    prompts = lm.sample(SPEC_N_REQUESTS, 16, step=20_000).astype(np.int32)
    reqs = lambda: [Request(p, SPEC_NEW_TOKENS) for p in prompts]  # noqa: E731
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0                 # 25%-pruned drafter

    def run(**kwargs):
        eng = ServeEngine(params, cfg, max_len=64, max_batch=SPEC_MAX_BATCH,
                          prefill_chunk=16, page_size=PAGE_SIZE, **kwargs)
        eng.generate(reqs())                         # compile
        eng.reset_stats()
        t0 = time.monotonic()
        outs = eng.generate(reqs())
        dt = time.monotonic() - t0
        n_tok = sum(len(o) for o in outs)
        return eng, outs, n_tok / dt, dt

    _, outs_plain, tps_plain, _ = run()
    spec, outs_spec, tps_spec, dt = run(spec_decode="pruned", spec_k=SPEC_K,
                                        expert_mask=mask)
    # correctness oracle (hard-asserted in tests/test_speculative.py);
    # reported rather than asserted here so a pathological fp32 argmax
    # tie between the verify and plain decode attention paths degrades
    # the metric instead of crashing the CI benchmark job
    identical = all(a.shape == b.shape and bool(np.all(a == b))
                    for a, b in zip(outs_plain, outs_spec))
    st = spec.latency_stats()
    metrics = {
        "spec_k": SPEC_K,
        "output_identical_to_plain": identical,
        "accept_rate": st["spec_accept_rate"],
        "tokens_per_verify_dispatch": st["spec_tokens_per_verify"],
        "tok_per_s": tps_spec,
        "plain_tok_per_s": tps_plain,
        "speedup_vs_plain": tps_spec / tps_plain,
        "decode_dispatches": spec.decode_dispatches,
        "p50_latency_s": st["p50_latency_s"],
        "p95_latency_s": st["p95_latency_s"],
    }
    emit("serve_spec_decode", dt * 1e6,
         f"tok/s={tps_spec:.1f}vs{tps_plain:.1f}plain "
         f"speedup={metrics['speedup_vs_plain']:.2f}x (target >=1.0x) "
         f"accept={metrics['accept_rate']:.2f} "
         f"tok/verify={metrics['tokens_per_verify_dispatch']:.1f} "
         f"k={SPEC_K} identical={identical} (target True)")
    return metrics


SPEC_SAMPLING_TEMP = 0.7
SPEC_SAMPLING_K = 3
SPEC_SAMPLING_TREE = 2


def bench_spec_sampling():
    """Speculative SAMPLING (rejection-sampled accept, temperature 0.7):
    plain sampling vs chain drafts vs 2-branch tree drafts.

    Sampling lowers per-token acceptance versus greedy (the accept
    coin flips at min(1, p/q) instead of exact argmax agreement), which
    is exactly the regime token trees target: a second root candidate
    gets its own rejection-sampling round, so each verify dispatch
    salvages rounds the chain would end at depth 0.  The headline
    figure is ``accepted_per_verify`` (accepted DRAFT tokens per verify
    dispatch, bonus excluded) — the tree must beat the chain there or
    its extra draft rows are wasted work."""
    from benchmarks.common import DATA_SEED, tiny_moe_cfg, train_tiny
    from repro.data.synthetic import SyntheticLM

    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    prompts = lm.sample(SPEC_N_REQUESTS, 16, step=20_000).astype(np.int32)
    reqs = lambda: [Request(p, SPEC_NEW_TOKENS,  # noqa: E731
                            temperature=SPEC_SAMPLING_TEMP)
                    for p in prompts]
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0                 # 25%-pruned drafter

    def run(seed, **kwargs):
        eng = ServeEngine(params, cfg, max_len=64, max_batch=SPEC_MAX_BATCH,
                          prefill_chunk=16, page_size=PAGE_SIZE, seed=seed,
                          **kwargs)
        eng.generate(reqs())                         # compile
        eng.reset_stats()
        t0 = time.monotonic()
        eng.generate(reqs())
        dt = time.monotonic() - t0
        n_tok = SPEC_N_REQUESTS * SPEC_NEW_TOKENS
        return eng, n_tok / dt, dt

    _, tps_plain, _ = run(seed=0)
    spec_kw = dict(spec_decode="pruned", spec_k=SPEC_SAMPLING_K,
                   expert_mask=mask)
    chain, tps_chain, _ = run(seed=1, **spec_kw)
    tree, tps_tree, dt = run(seed=2, spec_tree=SPEC_SAMPLING_TREE,
                             **spec_kw)

    def shape_metrics(eng, tps):
        st = eng.latency_stats()
        return {
            "accept_rate": st["spec_accept_rate"],
            "accepted_per_verify": st["spec_accepted_per_verify"],
            "tokens_per_verify_dispatch": st["spec_tokens_per_verify"],
            "tok_per_s": tps,
            "speedup_vs_plain": tps / tps_plain,
        }

    metrics = {
        "temperature": SPEC_SAMPLING_TEMP,
        "spec_k": SPEC_SAMPLING_K,
        "spec_tree": SPEC_SAMPLING_TREE,
        "plain_tok_per_s": tps_plain,
        "chain": shape_metrics(chain, tps_chain),
        "tree": shape_metrics(tree, tps_tree),
    }
    # distribution equivalence is pinned statistically in
    # tests/test_spec_sampling.py; the bench tracks the draft-shape
    # economics (chain vs tree) at a sampling temperature
    metrics["tree_beats_chain_accepted_per_verify"] = (
        metrics["tree"]["accepted_per_verify"]
        > metrics["chain"]["accepted_per_verify"])
    emit("serve_spec_sampling", dt * 1e6,
         f"T={SPEC_SAMPLING_TEMP} tok/s plain={tps_plain:.1f} "
         f"chain={tps_chain:.1f} tree={tps_tree:.1f} "
         f"accept chain={metrics['chain']['accept_rate']:.2f} "
         f"tree={metrics['tree']['accept_rate']:.2f} "
         f"acc/verify chain={metrics['chain']['accepted_per_verify']:.2f} "
         f"tree={metrics['tree']['accepted_per_verify']:.2f} "
         f"(target tree>chain)")
    return metrics


# ---------------------------------------------------------------------------
# sparse pruned-artifact runtime: dense-masked vs block-compressed serving
# ---------------------------------------------------------------------------

SPARSE_BLOCK = (16, 16)
SPARSE_TARGET_BLOCK_SPARSITY = 0.4
SPARSE_PHI_U = 0.2          # stage-2 ratio; with 25% experts dead -> 40% total


SPARSE_MOE_D_FF = 128


def bench_sparse_runtime():
    """STUN's 40%-total-sparsity artifact served two ways on the SAME
    pruned model: dense-masked (stage-2 masks multiplied into dense
    weights at load — zero byte / FLOP savings) vs the packed sparse
    runtime (live MXU-tile blocks in per-matrix pools, block-sparse
    execute path).  The plan folds the stage-1 expert keep-mask (25% of
    experts -> all-dead blocks, whose compute the packed runtime skips
    outright) and block-rerounds toward ``SPARSE_TARGET_BLOCK_SPARSITY``
    (sparsity-preserving — total nonzeros unchanged, see docs/sparse.md);
    the dense-masked baseline serves the plan's own masks, so outputs
    are bit-identical and the tok/s comparison is apples to apples.

    Measured on an *expert-FFN-dominated* proxy (``moe_d_ff=128`` vs the
    throughput sections' 32): MoE serving cost is dominated by expert
    weights — the paper's premise — and the CPU-reduced default buries
    that term under attention, which would benchmark the runtime on a
    workload it doesn't target.  Wall clocks use back-to-back paired
    runs with a median-of-ratios (same rationale as
    ``bench_mixed_schedules``)."""
    from repro import sparse
    from repro.core.stun import unstructured_only
    from repro.data.synthetic import calibration_batches

    cfg = dataclasses.replace(_proxy_cfg(), moe_d_ff=SPARSE_MOE_D_FF)
    params = _params(cfg)
    em = np.ones(cfg.n_experts, np.float32)
    em[-cfg.n_experts // 4:] = 0.0               # stage-1: 25% experts dead
    batches = calibration_batches(cfg, n_batches=2)
    _, masks, _ = unstructured_only(params, cfg, batches,
                                    target_sparsity=SPARSE_PHI_U,
                                    method="owl")
    plan = sparse.plan_sparse_ffn(
        masks, sparse.ffn_weights_from_params(params, cfg),
        block=SPARSE_BLOCK, expert_mask=em,
        target_block_sparsity=SPARSE_TARGET_BLOCK_SPARSITY)
    packed, prep = sparse.pack_sparse_ffn(params, cfg, plan)
    base_masks = dict(masks)
    base_masks.update(plan.element_masks())

    reqs = _workload(cfg)
    biggest = max(-(-(len(r.prompt) + r.max_new_tokens) // PAGE_SIZE)
                  for r in reqs)

    def mk(**kw):
        return ServeEngine(params, cfg, max_len=SERVE_MAX_LEN,
                           max_batch=SERVE_MAX_BATCH,
                           prefill_chunk=SERVE_CHUNK, page_size=PAGE_SIZE,
                           page_budget=SERVE_MAX_BATCH * biggest,
                           expert_mask=em, weight_masks=base_masks, **kw)

    def drive(eng):
        t0 = time.monotonic()
        outs = eng.generate([Request(r.prompt, r.max_new_tokens)
                             for r in reqs])
        return outs, time.monotonic() - t0

    engines = {"dense_masked": mk(),
               "packed": mk(sparse_weights=packed)}
    outs = {}
    for name, eng in engines.items():
        outs[name], _ = drive(eng)                           # compile
    walls = {name: [] for name in engines}
    for _ in range(5):
        for name, eng in engines.items():
            outs[name], dt = drive(eng)
            walls[name].append(dt)
    n_tok = {name: sum(len(o) for o in outs[name]) for name in engines}
    pair = sorted(d / p for d, p in zip(walls["dense_masked"],
                                        walls["packed"]))
    tps_ratio = pair[len(pair) // 2]             # packed/dense, median pair
    identical = all(a.shape == b.shape and bool(np.all(a == b))
                    for a, b in zip(outs["dense_masked"], outs["packed"]))
    dense_ffn_bytes = sum(
        np.asarray(params["layers"]["moe"][k]).nbytes
        for k in ("we_gate", "we_up", "we_down"))
    metrics = {
        "block": list(SPARSE_BLOCK),
        "moe_d_ff": SPARSE_MOE_D_FF,
        "phi_u": SPARSE_PHI_U,
        "expert_drop": 0.25,
        "element_sparsity": prep["element_sparsity"],
        "planned_block_sparsity": prep["block_sparsity"],
        "planned_block_sparsity_per_layer": {
            str(l): r["block_sparsity"]
            for l, r in prep["per_layer"].items()},
        "blocks_rerounded": prep["blocks_rerounded"],
        "expert_ffn_bytes_dense": int(dense_ffn_bytes),
        "expert_ffn_bytes_packed": prep["packed_bytes"],
        "weight_bytes_ratio": prep["packed_bytes"] / dense_ffn_bytes,
        "output_identical_to_dense_masked": identical,
        "tok_per_s_packed_over_dense": tps_ratio,
    }
    for name in engines:
        dt = min(walls[name])
        metrics[f"tok_per_s_{name}"] = n_tok[name] / dt
    emit("serve_sparse_runtime", min(walls["packed"]) * 1e6,
         f"tok/s_ratio={tps_ratio:.2f} (target >=1.0) "
         f"bytes={metrics['weight_bytes_ratio']:.2f}x (target <=0.75) "
         f"block_sparsity={prep['block_sparsity']:.2f} "
         f"identical={identical} (target True)")
    return metrics


# ---------------------------------------------------------------------------
# prefix caching: shared-system-prompt reuse vs cold re-prefill
# ---------------------------------------------------------------------------

PFX_PROMPT = 96            # shared system prompt: 6 full 16-token pages
PFX_NEW = 8
PFX_REPEATS = 6
PFX_MAX_LEN = 112
PFX_BUDGET = 28            # 2 lanes x 7 pages + trie residency, no eviction
PFX_PAIR_REPS = 3


def bench_prefix_cache(params, cfg):
    """Radix-tree prefix caching (``prefix_cache=True``) measured two
    ways.  (a) The shared-system-prompt workload it targets: a 96-token
    prompt served cold, then repeated — every repeat must claim all six
    pages from the trie and dispatch ZERO prefill chunks (asserted:
    that's the tentpole property, not a wall clock), with repeat TTFT
    collapsing from a 6-chunk prefill to one COW fork + one decode
    dispatch (target <= 0.3x the cache-off repeat TTFT).  (b) Its
    overhead on a workload with NO sharing — fresh random prompts every
    wave so the trie never pays off, paired back-to-back cache-on/off
    runs, median per-pair tok/s ratio (target >= 0.97x: the trie walk,
    refcounting and eviction churn must cost ~nothing when idle)."""
    rs = np.random.RandomState(3)
    sys_prompt = rs.randint(0, cfg.vocab, PFX_PROMPT).astype(np.int32)
    warm_prompt = rs.randint(0, cfg.vocab, PFX_PROMPT).astype(np.int32)

    def mk(on):
        return ServeEngine(params, cfg, max_len=PFX_MAX_LEN, max_batch=2,
                           prefill_chunk=SERVE_CHUNK, page_size=PAGE_SIZE,
                           page_budget=PFX_BUDGET, prefix_cache=on)

    def ttft_wave(eng, n):
        eng.reset_stats()
        p0 = eng.prefill_dispatches
        outs = [eng.generate([Request(sys_prompt, PFX_NEW)])[0]
                for _ in range(n)]
        return outs, eng.latency_stats(), eng.prefill_dispatches - p0

    on, off = mk(True), mk(False)
    for eng in (on, off):      # compile prefill/decode on a disjoint prompt
        eng.generate([Request(warm_prompt, PFX_NEW)])

    outs_cold, st_cold, p_cold = ttft_wave(on, 1)
    on.generate([Request(sys_prompt, PFX_NEW)])   # compiles the COW fork
    outs_rep, st_rep, p_rep = ttft_wave(on, PFX_REPEATS)
    outs_off, st_off, p_off = ttft_wave(off, PFX_REPEATS)

    assert p_cold == PFX_PROMPT // SERVE_CHUNK, p_cold
    assert p_rep == 0, "a fully cached repeat dispatched prefill chunks"
    assert p_off == PFX_REPEATS * (PFX_PROMPT // SERVE_CHUNK), p_off
    identical = all(a.shape == b.shape and bool(np.all(a == b))
                    for a, b in zip(outs_cold * PFX_REPEATS, outs_rep)) \
        and bool(np.all(outs_cold[0] == outs_off[0]))
    ttft_ratio = (st_rep["p50_first_token_s"] / st_off["p50_first_token_s"])
    metrics = {
        "workload": {"system_prompt_tokens": PFX_PROMPT,
                     "new_tokens": PFX_NEW, "repeats": PFX_REPEATS,
                     "page_size": PAGE_SIZE, "prefill_chunk": SERVE_CHUNK},
        "hit_rate_repeat_wave": st_rep["prefix_hit_rate"],
        "prefill_dispatches_first": p_cold,
        "prefill_dispatches_repeat": p_rep,
        "ttft_first_s": st_cold["p50_first_token_s"],
        "ttft_repeat_s": st_rep["p50_first_token_s"],
        "ttft_cache_off_s": st_off["p50_first_token_s"],
        "ttft_repeat_over_cache_off": ttft_ratio,
        "claimed_tokens_repeat_wave": st_rep["prefix_claimed_tokens"],
        "cow_forks": st_rep["cow_forks"],
        "output_identical_to_cache_off": identical,
    }
    emit("serve_prefix_cache_repeat", st_rep["p50_first_token_s"] * 1e6,
         f"ttft={st_rep['p50_first_token_s'] * 1e3:.1f}ms "
         f"vs_off={ttft_ratio:.2f}x (target <=0.3) "
         f"prefill_disp={p_rep} (target 0) "
         f"hit_rate={st_rep['prefix_hit_rate']:.2f} "
         f"identical={identical} (target True)")

    # (b) no-sharing overhead: paired waves of fresh random prompts
    def pair_workload(seed):
        prs = np.random.RandomState(seed)
        lens = prs.randint(8, 48, size=N_REQUESTS)
        news = prs.randint(4, 16, size=N_REQUESTS)
        return [Request(prs.randint(0, cfg.vocab, l).astype(np.int32),
                        int(n)) for l, n in zip(lens, news)]

    pair_budget = SERVE_MAX_BATCH * (-(-(47 + 15) // PAGE_SIZE))
    engines = {
        name: ServeEngine(params, cfg, max_len=SERVE_MAX_LEN,
                          max_batch=SERVE_MAX_BATCH,
                          prefill_chunk=SERVE_CHUNK, page_size=PAGE_SIZE,
                          page_budget=pair_budget, prefix_cache=on_flag)
        for name, on_flag in (("on", True), ("off", False))}
    for eng in engines.values():
        eng.generate([Request(r.prompt, r.max_new_tokens)
                      for r in pair_workload(999)])           # compile
    walls = {name: [] for name in engines}
    n_tok = {}
    for rep in range(PFX_PAIR_REPS):
        reqs = pair_workload(100 + rep)
        for name, eng in engines.items():
            t0 = time.monotonic()
            outs = eng.generate([Request(r.prompt, r.max_new_tokens)
                                 for r in reqs])
            walls[name].append(time.monotonic() - t0)
            n_tok[name] = sum(len(o) for o in outs)
    pair = sorted(f / n for f, n in zip(walls["off"], walls["on"]))
    tps_ratio = pair[len(pair) // 2]              # on/off, median pair
    metrics["paired_no_sharing"] = {
        "reps": PFX_PAIR_REPS,
        "tok_per_s_on": n_tok["on"] / min(walls["on"]),
        "tok_per_s_off": n_tok["off"] / min(walls["off"]),
        "tok_per_s_on_over_off": tps_ratio,
        "hit_rate": engines["on"].prefix_cache.hit_rate,
    }
    emit("serve_prefix_cache_no_sharing", min(walls["on"]) * 1e6,
         f"tok/s_ratio={tps_ratio:.2f} (target >=0.97) "
         f"hit_rate={metrics['paired_no_sharing']['hit_rate']:.2f}")
    return metrics


# ---------------------------------------------------------------------------
# mixed short/long open-loop workload: blocking vs interleaved schedule
# ---------------------------------------------------------------------------

MIXED_SHORT_N = 3          # decode-heavy lanes whose streams can stall
MIXED_SHORT_PROMPT = 8
MIXED_SHORT_NEW = 96
MIXED_LONG_N = 8           # long prompts arriving mid-stream
MIXED_LONG_PROMPT = 192
MIXED_LONG_NEW = 4
MIXED_CHUNK = 16           # a long prompt = 12 prefill chunk dispatches
MIXED_MAX_LEN = 224
MIXED_BATCH = 4


def _mixed_workload(cfg):
    """(arrival_step, Request) pairs: short decode-heavy requests start
    immediately; long prompts arrive on a fixed step schedule regardless
    of completions (open-loop arrivals), so under the blocking schedule
    every long admission freezes the short lanes for a whole
    ``ceil(192/16) = 12``-dispatch prefill."""
    rs = np.random.RandomState(7)
    arrivals = [(0, Request(rs.randint(0, cfg.vocab, MIXED_SHORT_PROMPT)
                            .astype(np.int32), MIXED_SHORT_NEW))
                for _ in range(MIXED_SHORT_N)]
    arrivals += [(4 + 16 * j, Request(rs.randint(0, cfg.vocab,
                                                 MIXED_LONG_PROMPT)
                                      .astype(np.int32), MIXED_LONG_NEW))
                 for j in range(MIXED_LONG_N)]
    return sorted(arrivals, key=lambda a: a[0])


def _drive_open_loop(eng, arrivals):
    pending = collections.deque(arrivals)
    rids = []
    step_i = 0
    t0 = time.monotonic()
    while pending or eng.busy:
        while pending and pending[0][0] <= step_i:
            rids.append(eng.submit(pending.popleft()[1]))
        eng.step()
        step_i += 1
    dt = time.monotonic() - t0
    n_tok = sum(len(eng.scheduler.result(rid)) for rid in rids)
    return n_tok, dt


def bench_mixed_schedules(params, cfg):
    """The stall this PR removes, measured: p95 inter-token latency of
    the mixed workload under blocking vs interleaved scheduling.  Total
    work (dispatches) is identical — only the ordering differs — so
    tokens/s should match within noise while the interleaved p95 TPOT
    drops by roughly the long-prompt chunk count."""
    out = {"workload": {
        "short": {"n": MIXED_SHORT_N, "prompt": MIXED_SHORT_PROMPT,
                  "new_tokens": MIXED_SHORT_NEW},
        "long": {"n": MIXED_LONG_N, "prompt": MIXED_LONG_PROMPT,
                 "new_tokens": MIXED_LONG_NEW},
        "prefill_chunk": MIXED_CHUNK, "max_batch": MIXED_BATCH,
        "arrival": "open-loop, step-indexed",
    }}
    reps = 5
    engines = {}
    for schedule in ("blocking", "interleaved"):
        eng = ServeEngine(params, cfg, max_len=MIXED_MAX_LEN,
                          max_batch=MIXED_BATCH, prefill_chunk=MIXED_CHUNK,
                          page_size=PAGE_SIZE, schedule=schedule,
                          prefill_budget=MIXED_CHUNK)
        _drive_open_loop(eng, _mixed_workload(cfg))          # compile
        eng.reset_stats()
        engines[schedule] = eng
    # the two schedules do IDENTICAL work (same dispatches, different
    # order), so their throughput ratio should be ~1.  Shared-machine
    # contention swamps a single ~0.5s wall, so run the schedules in
    # adjacent back-to-back pairs and take the median per-pair ratio —
    # a burst then hits both members of a pair, not one side's total.
    walls = {"blocking": [], "interleaved": []}
    n_toks = {}
    for _ in range(reps):
        for schedule, eng in engines.items():
            n_toks[schedule], dt = _drive_open_loop(eng, _mixed_workload(cfg))
            walls[schedule].append(dt)
    pair_ratios = sorted((b / i) for b, i in zip(walls["blocking"],
                                                 walls["interleaved"]))
    tps_ratio = pair_ratios[len(pair_ratios) // 2]           # median
    for schedule, eng in engines.items():
        dt = min(walls[schedule])
        st = eng.latency_stats()                 # gaps pooled over reps
        out[schedule] = {
            "tok_per_s": n_toks[schedule] / dt,
            "wall_s": dt,
            "p50_inter_token_s": st["p50_inter_token_s"],
            "p95_inter_token_s": st["p95_inter_token_s"],
            "p50_first_token_s": st["p50_first_token_s"],
            "p95_first_token_s": st["p95_first_token_s"],
            "prefill_dispatches": eng.prefill_dispatches,
            "decode_dispatches": eng.decode_dispatches,
        }
        emit(f"serve_mixed_{schedule}", dt * 1e6,
             f"tok/s={out[schedule]['tok_per_s']:.1f} "
             f"p95_itl={st['p95_inter_token_s'] * 1e3:.1f}ms "
             f"p95_ttft={st['p95_first_token_s'] * 1e3:.1f}ms")
    itl_ratio = (out["interleaved"]["p95_inter_token_s"]
                 / out["blocking"]["p95_inter_token_s"])
    out["p95_itl_interleaved_over_blocking"] = itl_ratio
    out["tok_per_s_interleaved_over_blocking"] = tps_ratio
    emit("serve_mixed_interleaved_vs_blocking", 0.0,
         f"p95_itl_ratio={itl_ratio:.2f} (target <1) "
         f"tok/s_ratio={tps_ratio:.2f} (target within 10% of 1)")
    return out


def main():
    cfg = _proxy_cfg()
    params = _params(cfg)
    results = {"workload": {"n_requests": N_REQUESTS,
                            "max_batch": SERVE_MAX_BATCH,
                            "max_len": SERVE_MAX_LEN,
                            "prefill_chunk": SERVE_CHUNK,
                            "page_size": PAGE_SIZE}}
    results["prefill"] = bench_prefill(params, cfg)
    results["engines"] = {
        "paged": bench_engine(params, cfg, tag="paged"),
        "slot": bench_engine(params, cfg, kv_layout="slot", tag="slot"),
    }
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0                         # 25% pruned
    results["engines"]["paged_stun_pruned_25pct"] = bench_engine(
        params, cfg, expert_mask=mask, tag="paged_stun_pruned_25pct")
    results["sparse_runtime"] = bench_sparse_runtime()
    results["prefix_cache"] = bench_prefix_cache(params, cfg)
    results["mixed_schedule"] = bench_mixed_schedules(params, cfg)
    results["speculative"] = bench_spec_decode()
    results["spec_sampling"] = bench_spec_sampling()

    paged, slot = results["engines"]["paged"], results["engines"]["slot"]
    ratio = paged["kv_bytes_resident"] / slot["kv_bytes_resident"]
    emit("serve_paged_vs_slot", 0.0,
         f"tok/s={paged['tok_per_s']:.1f}vs{slot['tok_per_s']:.1f} "
         f"kv_bytes_ratio={ratio:.2f} (target <1)")
    emit("serve_prefill_speedup", 0.0,
         f"{results['prefill']['speedup']:.1f}x (target >=5x)")
    with open(JSON_OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {JSON_OUT}")


if __name__ == "__main__":
    main()
