"""Serving benchmark: chunked prefill vs the seed token-by-token engine,
and dense vs STUN-pruned continuous-batching throughput.

Measures, on the mixtral proxy (reduced to CPU scale):

  * prefill dispatch count + wall time at S=128 — the seed engine replayed
    prompts through the jitted decode step (S dispatches); the rebuilt
    engine issues one jitted call per ``prefill_chunk`` tokens, so the
    dispatch count is independent of the token count per dispatch.
  * end-to-end serving tokens/s and p50/p95 request latency for the dense
    model vs the same model with 25% of experts pruned at runtime
    (``expert_mask``) — STUN's serving payoff.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.models import abstract_params, decode_step, init_cache
from repro.models import param as pm
from repro.serving import Request, ServeEngine

S_PROMPT = 128
PREFILL_CHUNK = 32


def _proxy_cfg():
    cfg = reduced(get_config("mixtral-8x7b-proxy"), n_layers=2,
                  n_experts=8, top_k=2)
    return dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                               remat_policy="full")


def _params(cfg):
    p = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    return jax.tree.map(lambda x: x.astype(jnp.float32), p)


def _seed_style_prefill(params, cfg, toks, max_len):
    """The seed engine's prefill: one jitted decode dispatch per token."""
    step = jax.jit(lambda p, c, t, n: decode_step(p, cfg, c, t, n))
    cache = init_cache(cfg, 1, max_len)
    logits = None
    for t in range(toks.shape[1]):
        logits, cache = step(params, cache, toks[:, t: t + 1], jnp.int32(t))
    jax.block_until_ready(logits)
    return toks.shape[1]  # dispatches


def bench_prefill(params, cfg):
    max_len = S_PROMPT + 16
    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(0, cfg.vocab, (1, S_PROMPT)), jnp.int32)

    _seed_style_prefill(params, cfg, toks, max_len)          # compile
    t0 = time.monotonic()
    seed_dispatches = _seed_style_prefill(params, cfg, toks, max_len)
    dt_seed = time.monotonic() - t0

    eng = ServeEngine(params, cfg, max_len=max_len, max_batch=1,
                      prefill_chunk=PREFILL_CHUNK)
    prompt = np.asarray(toks[0])
    eng.generate([Request(prompt, 1)])                       # compile
    eng.reset_stats()
    t0 = time.monotonic()
    eng.generate([Request(prompt, 1)])
    dt_chunked = time.monotonic() - t0
    chunked_dispatches = eng.prefill_dispatches

    emit(f"serve_prefill_seed_S{S_PROMPT}", dt_seed * 1e6,
         f"dispatches={seed_dispatches}")
    emit(f"serve_prefill_chunked_S{S_PROMPT}", dt_chunked * 1e6,
         f"dispatches={chunked_dispatches} chunk={PREFILL_CHUNK} "
         f"speedup={dt_seed / dt_chunked:.1f}x")
    assert chunked_dispatches == S_PROMPT // PREFILL_CHUNK
    return dt_seed / dt_chunked


def bench_serving(params, cfg, expert_mask=None, tag="dense"):
    rs = np.random.RandomState(1)
    lens = rs.randint(8, 48, size=12)
    news = rs.randint(4, 16, size=12)
    reqs = [Request(rs.randint(0, cfg.vocab, l).astype(np.int32), int(n))
            for l, n in zip(lens, news)]
    eng = ServeEngine(params, cfg, max_len=80, max_batch=4,
                      prefill_chunk=16, expert_mask=expert_mask)
    eng.generate(reqs)                                       # compile
    eng.reset_stats()
    t0 = time.monotonic()
    outs = eng.generate(reqs)
    dt = time.monotonic() - t0
    n_tok = sum(len(o) for o in outs)
    stats = eng.latency_stats()
    emit(f"serve_{tag}", dt * 1e6,
         f"tok/s={n_tok / dt:.1f} p50={stats['p50_latency_s'] * 1e3:.0f}ms "
         f"p95={stats['p95_latency_s'] * 1e3:.0f}ms")
    return n_tok / dt


def main():
    cfg = _proxy_cfg()
    params = _params(cfg)
    speedup = bench_prefill(params, cfg)
    bench_serving(params, cfg, tag="dense")
    mask = np.ones(cfg.n_experts, np.float32)
    mask[-cfg.n_experts // 4:] = 0.0                         # 25% pruned
    bench_serving(params, cfg, expert_mask=mask, tag="stun_pruned_25pct")
    emit("serve_prefill_speedup", 0.0, f"{speedup:.1f}x (target >=5x)")


if __name__ == "__main__":
    main()
