"""§5 robustness probe: weight kurtosis before/after each pruning kind.

Claim: expert (structured) pruning preserves kurtosis (the surviving
weights still look Gaussian => room for unstructured pruning remains);
unstructured pruning collapses it toward the bimodal minimum.
"""
from __future__ import annotations

from benchmarks.common import calib, emit, tiny_moe_cfg, train_tiny
from repro.core import expert_prune_moe, model_kurtosis, unstructured_only


def main():
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    batches = calib(cfg)
    k0 = model_kurtosis(params)["__all__"]
    emit("kurtosis/unpruned", 0.0, f"kurtosis={k0:.4f}")

    pe, ce, _, _ = expert_prune_moe(params, cfg, 0.25)
    k1 = model_kurtosis(pe)["__all__"]
    emit("kurtosis/expert_25pct", 0.0,
         f"kurtosis={k1:.4f};delta={k1-k0:+.4f}")

    pu, _, _ = unstructured_only(params, cfg, batches, target_sparsity=0.25,
                                 method="wanda")
    k2 = model_kurtosis(pu)["__all__"]
    emit("kurtosis/wanda_25pct", 0.0,
         f"kurtosis={k2:.4f};delta={k2-k0:+.4f};"
         f"claim_holds={abs(k1-k0) < abs(k2-k0)}")


if __name__ == "__main__":
    main()
