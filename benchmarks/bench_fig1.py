"""Figure 1 analogue: eval loss vs total sparsity, STUN vs OWL-only.

The paper's headline curve (GSM8K accuracy vs sparsity for Arctic):
unstructured-only degrades sharply past ~40%, STUN holds on longer.
"""
from __future__ import annotations

from benchmarks.common import calib, emit, eval_loss, tiny_moe_cfg, train_tiny
from repro.core import stun_prune, unstructured_only


def main():
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    batches = calib(cfg)
    base = eval_loss(params, cfg)
    emit("fig1/sparsity_0", 0.0, f"stun={base:.4f};owl={base:.4f}")
    for sp in (0.3, 0.4, 0.5, 0.6, 0.7):
        p1, c1, _, _ = stun_prune(params, cfg, batches, target_sparsity=sp,
                                  expert_ratio=0.25, unstructured="owl")
        l1 = eval_loss(p1, c1)
        p2, _, _ = unstructured_only(params, cfg, batches,
                                     target_sparsity=sp, method="owl")
        l2 = eval_loss(p2, cfg)
        emit(f"fig1/sparsity_{int(sp*100)}", 0.0,
             f"stun={l1:.4f};owl={l2:.4f};stun_wins={l1 < l2}")


if __name__ == "__main__":
    main()
