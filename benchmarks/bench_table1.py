"""Table 1 analogue: STUN vs unstructured-only at matched total sparsity.

Paper: Arctic/Mixtral on GSM8K+NLU at 40–70% sparsity; here: the trained
tiny MoE's held-out eval loss (lower = better) at 40% and 65%.  The claim
under test: STUN (expert-prune, then Wanda/OWL) beats Wanda/OWL alone at
the same total sparsity.
"""
from __future__ import annotations

from benchmarks.common import (Timer, calib, emit, eval_loss, tiny_moe_cfg,
                               train_tiny)
from repro.core import stun_prune, unstructured_only


def main():
    cfg = tiny_moe_cfg()
    params = train_tiny(cfg, "tiny_moe")
    batches = calib(cfg)
    base = eval_loss(params, cfg)
    emit("table1/unpruned", 0.0, f"eval_loss={base:.4f}")

    for sparsity in (0.4, 0.65):
        for method in ("owl", "wanda"):
            with Timer() as t:
                p, c, _, rep = stun_prune(params, cfg, batches,
                                          target_sparsity=sparsity,
                                          expert_ratio=0.25,
                                          unstructured=method)
            l_stun = eval_loss(p, c)
            emit(f"table1/stun_{method}_{int(sparsity*100)}",
                 t.seconds * 1e6,
                 f"eval_loss={l_stun:.4f};delta={l_stun-base:+.4f}")

            with Timer() as t:
                p2, _, r2 = unstructured_only(params, cfg, batches,
                                              target_sparsity=sparsity,
                                              method=method)
            l_unstr = eval_loss(p2, cfg)
            emit(f"table1/{method}_only_{int(sparsity*100)}",
                 t.seconds * 1e6,
                 f"eval_loss={l_unstr:.4f};delta={l_unstr-base:+.4f};"
                 f"stun_wins={l_stun < l_unstr}")


if __name__ == "__main__":
    main()
