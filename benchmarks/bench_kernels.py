"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels cannot execute compiled (interpret
mode measures the Python interpreter, not the TPU), so we time the jitted
jnp reference path — the same math the kernel implements — and derive
bytes/FLOPs rates.  The TPU-side performance story for each kernel lives
in the §Roofline/§Perf analysis (VMEM tiling budgets in each kernel's
docstring).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import random

from benchmarks.common import emit
from repro.kernels import ref

RNG = random.PRNGKey(0)


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6  # us


def main():
    # flash attention ref
    B, H, S, hd = 1, 4, 1024, 64
    q = random.normal(RNG, (B, H, S, hd), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time(fn, q, q, q)
    flops = 4 * B * H * S * S * hd
    emit("kernels/flash_attention_ref", us,
         f"gflops={flops/us/1e3:.2f};shape=B{B}H{H}S{S}hd{hd}")

    # moe gmm ref
    E, C, D, F = 8, 256, 256, 512
    buf = random.normal(RNG, (E, C, D), jnp.float32)
    w = random.normal(RNG, (E, D, F), jnp.float32)
    fn = jax.jit(ref.moe_gmm_ref)
    us = _time(fn, buf, w)
    flops = 2 * E * C * D * F
    emit("kernels/moe_gmm_ref", us, f"gflops={flops/us/1e3:.2f};E{E}C{C}D{D}F{F}")

    # block sparse matmul ref (50% block density)
    M, K, N, bk, bn = 512, 512, 512, 128, 128
    x = random.normal(RNG, (M, K), jnp.float32)
    wd = random.normal(RNG, (K, N), jnp.float32)
    bm = jnp.asarray(np.random.RandomState(0).rand(K // bk, N // bn) < 0.5)
    fn = jax.jit(lambda x, w, m: ref.block_sparse_matmul_ref(x, w, m, bk, bn))
    us = _time(fn, x, wd, bm)
    emit("kernels/block_sparse_ref", us,
         f"dense_gflops={2*M*K*N/us/1e3:.2f};block_density=0.5")

    # wanda mask apply ref
    K2, N2 = 2048, 2048
    w2 = random.normal(RNG, (K2, N2), jnp.float32)
    xn = jnp.abs(random.normal(RNG, (K2,)))
    th = jnp.abs(random.normal(RNG, (N2,)))
    fn = jax.jit(ref.wanda_mask_apply_ref)
    us = _time(fn, w2, xn, th)
    gb = 2 * K2 * N2 * 4 / 1e9
    emit("kernels/wanda_mask_ref", us, f"gbps={gb/(us/1e6):.2f}")

    # rglru scan ref
    B2, S2, W2 = 4, 512, 256
    a = jax.nn.sigmoid(random.normal(RNG, (B2, S2, W2)))
    b = random.normal(RNG, (B2, S2, W2))
    fn = jax.jit(ref.rglru_scan_ref)
    us = _time(fn, a, b)
    emit("kernels/rglru_scan_ref", us,
         f"elems_per_us={B2*S2*W2/us:.0f}")


if __name__ == "__main__":
    main()
