"""Figure 2 analogue (RQ3): the STUN-vs-unstructured gap grows with the
number of (smaller) experts.

Paper: gap increases from Mixtral-8x22B (few large experts) to Arctic
(128 small experts).  Here: 4/8/16-expert tiny MoEs at fixed total expert
parameters (moe_d_ff scales inversely), same total sparsity.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import calib, emit, eval_loss, tiny_moe_cfg, train_tiny
from repro.core import stun_prune, unstructured_only


def main():
    for n_e, ff in ((4, 64), (8, 32), (16, 16)):
        cfg = tiny_moe_cfg(n_experts=n_e, top_k=2)
        cfg = dataclasses.replace(cfg, moe_d_ff=ff)
        params = train_tiny(cfg, f"tiny_moe_e{n_e}")
        batches = calib(cfg)
        base = eval_loss(params, cfg)
        p1, c1, _, _ = stun_prune(params, cfg, batches, target_sparsity=0.5,
                                  expert_ratio=0.25, unstructured="owl")
        l1 = eval_loss(p1, c1)
        p2, _, _ = unstructured_only(params, cfg, batches,
                                     target_sparsity=0.5, method="owl")
        l2 = eval_loss(p2, cfg)
        emit(f"fig2/experts_{n_e}", 0.0,
             f"base={base:.4f};stun={l1:.4f};owl={l2:.4f};gap={l2-l1:+.4f}")


if __name__ == "__main__":
    main()
