"""Shared benchmark substrate: a trained tiny MoE (cached), eval harness.

The paper evaluates pruning on trained MoEs (Arctic/Mixtral) with
GSM8K/NLU suites; our CPU-scale analogue trains a tiny MoE on the
synthetic Markov LM until it clearly beats the unigram floor, then
measures held-out eval loss after each pruning strategy.  All tables
reuse ONE cached model so the whole suite runs in minutes.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, reduced
from repro.data.synthetic import SyntheticLM, batch_iterator, make_batch
from repro.models import abstract_params, loss_fn
from repro.models import param as pm
from repro.optim import AdamWConfig
from repro.runtime import TrainLoopConfig, train_loop

CACHE = os.path.join(os.path.dirname(__file__), "..", "experiments", "cache")
DATA_SEED = 11


def tiny_moe_cfg(n_experts: int = 8, top_k: int = 2, n_layers: int = 2,
                 d_model: int = 64):
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=n_layers,
                  n_experts=n_experts, top_k=top_k, d_model=d_model,
                  vocab=256)
    return dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                               remat_policy="full")


def tiny_dense_cfg(n_layers: int = 2, d_model: int = 64):
    cfg = reduced(get_config("qwen2-7b"), n_layers=n_layers, d_model=d_model,
                  vocab=256)
    return dataclasses.replace(cfg, dtype="float32", remat_policy="full")


def train_tiny(cfg, name: str, steps: int = 400, batch: int = 8,
               seq: int = 64):
    """Train (or load cached) params for `cfg` on the synthetic LM."""
    ckdir = os.path.join(CACHE, name)
    if latest_step(ckdir) is not None:
        _, tree = restore_checkpoint(ckdir)
        return jax.tree.map(jnp.asarray, tree["params"])
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    it = batch_iterator(cfg, batch, seq, seed=DATA_SEED)
    lc = TrainLoopConfig(total_steps=steps, checkpoint_every=10 ** 9,
                         log_every=100, warmup_steps=20)
    params, _, hist = train_loop(cfg, params, it, lc,
                                 AdamWConfig(lr=1e-3, weight_decay=0.01),
                                 log_fn=lambda *a: None)
    save_checkpoint(ckdir, steps, {"params": jax.tree.map(np.asarray,
                                                          params)})
    return params


def eval_loss(params, cfg, n_batches: int = 8, batch: int = 8,
              seq: int = 64) -> float:
    """Held-out eval loss (steps beyond the training range)."""
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    fn = jax.jit(lambda p, b: loss_fn(p, cfg, b))
    tot = 0.0
    for i in range(n_batches):
        b = make_batch(lm, batch, seq, step=10_000 + i,
                       d_model=cfg.d_model, frontend_stub=cfg.frontend_stub)
        tot += float(fn(params, b))
    return tot / n_batches


def calib(cfg, n: int = 4):
    lm = SyntheticLM(vocab=cfg.vocab, seed=DATA_SEED)
    return [make_batch(lm, 4, 64, step=5000 + i, d_model=cfg.d_model,
                       frontend_stub=cfg.frontend_stub) for i in range(n)]


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
