"""The O(1)-vs-O(k^n/√n) cost claim: wall-clock of our expert pruning vs
the combinatorial forward-pass count, as n grows (footnote 2's 2.4e37
number for n=128 is reproduced analytically)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit
from repro.core import behavioral_distance, cluster_experts, n_combinations
from repro.core.expert_prune import representatives


def main():
    rs = np.random.RandomState(0)
    for n in (16, 32, 64, 128):
        W = rs.randn(n, 256).astype(np.float32)       # router rows
        flat = rs.randn(n, 4096).astype(np.float32)   # expert params
        with Timer() as t:
            dist = behavioral_distance(W)
            labels = cluster_experts(dist, int(n * 0.75))
            representatives(flat, labels, kappa=3)
        combos = n_combinations(n, 0.25)
        emit(f"scaling/experts_{n}", t.seconds * 1e6,
             f"ours_fwd_passes=0;combinatorial_fwd_passes={combos:.3e}")


if __name__ == "__main__":
    main()
