"""Docs checker (CI `docs` job, `make docs-check`).

Two checks over the project's markdown docs:

  * every relative markdown link ``[text](target)`` resolves to a file
    or directory in the repo (anchors and external URLs are skipped);
  * ``python -m doctest`` passes on every doctested document (doctest
    scans text files for ``>>>`` examples; documents without examples
    pass trivially).

Run from the repo root: ``python tools/check_docs.py``.
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# the doctests import repro.*; make `python tools/check_docs.py` work
# without requiring the caller to export PYTHONPATH=src
sys.path.insert(0, str(ROOT / "src"))
DOCS = ["README.md", "docs/serving.md", "docs/sparse.md",
        "docs/analysis.md", "docs/observability.md", "ROADMAP.md",
        "PAPER.md"]

# [text](target) — excluding images and fenced code spans is overkill for
# these docs; inline code never contains the ](... sequence we match
LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def check_links(md: Path) -> list:
    errors = []
    for target in LINK.findall(md.read_text()):
        if re.match(r"^[a-z]+://", target) or target.startswith("mailto:"):
            continue                                  # external URL
        path = target.split("#", 1)[0]
        if not path:
            continue                                  # same-file anchor
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main() -> int:
    errors = []
    # a docs/*.md not registered in DOCS is silently unchecked forever —
    # fail loudly instead so new design notes opt into the link/doctest
    # checks the moment they land
    for md in sorted((ROOT / "docs").glob("*.md")):
        rel = str(md.relative_to(ROOT))
        if rel not in DOCS:
            errors.append(f"dangling document: {rel} exists but is not "
                          f"registered in tools/check_docs.py DOCS")
    for name in DOCS:
        md = ROOT / name
        if not md.exists():
            errors.append(f"missing document: {name}")
            continue
        link_errs = check_links(md)
        errors.extend(link_errs)
        fails, tests = doctest.testfile(str(md), module_relative=False)
        if fails:
            errors.append(f"{name}: {fails} doctest failure(s)")
        print(f"{name}: {len(link_errs)} broken links, "
              f"{tests - fails}/{tests} doctests passed")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print("docs check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
