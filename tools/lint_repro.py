"""Repo lint driver (CI `lint` job, `make lint`).

Runs the dispatch-safety checkers from :mod:`repro.analysis` over every
``.py`` file under the given paths and prints findings as
``path:line: [severity] check: message`` — one line per finding, sorted,
greppable, and clickable in most terminals.

Exit status: non-zero when any **error**-severity finding (including
``unexplained-suppression``) survives; ``--strict`` also fails on
warnings.  Suppress a finding in source with a justified marker::

    x = jnp.asarray(self.buf)  # repro-lint: disable=aliasing-hazard -- why

A marker without the ``-- why`` tail is itself an error finding that
cannot be suppressed, so the lint never ships an unexplained exemption.

Run from the repo root: ``python tools/lint_repro.py src/ --strict``.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
# the checkers live in-repo; make `python tools/lint_repro.py` work
# without requiring the caller to export PYTHONPATH=src
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import analyze_file, checkers_for  # noqa: E402


def iter_python_files(paths):
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p
        else:
            print(f"warning: skipping non-python path {raw}",
                  file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="dispatch-safety lint for the repro serving stack")
    ap.add_argument("paths", nargs="*", default=["src/"],
                    help="files or directories to lint (default: src/)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings too, not just errors")
    ap.add_argument("--check", action="append", default=None,
                    help="run only the named checker(s); repeatable")
    args = ap.parse_args(argv)
    paths = args.paths or ["src/"]

    findings = []
    n_files = 0
    for py in iter_python_files(paths):
        checkers = checkers_for(str(py))
        if args.check is not None:
            checkers = [c for c in checkers if c.name in args.check]
        if not checkers:
            continue
        n_files += 1
        findings.extend(analyze_file(str(py), checkers))

    errors = [f for f in findings if f.severity == "error"]
    warnings = [f for f in findings if f.severity == "warning"]
    for f in findings:
        print(f"{f.path}:{f.line}: [{f.severity}] {f.check}: {f.message}")
    print(f"lint: {n_files} files, {len(errors)} error(s), "
          f"{len(warnings)} warning(s)")
    if errors or (args.strict and warnings):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
