#!/usr/bin/env python
"""Validate a Chrome-trace-event JSON export (``Tracer.export`` output).

Checks the structural contract Perfetto / chrome://tracing rely on, so
``make trace-smoke`` fails in CI when an exporter change would produce a
file the viewers silently drop events from:

  * top level is an object with a ``traceEvents`` list (and our exports
    carry ``displayTimeUnit``);
  * every event has ``ph``/``name``/``pid``/``tid``; ``ts`` (and ``dur``
    for complete events) are non-negative numbers in microseconds;
  * ``ph`` is one of ``X`` (complete span), ``i`` (instant, with a
    scope ``s``), ``M`` (metadata — ``thread_name``/``process_name``
    with ``args.name``);
  * every ``tid`` that carries spans has a ``thread_name`` metadata
    event, so tracks render with names instead of bare numbers.

Importable: ``validate(trace) -> List[str]`` returns human-readable
errors (empty = valid).  CLI: ``python tools/validate_trace.py out.json``
exits non-zero and prints each error on failure.
"""
from __future__ import annotations

import json
import sys
from typing import List

_PHASES = {"X", "i", "M"}


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate(trace) -> List[str]:
    """Structural errors in a parsed Chrome-trace dict (empty = valid)."""
    errors: List[str] = []
    if not isinstance(trace, dict):
        return [f"top level must be an object, got {type(trace).__name__}"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["missing/invalid 'traceEvents' (must be a list)"]
    named_tids = set()
    span_tids = set()
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: event must be an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: unknown phase {ph!r} "
                          f"(expected one of {sorted(_PHASES)})")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        if ph == "M":
            if ev.get("name") not in ("thread_name", "process_name"):
                errors.append(f"{where}: metadata name must be "
                              f"thread_name/process_name, got "
                              f"{ev.get('name')!r}")
            if not isinstance(ev.get("args", {}).get("name"), str):
                errors.append(f"{where}: metadata needs args.name (str)")
            elif ev.get("name") == "thread_name":
                named_tids.add(ev.get("tid"))
            continue
        if not _num(ev.get("ts")) or ev["ts"] < 0:
            errors.append(f"{where}: ts must be a non-negative number "
                          f"(microseconds), got {ev.get('ts')!r}")
        if ph == "X":
            if not _num(ev.get("dur")) or ev["dur"] < 0:
                errors.append(f"{where}: complete event needs "
                              f"non-negative numeric dur, got "
                              f"{ev.get('dur')!r}")
            span_tids.add(ev.get("tid"))
        elif ph == "i" and ev.get("s") not in ("t", "p", "g"):
            errors.append(f"{where}: instant event needs scope s in "
                          f"t/p/g, got {ev.get('s')!r}")
    for tid in sorted(span_tids - named_tids, key=str):
        errors.append(f"tid {tid} carries spans but has no thread_name "
                      f"metadata — the track renders unnamed")
    return errors


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    if len(args) != 1:
        print("usage: validate_trace.py TRACE_JSON", file=sys.stderr)
        return 2
    with open(args[0]) as f:
        trace = json.load(f)
    errors = validate(trace)
    for e in errors:
        print(f"{args[0]}: {e}", file=sys.stderr)
    if not errors:
        n = len(trace["traceEvents"])
        print(f"{args[0]}: valid Chrome trace ({n} events)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
