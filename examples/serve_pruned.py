"""End-to-end serving driver: batched requests against an unpruned vs a
STUN-pruned MoE — the paper's serving-cost story in one script.

    PYTHONPATH=src python examples/serve_pruned.py

Trains a tiny MoE, prunes with STUN, serves a batch of requests through
the engine with both checkpoints and reports tokens/s, parameter bytes
resident, and expert-weight bytes (the MoE serving bottleneck the paper
targets).

``--spec-decode`` additionally turns the pruning artifact into a serving
*speedup*: STUN's stage-1 expert keep-mask becomes the drafter of a
self-speculative engine (pruned model drafts ``--spec-k`` tokens per
round, the dense model verifies the block in one dispatch).  Output is
token-identical to plain dense decode; the script prints the accept rate
and speedup from ``latency_stats()``.  The non-speculative comparison
stays the default.

``--prefix-cache`` serves a shared-system-prompt workload through the
radix-tree prefix cache: repeats of a cached prompt claim its KV pages
straight from the trie and cost zero prefill dispatches.

Engine API (repro.serving)
--------------------------
``ServeEngine(params, cfg, max_len=, max_batch=, prefill_chunk=,
expert_mask=, weight_masks=, seed=)`` is a continuous-batching engine:

  * ``submit(Request(prompt, max_new_tokens, eos_id=, temperature=))``
    queues a request and returns its id; ``run()`` drains the queue;
    ``generate([...])`` is the submit+run+collect convenience wrapper.
  * Prompts are prefilled in fixed-size chunks — one jitted dispatch per
    ``prefill_chunk`` tokens (NOT per token), writing K/V straight into
    the request's cache slot with padded positions masked out.  Under the
    default ``schedule="interleaved"`` at most ``prefill_budget`` prompt
    tokens are dispatched per engine step next to the decode dispatch, so
    a long prompt never stalls the other lanes' token streams
    (``schedule="blocking"`` keeps run-prefill-to-completion).
  * Decode is one jitted call per step for *all* in-flight requests —
    K/V lives in a paged cache (fixed-size pages + per-lane page tables,
    fused Pallas paged-decode attention on TPU), so admission is gated on
    free pages rather than whole ``max_len`` slots; each request stops at
    its own EOS / ``max_new_tokens`` and its pages immediately return to
    the pool for the next queued request (``kv_layout="slot"`` keeps the
    legacy slot-granular cache).
  * Pruned serving: pass the compacted STUN checkpoint directly, or keep
    the full checkpoint and pass ``expert_mask`` ([E] or [L, E]) /
    ``weight_masks`` (stage-2 masks from ``sparsify_model``) to apply
    pruning at runtime.
  * ``latency_stats()`` reports per-request p50/p95 full-request and
    first-token latencies, cache gauges, and (in spec mode) accept-rate
    counters.
  * Self-speculative decoding: ``spec_decode="pruned"`` + ``spec_k=`` —
    ``expert_mask`` / ``weight_masks`` / ``draft_params`` then describe
    the *drafter* while the dense params verify, so output quality is
    exactly the dense model's.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import stun_prune
from repro.data.synthetic import batch_iterator, calibration_batches
from repro.models import abstract_params
from repro.models import param as pm
from repro.optim import AdamWConfig
from repro.runtime import TrainLoopConfig, train_loop
from repro.serving import Request, ServeEngine


def param_bytes(params):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def expert_bytes(params):
    moe = params["layers"]["moe"]
    return sum(np.asarray(moe[k]).nbytes
               for k in ("we_gate", "we_up", "we_down"))


def serve_and_time(params, cfg, requests, max_len=96, max_batch=None,
                   **kwargs):
    eng = ServeEngine(params, cfg, max_len=max_len,
                      max_batch=max_batch or len(requests),
                      prefill_chunk=16, **kwargs)
    eng.generate(requests)            # includes compile
    eng.reset_stats()
    t0 = time.monotonic()
    out = eng.generate(requests)
    dt = time.monotonic() - t0
    n_tok = sum(len(o) for o in out)
    return out, n_tok / dt, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-decode", action="store_true",
                    help="also serve via self-speculative decoding "
                         "(STUN expert keep-mask drafts, dense verifies)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--spec-tree", type=int, default=1,
                    help="draft-tree branches per round (>1 scores a "
                         "token tree in one verify dispatch; 1 = chain)")
    ap.add_argument("--schedule", choices=["interleaved", "blocking"],
                    default="interleaved",
                    help="prefill/decode schedule (interleaved meters "
                         "prefill at --prefill-budget tokens per step so "
                         "decode lanes never stall; outputs are "
                         "token-identical either way)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prompt tokens of prefill per step under the "
                         "interleaved schedule (default: one chunk)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="also serve a shared-system-prompt workload "
                         "with radix-tree prefix caching: repeats claim "
                         "the cached prompt's KV pages and skip prefill "
                         "entirely (zero prefill dispatches)")
    ap.add_argument("--sparse-runtime", action="store_true",
                    help="also serve through the sparse pruned-artifact "
                         "runtime: stage-2 masks (+ the stage-1 expert "
                         "keep-mask) are planned into block bitmaps, "
                         "packed into block pools (repro.sparse), and "
                         "served physically smaller — output is "
                         "token-identical to dense-masked serving")
    args = ap.parse_args()
    sched_kwargs = {"schedule": args.schedule,
                    "prefill_budget": args.prefill_budget}
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8,
                  top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    print("== training ==")
    params, _, _ = train_loop(
        cfg, params, batch_iterator(cfg, 8, 64, seed=11),
        TrainLoopConfig(total_steps=200, log_every=100, warmup_steps=20),
        AdamWConfig(lr=1e-3))

    print("== STUN pruning (40% total; 25% experts) ==")
    batches = calibration_batches(cfg, n_batches=4)
    pruned, pcfg, _, _ = stun_prune(params, cfg, batches,
                                    target_sparsity=0.4, expert_ratio=0.25,
                                    unstructured="owl")

    rs = np.random.RandomState(0)
    requests = [Request(rs.randint(0, cfg.vocab, 12).astype(np.int32),
                        max_new_tokens=16) for _ in range(8)]

    print("== serving: unpruned ==")
    out0, tps0, _ = serve_and_time(params, cfg, requests,
                                   **sched_kwargs)
    print(f"tokens/s={tps0:.1f} params={param_bytes(params)/1e6:.2f}MB "
          f"expert_bytes={expert_bytes(params)/1e6:.2f}MB")

    print("== serving: STUN-pruned ==")
    out1, tps1, _ = serve_and_time(pruned, pcfg, requests,
                                   **sched_kwargs)
    print(f"tokens/s={tps1:.1f} params={param_bytes(pruned)/1e6:.2f}MB "
          f"expert_bytes={expert_bytes(pruned)/1e6:.2f}MB")

    agree = np.mean([float(np.mean(a[:8] == b[:8]))
                     for a, b in zip(out0, out1)])
    print(f"first-8-token agreement pruned vs unpruned: {agree:.2%}")
    print(f"expert-weight reduction: "
          f"{1 - expert_bytes(pruned)/expert_bytes(params):.0%}")

    if args.prefix_cache:
        print("== serving: prefix caching (shared system prompt) ==")
        # a page-aligned prompt served once, then repeated: every repeat
        # claims all its KV pages from the radix trie and costs ZERO
        # prefill dispatches (row S-1 is COW-forked; the final prompt
        # token replays through the ordinary decode dispatch)
        sys_prompt = rs.randint(0, cfg.vocab, 48).astype(np.int32)
        eng = ServeEngine(pruned, pcfg, max_len=96, max_batch=2,
                          prefill_chunk=16, page_size=16,
                          prefix_cache=True, **sched_kwargs)
        out_cold = eng.generate([Request(sys_prompt, max_new_tokens=16)])
        p_cold = eng.prefill_dispatches
        eng.reset_stats()
        t0 = time.monotonic()
        outs = eng.generate([Request(sys_prompt, max_new_tokens=16)
                             for _ in range(4)])
        dt = time.monotonic() - t0
        st = eng.latency_stats()
        identical = all(bool(np.all(o == out_cold[0])) for o in outs)
        n_tok = sum(len(o) for o in outs)
        print(f"tokens/s={n_tok / dt:.1f} "
              f"repeat_prefill_dispatches={eng.prefill_dispatches} "
              f"(cold wave paid {p_cold}) "
              f"hit_rate={st['prefix_hit_rate']:.2f} "
              f"cow_forks={st['cow_forks']:.0f} "
              f"token-identical-to-cold={identical}")

    if args.sparse_runtime:
        from repro import sparse
        from repro.core.expert_prune import expert_prune_moe
        from repro.core.stun import unstructured_only

        print("== serving: sparse pruned-artifact runtime ==")
        # mask-form STUN: stage-1 keep-mask + stage-2 masks on the FULL
        # model, then plan/pack the expert FFNs into block pools.  The
        # dense-masked engine serving the plan's masks is the baseline
        # the packed engine must reproduce token for token.
        _, _, keep_mask, _ = expert_prune_moe(params, cfg, 0.25, mode="mask")
        _, masks, _ = unstructured_only(params, cfg, batches,
                                        target_sparsity=0.2, method="owl")
        plan = sparse.plan_sparse_ffn(
            masks, sparse.ffn_weights_from_params(params, cfg),
            block=(16, 16), expert_mask=keep_mask,
            target_block_sparsity=0.4)
        packed, prep = sparse.pack_sparse_ffn(params, cfg, plan)
        masks.update(plan.element_masks())
        out_m, tps_m, _ = serve_and_time(params, cfg, requests,
                                         expert_mask=keep_mask,
                                         weight_masks=masks, **sched_kwargs)
        out_s, tps_s, _ = serve_and_time(params, cfg, requests,
                                         expert_mask=keep_mask,
                                         weight_masks=masks,
                                         sparse_weights=packed,
                                         **sched_kwargs)
        identical = all(bool(np.all(a == b)) for a, b in zip(out_m, out_s))
        print(f"tokens/s={tps_s:.1f} ({tps_s / tps_m:.2f}x dense-masked) "
              f"expert_ffn_bytes={prep['packed_bytes'] / 1e6:.2f}MB "
              f"({prep['bytes_ratio']:.2f}x dense) "
              f"block_sparsity={prep['block_sparsity']:.1%} "
              f"token-identical-to-dense-masked={identical}")

    if args.spec_decode:
        from repro.core.expert_prune import expert_prune_moe

        print("== serving: self-speculative (pruned draft, dense verify) ==")
        # speculation pays in the latency-bound regime (few concurrent
        # lanes, dispatch overhead per token dominates), so compare at
        # max_batch=2 — at full batch the CPU is compute-bound and plain
        # batched decode is already efficient
        out0b, tps0b, _ = serve_and_time(params, cfg, requests, max_batch=2,
                                         **sched_kwargs)
        # stage-1 keep-mask ([L, E]) in mask form: same clustering decision
        # as the compact checkpoint above, but usable as a runtime drafter
        _, _, keep_mask, _ = expert_prune_moe(params, cfg, 0.25,
                                              mode="mask")
        out2, tps2, eng = serve_and_time(params, cfg, requests, max_batch=2,
                                         spec_decode="pruned",
                                         spec_k=args.spec_k,
                                         spec_tree=args.spec_tree,
                                         expert_mask=keep_mask,
                                         **sched_kwargs)
        # dense-identical (hard-asserted in tests; reported here)
        identical = all(bool(np.all(a == b)) for a, b in zip(out0b, out2))
        st = eng.latency_stats()
        print(f"tokens/s={tps2:.1f} ({tps2 / tps0b:.2f}x plain dense at "
              f"the same concurrency) "
              f"accept_rate={st['spec_accept_rate']:.2f} "
              f"tok/verify={st['spec_tokens_per_verify']:.1f} "
              f"k={args.spec_k} tree={args.spec_tree} "
              f"token-identical-to-dense={identical}")


if __name__ == "__main__":
    main()
