"""Quickstart: build a tiny MoE, train briefly, STUN-prune it, compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import stun_prune, unstructured_only
from repro.data.synthetic import batch_iterator, calibration_batches
from repro.models import abstract_params, loss_fn
from repro.models import param as pm
from repro.optim import AdamWConfig
from repro.runtime import TrainLoopConfig, train_loop


def main():
    # 1. a reduced same-family config of the assigned olmoe-1b-7b
    cfg = reduced(get_config("olmoe-1b-7b"), n_layers=2, n_experts=8, top_k=2)
    cfg = dataclasses.replace(cfg, moe_impl="dense", dtype="float32",
                              remat_policy="full")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)

    # 2. brief training on the synthetic Markov LM
    print("== training tiny MoE (200 steps) ==")
    params, _, _ = train_loop(
        cfg, params, batch_iterator(cfg, 8, 64, seed=11),
        TrainLoopConfig(total_steps=200, log_every=50, warmup_steps=20),
        AdamWConfig(lr=1e-3))

    batches = calibration_batches(cfg, n_batches=4)
    base = float(loss_fn(params, cfg, batches[0]))
    print(f"eval loss unpruned: {base:.4f}")

    # 3. STUN at 40% total sparsity (25% experts first, then OWL)
    pruned, pcfg, _, report = stun_prune(params, cfg, batches,
                                         target_sparsity=0.4,
                                         expert_ratio=0.25,
                                         unstructured="owl")
    l_stun = float(loss_fn(pruned, pcfg, batches[0]))
    print(f"STUN  40%: loss={l_stun:.4f} "
          f"(experts {cfg.n_experts}->{pcfg.n_experts}, "
          f"then OWL at {report.unstructured_ratio:.0%})")

    # 4. baseline: OWL-only at the same total sparsity
    owl, _, _ = unstructured_only(params, cfg, batches, target_sparsity=0.4,
                                  method="owl")
    l_owl = float(loss_fn(owl, cfg, batches[0]))
    print(f"OWL-only 40%: loss={l_owl:.4f}")
    print(f"STUN wins: {l_stun < l_owl}")


if __name__ == "__main__":
    main()
