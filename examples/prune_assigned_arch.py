"""Run the full STUN pipeline against any assigned architecture (reduced
to CPU scale) — demonstrates the --arch selectable config surface.

    PYTHONPATH=src python examples/prune_assigned_arch.py --arch qwen2-7b
    PYTHONPATH=src python examples/prune_assigned_arch.py --arch olmoe-1b-7b

MoE archs get expert pruning (stage 1); dense/ssm/hybrid archs get the
RQ5 structured FFN stage (§6.2.5), exactly as DESIGN.md §Arch-applicability
prescribes.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.core import stun_prune
from repro.data.synthetic import calibration_batches
from repro.models import abstract_params, loss_fn
from repro.models import param as pm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--sparsity", type=float, default=0.4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="full",
                              moe_impl="dense")
    print(f"arch={args.arch} family={cfg.family} "
          f"(reduced: {cfg.n_layers}L d{cfg.d_model})")
    params = pm.init_params(abstract_params(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    batches = calibration_batches(cfg, n_batches=2)
    base = float(loss_fn(params, cfg, batches[0]))

    structured = 0.25 if cfg.family == "moe" else 0.05
    pruned, pcfg, masks, report = stun_prune(
        params, cfg, batches, target_sparsity=args.sparsity,
        expert_ratio=structured, unstructured="owl")
    after = float(loss_fn(pruned, pcfg, batches[0]))
    print(f"loss: {base:.4f} -> {after:.4f} at {args.sparsity:.0%} sparsity")
    print(f"stage1 removed {report.structured_ratio:.1%} of prunable "
          f"params structurally; stage2 OWL at {report.unstructured_ratio:.1%}")
    print(f"kurtosis: {report.kurtosis_before['__all__']:.2f} -> "
          f"{report.kurtosis_after_structured['__all__']:.2f} (structured) "
          f"-> {report.kurtosis_after_unstructured['__all__']:.2f} (final)")


if __name__ == "__main__":
    main()
