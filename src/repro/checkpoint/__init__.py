from repro.checkpoint.store import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.sparse_artifact import (  # noqa: F401
    masks_from_tree,
    masks_to_tree,
)
