"""Checkpoint format for pruning artifacts: masks + packed sparse FFN.

``save_checkpoint`` flattens any nested dict of arrays, so both artifacts
are stored as plain subtrees next to ``params`` in the same step
directory (block index, pool, and permutations all land in the manifest
as ordinary leaves — no side files, atomicity for free):

    step_N/
      manifest.msgpack      params/..., masks/..., sparse_ffn/...
      shard_0.bin

``masks`` — the ``{(layer, path) -> bool ndarray}`` dict from
``core.unstructured.sparsify_model``, stored under
``masks/<layer>/<path...>`` so pruning runs are resumable and
inspectable without recomputing Wanda/OWL scores.

``sparse_ffn`` — the packed artifact from ``sparse.pack_sparse_ffn``
(already a plain dict of arrays: ``pool`` / ``index`` / ``perm_k`` /
``perm_n`` per expert FFN matrix), stored verbatim; feed it back to
``ServeEngine(sparse_weights=...)`` or ``sparse.install_sparse_ffn``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def masks_to_tree(masks: Dict[Tuple[int, tuple], np.ndarray]) -> Dict:
    """{(layer, path) -> mask} -> nested checkpoint subtree."""
    tree: Dict = {}
    for (layer, path), mask in masks.items():
        node = tree.setdefault(str(layer), {})
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = np.asarray(mask, bool)
    return tree


def masks_from_tree(tree: Dict) -> Dict[Tuple[int, tuple], np.ndarray]:
    """Inverse of ``masks_to_tree`` (restore path)."""
    masks: Dict = {}

    def walk(node, layer, prefix):
        for key, val in node.items():
            if isinstance(val, dict):
                walk(val, layer, prefix + (key,))
            else:
                masks[(layer, prefix + (key,))] = np.asarray(val, bool)

    for layer_str, sub in tree.items():
        walk(sub, int(layer_str), ())
    return masks
