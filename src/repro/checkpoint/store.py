"""Sharded, atomic, resharding-tolerant checkpointing.

Layout:  <dir>/step_<N>/manifest.msgpack  (tree structure, shapes, dtypes)
         <dir>/step_<N>/shard_<host>.bin  (zstd-compressed concatenated
                                           leaf bytes owned by this host)
Atomicity: written to `step_<N>.tmp`, fsync'd, renamed — a crashed writer
never leaves a readable-but-partial step.  Restore returns numpy leaves, so
the caller can `device_put` onto *any* mesh (elastic restart: mesh shape at
restore time may differ from save time).  On multi-host deployments each
host writes the leaves it owns (addressable shards); this container is
single-host so host 0 owns everything.
"""
from __future__ import annotations

import io
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
import msgpack
import numpy as np

try:  # zstd is an optional dependency; shards fall back to raw bytes
    import zstandard as zstd
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - environment-dependent
    zstd = None
    HAVE_ZSTD = False


def _encode_shard(raw: bytes) -> Tuple[str, bytes]:
    if HAVE_ZSTD:
        return "zstd", zstd.ZstdCompressor(level=3).compress(raw)
    return "raw", raw


def _decode_shard(codec: str, blob: bytes) -> bytes:
    if codec == "raw":
        return blob
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError(
                "checkpoint shard is zstd-compressed but the 'zstandard' "
                "module is not installed; `pip install zstandard` to restore")
        return zstd.ZstdDecompressor().decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    else:
        yield "/".join(prefix), tree


def _unflatten(items):
    root: dict = {}
    for path, val in items:
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val
    return root


def save_checkpoint(directory: str, step: int, tree: Any,
                    host_id: int = 0, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = list(_flatten(tree))
    manifest = []
    buf = io.BytesIO()
    offset = 0
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        manifest.append({"path": path, "shape": list(arr.shape),
                         "dtype": str(arr.dtype), "offset": offset,
                         "nbytes": len(raw), "host": host_id})
        buf.write(raw)
        offset += len(raw)
    codec, blob = _encode_shard(buf.getvalue())
    with open(os.path.join(tmp, f"shard_{host_id}.bin"), "wb") as f:
        f.write(blob)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb({"step": step, "codec": codec,
                               "leaves": manifest}))
    # atomic publish
    for fname in os.listdir(tmp):
        fd = os.open(os.path.join(tmp, fname), os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    os.rename(tmp, final)
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int] = None,
                       shardings=None) -> Tuple[int, Any]:
    """Returns (step, tree).  With `shardings` (matching pytree of
    NamedSharding) leaves are device_put onto the *current* mesh —
    this is the elastic-restart reshard path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    codec = manifest.get("codec", "zstd")  # pre-codec manifests were zstd
    blobs = {}
    for entry in manifest["leaves"]:
        h = entry["host"]
        if h not in blobs:
            with open(os.path.join(d, f"shard_{h}.bin"), "rb") as f:
                blobs[h] = _decode_shard(codec, f.read())
    items = []
    for e in manifest["leaves"]:
        raw = blobs[e["host"]][e["offset"]: e["offset"] + e["nbytes"]]
        arr = np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(
            e["shape"]).copy()
        items.append((e["path"], arr))
    tree = _unflatten(items)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return manifest["step"], tree


class AsyncCheckpointer:
    """Background-thread writer; `wait()` joins the in-flight save (called
    before the next save and on SIGTERM-triggered final checkpoint)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
