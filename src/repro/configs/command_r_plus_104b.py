"""command-r-plus-104b [dense] — GQA, no biases.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    qkv_bias=False,
    rope_theta=75000000.0,
))
