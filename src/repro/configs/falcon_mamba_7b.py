"""falcon-mamba-7b [ssm] — attention-free Mamba-1 architecture.

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,        # unused (attention-free)
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,           # mamba block subsumes the MLP
    vocab=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
))
