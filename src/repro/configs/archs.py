"""Side-effect import of every architecture config (registry population)."""
from repro.configs import (  # noqa: F401
    recurrentgemma_2b,
    falcon_mamba_7b,
    command_r_plus_104b,
    qwen15_4b,
    qwen2_7b,
    deepseek_67b,
    moonshot_v1_16b_a3b,
    olmoe_1b_7b,
    musicgen_medium,
    internvl2_2b,
    mixtral_8x7b_proxy,
)
