"""moonshot-v1-16b-a3b [moe] — kimi/moonlight family, 64 experts top-6.

48L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]
(Spec gives 64e top-6 only; shared experts not in the assigned spec -> off.)
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    moe_d_ff=1408,
    n_experts=64,
    top_k=6,
    vocab=163840,
))
