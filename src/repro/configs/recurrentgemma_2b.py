"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

26L d_model=2560 10H (GQA kv=1 / MQA) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    local_window=2048,
    layer_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    rope_theta=10000.0,
    # heterogeneous layer stack -> unrolled (26 layers, small model)
    scan_layers=False,
))
