"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]
vocab 92553 is padded to 92672 (x512) for 16-way tensor sharding; padded
logits are masked out of loss/decoding (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    frontend_stub=True,
))
