"""Config system: model architectures, input shapes, runtime knobs.

Every assigned architecture is a `ModelConfig` registered under its public
id (``--arch <id>``).  The four benchmark shapes are `ShapeSpec`s.  A config
is a plain frozen dataclass so it can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # --- attention ---
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    local_window: Optional[int] = None  # sliding-window size (hybrid local attn)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0          # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    shared_expert: bool = False
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0           # 0 -> ceil(d_model/16)
    # --- hybrid (recurrentgemma / griffin) ---
    layer_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn") repeating
    lru_width: int = 0
    # --- numerics / impl ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat_policy: str = "nothing"  # nothing | dots | full(no remat)
    attn_impl: str = "chunked"     # naive | chunked | pallas
    attn_chunk: int = 512
    ssm_chunk: int = 128
    moe_impl: str = "scatter"      # dense | scatter | gmm(pallas)
    vocab_pad_to: int = 512
    # probe mode for dry-run costing: python-unroll every inner scan so
    # HloCostAnalysis (which visits while bodies once) counts all work
    unroll_scans: bool = False
    # --- beyond-paper perf: exact head padding/duplication ---
    # Pads q heads to a multiple of `head_pad_to` (the model-axis size) and
    # duplicates kv heads up to it, so attention shards instead of
    # replicating / involuntarily rematerializing. Mathematically EXACT:
    # padded q-head outputs are killed by a zero mask on wo rows, duplicated
    # kv heads carry identical K/V. See models/transformer.pad_attention_params.
    pad_heads: bool = False
    head_pad_to: int = 16
    # keep the residual-stream gradient psum in the model dtype (see
    # models/layers.rmsnorm_bf16grad) — beyond-paper collective optimization
    norm_bf16_grad: bool = False
    # serving: store the KV cache in a narrower dtype ("" = model dtype).
    # float8_e4m3fn halves the decode memory term — the TPU-idiomatic
    # analogue of the paper's 4-bit serving quantization.
    kv_cache_dtype: str = ""
    # sparse pruned-artifact runtime (repro.sparse): execute-mode override
    # for packed expert-FFN weights.  "" = backend default (Pallas gather
    # kernel on TPU, bit-exact densify elsewhere); "exact" | "gather" |
    # "pallas" | "interpret" force a path (see sparse/execute.py).
    sparse_exec: str = ""

    @property
    def heads_eff(self) -> int:
        if not self.pad_heads:
            return self.n_heads
        p = self.head_pad_to
        k_eff = self.kv_eff
        # q heads padded to a multiple of lcm(p, k_eff) so groups divide
        base = ((self.n_heads + p - 1) // p) * p
        while base % k_eff != 0:
            base += p
        return base

    @property
    def kv_eff(self) -> int:
        if not self.pad_heads:
            return self.n_kv_heads
        p = self.head_pad_to
        K, H = self.n_kv_heads, self.n_heads
        if K == H:                       # MHA: pad together
            return ((H + p - 1) // p) * p
        if K >= p or p % K != 0:
            return K                     # already shardable / not dup-able
        return p                         # duplicate each kv head p//K times

    def head_slot_mask(self):
        """bool [heads_eff]: True = real q head (False rows of wo are
        zero-masked)."""
        import numpy as _np
        H, K = self.n_heads, max(self.n_kv_heads, 1)
        He, Ke = self.heads_eff, max(self.kv_eff, 1)
        mask = _np.zeros(He, bool)
        per_real = H // K                # real q heads per real kv group
        per_eff = He // K                # slots per real kv group
        for g in range(K):
            mask[g * per_eff: g * per_eff + per_real] = True
        return mask
    # --- modality frontend stub (audio/vlm) ---
    frontend_stub: bool = False

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:  # mamba
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_actual(self) -> int:
        return self.dt_rank or max(1, (self.d_model + 15) // 16)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is O(window) or O(1), not O(seq)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.padded_vocab
        hd = self.head_dim
        n_attn = self.n_layers
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        if self.family == "ssm":
            di, N, R = self.d_inner, self.ssm_state, self.dt_rank_actual
            per = (d * 2 * di            # in_proj (x and z)
                   + di * self.ssm_conv  # conv1d
                   + di * (R + 2 * N)    # x_proj -> dt, B, C
                   + R * di + di         # dt_proj
                   + di * N + di         # A_log, D
                   + di * d)             # out_proj
            total += L * (per + d)       # + norm
            return total
        if self.family == "hybrid":
            pat = self.effective_pattern()
            n_rec = sum(1 for p in pat if p == "rec")
            n_attn = sum(1 for p in pat if p == "attn")
            w = self.lru_width or self.d_model
            rec_per = d * 2 * w + w * self.ssm_conv + 2 * w + w * d + 2 * w  # proj,conv,gates(a/x per-chan),out,lru params
            attn_per = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            mlp_per = 3 * d * f
            total += n_rec * (rec_per + mlp_per + 2 * d)
            total += n_attn * (attn_per + mlp_per + 2 * d)
            return total
        # dense / moe / audio / vlm transformer
        attn_per = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.qkv_bias:
            attn_per += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.family == "moe":
            mlp_per = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            if self.shared_expert:
                mlp_per += 3 * d * self.moe_d_ff
        else:
            mlp_per = 3 * d * f
        total += n_attn * (attn_per + mlp_per + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_expert = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        act_expert = self.n_layers * self.top_k * 3 * d * self.moe_d_ff
        return full - all_expert + act_expert

    def effective_pattern(self) -> Tuple[str, ...]:
        if self.family == "hybrid":
            pat = []
            while len(pat) < self.n_layers:
                pat.extend(self.layer_pattern)
            return tuple(pat[: self.n_layers])
        if self.family == "ssm":
            return tuple(["ssm"] * self.n_layers)
        return tuple(["attn"] * self.n_layers)


# ---------------------------------------------------------------------------
# Input shapes (assigned benchmark cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a benchmark cell applies to this architecture."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (O(seq) KV cache)")
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "recurrentgemma-2b",
    "falcon-mamba-7b",
    "command-r-plus-104b",
    "qwen1.5-4b",
    "qwen2-7b",
    "deepseek-67b",
    "moonshot-v1-16b-a3b",
    "olmoe-1b-7b",
    "musicgen-medium",
    "internvl2-2b",
)


def _ensure_loaded():
    # import side-effect registration of all arch modules
    if _REGISTRY:
        return
    from repro.configs import archs  # noqa: F401


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.family != "hybrid" else 3),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        vocab_pad_to=64,
        scan_layers=cfg.scan_layers,
        attn_chunk=32,
        ssm_chunk=16,
    )
    if cfg.family == "moe":
        small.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                     moe_d_ff=32, d_ff=0)
    if cfg.family == "ssm":
        small.update(ssm_state=cfg.ssm_state, d_ff=0, n_heads=1, n_kv_heads=1)
    if cfg.family == "hybrid":
        small.update(lru_width=64, local_window=32, n_kv_heads=1)
    small.update(overrides)
    return replace(cfg, **small)
