"""mixtral-8x7b-proxy [moe] — the paper's own comparison arch (Table 2).

Not in the assigned pool; included so the Lu-et-al. combinatorial baseline
benchmark matches the paper's 8-expert setting. 32L d_model=4096 32H (kv=8)
per-expert d_ff=14336 vocab=32000, 8e top-2. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b-proxy",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    moe_d_ff=14336,
    n_experts=8,
    top_k=2,
    vocab=32000,
))
