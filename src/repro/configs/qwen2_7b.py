"""qwen2-7b [dense] — GQA kv=4, QKV bias.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
[arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
))
