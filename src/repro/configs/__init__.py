from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    get_config,
    list_configs,
    reduced,
    register,
    shape_applicable,
)
