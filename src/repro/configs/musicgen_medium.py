"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]
Backbone only; EnCodec frame embeddings arrive via the frontend stub.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    frontend_stub=True,
))
