"""qwen1.5-4b [dense] — MHA with QKV bias.

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936
[hf:Qwen/Qwen1.5-0.5B family; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
))
