"""Pallas fused Wanda score-and-mask kernel.

Offline pruning hot-spot: for a weight tile W [bk, bn], the Wanda score is
|W| · ||X||₂ (input-feature norms broadcast down columns); weights whose
score falls at or below the per-output threshold are zeroed in place.
Fusing |W|·norm, compare and select into one pass keeps the weight stream
at exactly one HBM read + one write — the op is purely memory-bound, so
this is the roofline-optimal shape for it.

Threshold computation (a per-column k-th order statistic) stays in jnp on
the host path (`ops.wanda_prune`): a quantile over K elements per column is
cheap and awkward on the MXU/VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _wanda_kernel(w_ref, xn_ref, th_ref, o_ref):
    w = w_ref[...]
    score = jnp.abs(w.astype(jnp.float32)) * xn_ref[...].astype(jnp.float32)[:, None]
    keep = score > th_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = jnp.where(keep, w, jnp.zeros_like(w))


@functools.partial(jax.jit, static_argnames=("block_k", "block_n",
                                             "interpret"))
def wanda_mask_apply(w, xnorm, thresh, *, block_k=256, block_n=256,
                     interpret=False):
    """w [K,N], xnorm [K], thresh [N] -> masked w."""
    K, N = w.shape
    block_k = min(block_k, K)
    block_n = min(block_n, N)
    assert K % block_k == 0 and N % block_n == 0
    return pl.pallas_call(
        _wanda_kernel,
        grid=(K // block_k, N // block_n),
        in_specs=[
            pl.BlockSpec((block_k, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_k,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_k, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), w.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(w, xnorm, thresh)
