"""Pallas blocked linear-recurrence scan (RG-LRU / SSM inner loop).

h_t = a_t ⊙ h_{t-1} + b_t over time, per (batch, width-block) tile.  The
whole [S, bw] tile sits in VMEM (S=4096, bw=128, fp32 -> 2 MB/input); the
kernel walks time in *sub-chunks*, running a log-depth Blelloch-style
associative combine inside each sub-chunk on the VPU and carrying the
[1, bw] state across sub-chunks — the TPU-native reshape of the paper-era
CUDA sequential scan (see DESIGN.md §3).

Grid (B, W/bw): embarrassingly parallel over both axes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _scan_kernel(a_ref, b_ref, o_ref, h_scr, *, seq_len, sub):
    @pl.when(pl.program_id(0) >= 0)  # always; keeps structure uniform
    def _run():
        h_scr[...] = jnp.zeros_like(h_scr)

    n_sub = seq_len // sub

    def outer(i, _):
        a = a_ref[0, pl.ds(i * sub, sub)].astype(jnp.float32)  # [sub, bw]
        b = b_ref[0, pl.ds(i * sub, sub)].astype(jnp.float32)

        # log-depth inclusive scan of the affine maps within the sub-chunk
        def combine(c, step):
            ca, cb = c
            sa = jnp.roll(ca, step, axis=0).at[:step].set(1.0)
            sb = jnp.roll(cb, step, axis=0).at[:step].set(0.0)
            return (ca * sa, cb + ca * sb), None

        ca, cb = a, b
        step = 1
        while step < sub:
            (ca, cb), _ = combine((ca, cb), step)
            step *= 2
        # apply incoming carry: h_t = ca_t * h_in + cb_t
        h_in = h_scr[...]
        h_all = ca * h_in + cb
        o_ref[0, pl.ds(i * sub, sub)] = h_all.astype(o_ref.dtype)
        h_scr[...] = h_all[-1:]
        return 0

    jax.lax.fori_loop(0, n_sub, outer, 0)


@functools.partial(jax.jit, static_argnames=("block_w", "sub", "interpret"))
def rglru_scan(a, b, *, block_w=128, sub=64, interpret=False):
    """a, b [B, S, W] -> h [B, S, W] with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    sub = min(sub, S)
    assert W % block_w == 0 and S % sub == 0
    return pl.pallas_call(
        functools.partial(_scan_kernel, seq_len=S, sub=sub),
        grid=(B, W // block_w),
        in_specs=[
            pl.BlockSpec((1, S, block_w), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, block_w), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, S, block_w), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a, b)
