"""Pallas TPU flash attention (blocked online softmax).

Grid (B*H, n_q_blocks, n_kv_blocks); the kv dimension is the minor
(sequential) grid axis so VMEM scratch (m, l, acc) carries state across kv
iterations.  Causal + sliding-window masking via block-level `pl.when`
skips: fully-masked kv blocks are never computed, so causal attention does
~half the FLOPs of the dense product and a window bounds work per q block.

VMEM budget per step (bq=bk=512, hd=128, fp32 scratch):
  q(512·128·4) + k,v(2·512·128·4) + acc(512·128·4) + s(512·512·4) ≈ 2.3 MB
— comfortably under the ~16 MB v5e VMEM, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, block_q, block_k, n_kv, causal, window):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level skip: causal => blocks above the diagonal never computed;
    # window => blocks entirely older than the window never computed.
    needed = jnp.bool_(True)
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 >= q_start - (window - 1))

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=512,
                    block_k=512, interpret=False):
    """q,k,v [B,H,S,hd] (GQA callers broadcast kv). Returns [B,H,S,hd]."""
    B, H, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_kv = S // block_q, S // block_k
    scale = hd ** -0.5

    kernel = functools.partial(_attn_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, n_kv=n_kv, causal=causal,
                               window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q.reshape(B * H, S, hd), k.reshape(B * H, S, hd),
      v.reshape(B * H, S, hd))
    return out.reshape(B, H, S, hd)
