"""Pallas API-drift shims.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``;
this container pins an older jax.  Kernels import the symbol from here so
they read like the current API while running on either version.
"""
from jax.experimental.pallas import tpu as _pltpu

_cp = getattr(_pltpu, "CompilerParams",
              getattr(_pltpu, "TPUCompilerParams", None))

if _cp is None:  # pragma: no cover - depends on installed jax
    def CompilerParams(*args, **kwargs):
        raise ImportError(
            "this jax version exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams; the Pallas kernels need jax>=0.4.30")
else:
    CompilerParams = _cp
