"""Pallas API-drift shims + the version-skew capability registry.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``;
this container pins an older jax.  Kernels import the symbol from here so
they read like the current API while running on either version.

``capabilities()`` is the single memoized probe of the installed jax's
Pallas surface.  Every version-skew workaround is *declared* here — the
``SHIMMED`` registry — so the ``pallas-invariants`` lint checker can
enforce that no kernel reaches for ``pltpu.<shimmed symbol>`` (old or
new spelling) directly: skew handling lives in exactly one place.
"""
from __future__ import annotations

import functools

from jax.experimental.pallas import tpu as _pltpu

# symbols this module shims across jax versions.  The lint checker bans
# direct ``pltpu.<name>`` / ``pltpu.TPU<name>`` references outside this
# file for every name listed here.
SHIMMED = ("CompilerParams",)

_cp = getattr(_pltpu, "CompilerParams",
              getattr(_pltpu, "TPUCompilerParams", None))

if _cp is None:  # pragma: no cover - depends on installed jax
    def CompilerParams(*args, **kwargs):
        raise ImportError(
            "this jax version exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams; the Pallas kernels need jax>=0.4.30")
else:
    CompilerParams = _cp


@functools.lru_cache(maxsize=None)
def capabilities() -> dict:
    """One memoized probe of the installed jax's Pallas capabilities.

    Keys:
      * ``jax_version`` — ``jax.__version__`` string.
      * ``shimmed`` — symbols this module shims (the lint registry).
      * ``compiler_params_source`` — the real ``pltpu`` attribute name
        backing :data:`CompilerParams` (``"CompilerParams"`` on jax>=0.5,
        ``"TPUCompilerParams"`` before, ``None`` if neither exists).
      * ``has_compiler_params`` — whether a usable class was found.
      * ``has_prefetch_scalar_grid_spec`` — ``pltpu.PrefetchScalarGridSpec``
        availability (the scalar-prefetch kernels need it).

    The dict is computed once per process; checkers and kernels consult
    it instead of sprinkling their own ``getattr(pltpu, ...)`` probes.
    """
    import jax

    source = None
    if getattr(_pltpu, "CompilerParams", None) is not None:
        source = "CompilerParams"
    elif getattr(_pltpu, "TPUCompilerParams", None) is not None:
        source = "TPUCompilerParams"
    return {
        "jax_version": jax.__version__,
        "shimmed": SHIMMED,
        "compiler_params_source": source,
        "has_compiler_params": _cp is not None,
        "has_prefetch_scalar_grid_spec": hasattr(_pltpu,
                                                 "PrefetchScalarGridSpec"),
    }
