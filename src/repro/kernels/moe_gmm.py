"""Pallas grouped expert matmul (MoE fast path).

Computes out[e] = buf[e] @ w[e] for every expert e: buf [E, C, D] is the
capacity-bounded dispatch buffer, w [E, D, F] the per-expert weights.
Grid (E, C/bc, F/bf, D/bd) — contraction (D) is the minor sequential axis,
accumulated into fp32 VMEM scratch and flushed once per (e, c, f) tile.
Tiles are MXU-aligned (128 multiples) in production; tests sweep smaller
shapes in interpret mode.

After STUN expert pruning the E axis physically shrinks (64 -> 48 @ 25%),
which reduces both the gmm grid and the EP all-to-all payload — this kernel
is where stage-1 pruning's serving win lands on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gmm_kernel(buf_ref, w_ref, o_ref, acc_scr, *, n_d):
    i_d = pl.program_id(3)

    @pl.when(i_d == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        buf_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i_d == n_d - 1)
    def _flush():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gmm(buf, w, *, block_c=128, block_f=128, block_d=128,
            interpret=False):
    """buf [E,C,D] @ w [E,D,F] -> [E,C,F]."""
    E, C, D = buf.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    n_d = D // block_d

    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_d=n_d),
        grid=(E, C // block_c, F // block_f, n_d),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(buf, w)
