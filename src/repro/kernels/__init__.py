"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel module contains the `pl.pallas_call` + BlockSpec implementation;
`ops.py` holds the jit'd public wrappers (TPU kernel / jnp fallback) and
`ref.py` the pure-jnp oracles used by the interpret-mode allclose tests.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.block_sparse_matmul import (  # noqa: F401
    block_sparse_matmul,
    build_block_mask,
)
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.moe_gmm import moe_gmm  # noqa: F401
from repro.kernels.paged_decode_attention import paged_decode_attention  # noqa: F401
from repro.kernels.rglru_scan import rglru_scan  # noqa: F401
from repro.kernels.wanda_score import wanda_mask_apply  # noqa: F401
