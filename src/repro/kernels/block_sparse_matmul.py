"""Pallas block-sparse matmul — the TPU adaptation of unstructured pruning.

The paper's stage-2 masks are element-unstructured, which no TPU primitive
accelerates (the paper's own Limitation §).  On TPU the exploitable
structure is *block* sparsity aligned to MXU tiles: a [K/bk, N/bn] bitmap
marks weight blocks that are entirely zero under the Wanda/OWL mask
(common under OWL's non-uniform high layer ratios and after N:M
re-rounding + column permutation).  The bitmap rides in scalar-prefetch
SMEM; `pl.when` skips the dot entirely for dead blocks, saving both MXU
time and the HBM->VMEM weight stream for those tiles.

out [M,N] = x [M,K] @ w [K,N], grid (M/bm, N/bn, K/bk), fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _bsmm_kernel(mask_ref, x_ref, w_ref, o_ref, acc_scr, *, n_k, n_n):
    j_n = pl.program_id(1)
    k_k = pl.program_id(2)

    @pl.when(k_k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(mask_ref[k_k * n_n + j_n] != 0)
    def _compute():
        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_k == n_k - 1)
    def _flush():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def block_sparse_matmul(x, w, block_mask, *, block_m=128, block_n=128,
                        block_k=128, interpret=False):
    """x [M,K] @ w [K,N] skipping blocks where block_mask[K/bk, N/bn]==0."""
    M, K = x.shape
    _, N = w.shape
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    n_k, n_n = K // block_k, N // block_n
    assert block_mask.shape == (n_k, n_n), (block_mask.shape, (n_k, n_n))
    mask_flat = block_mask.astype(jnp.int32).reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // block_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k, mask: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k, mask: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, k, mask: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bsmm_kernel, n_k=n_k, n_n=n_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(mask_flat, x, w)


def build_block_mask(mask: np.ndarray, block_k: int, block_n: int
                     ) -> np.ndarray:
    """Element mask [K,N] -> block bitmap [K/bk, N/bn] (1 = any nonzero)."""
    K, N = mask.shape
    assert K % block_k == 0 and N % block_n == 0
    m = mask.reshape(K // block_k, block_k, N // block_n, block_n)
    return m.any(axis=(1, 3))


# ---------------------------------------------------------------------------
# Gather variant: the weight never exists densely — live blocks sit in a
# [n_slots, bk, bn] pool (slot 0 is an all-zero sentinel) and a
# [K/bk, N/bn] int32 index maps each logical block to its pool slot
# (paged-KV-for-weights).  The index rides in scalar-prefetch SMEM and
# drives the pool BlockSpec index map, so a dead block neither streams
# bytes from its own storage (there is none) nor issues an MXU dot.
# ---------------------------------------------------------------------------


def _bsgmm_kernel(idx_ref, x_ref, pool_ref, o_ref, acc_scr, *, n_k, n_n):
    j_n = pl.program_id(1)
    k_k = pl.program_id(2)

    @pl.when(k_k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(idx_ref[k_k * n_n + j_n] != 0)
    def _compute():
        acc_scr[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), pool_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_k == n_k - 1)
    def _flush():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def block_sparse_gather_matmul(x, pool, block_index, *, block_m=128,
                               interpret=False):
    """x [M,K] @ block-compressed w -> [M,N].

    ``pool`` [n_slots, bk, bn] holds the live weight blocks (slot 0 MUST
    be all zeros — the dead-block sentinel); ``block_index`` [K/bk, N/bn]
    int32 maps logical block (k, j) to its pool slot, 0 where dead.  The
    index is scalar-prefetched and both selects the pool block to DMA and
    gates the dot with ``pl.when``, so dead blocks cost neither bandwidth
    nor MXU time (the sentinel block's DMA is shared and cache-resident).
    """
    M, K = x.shape
    _, bk, bn = pool.shape
    n_k, n_n = block_index.shape
    assert K == n_k * bk, (K, n_k, bk)
    N = n_n * bn
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    idx_flat = block_index.astype(jnp.int32).reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M // block_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, bk), lambda i, j, k, idx: (i, k)),
            pl.BlockSpec((1, bk, bn),
                         lambda i, j, k, idx: (idx[k * n_n + j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, bn),
                               lambda i, j, k, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bsgmm_kernel, n_k=n_k, n_n=n_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(idx_flat, x, pool)
