"""Pallas TPU paged ragged-decode attention (one query token per lane).

The serving engine stores K/V in fixed-size pages ([n_pages, page_size,
K, hd] pools) and hands each batch lane a page-table row of physical page
ids.  This kernel fuses the page-table gather into the attention loop:
grid (B, kv_heads, pages_per_lane) with the page axis minor (sequential),
scalar-prefetched ``page_tables``/``lengths`` drive the BlockSpec index
maps, so page ``i`` of lane ``b`` is DMA'd straight from its physical
location — no [B, T, K, hd] gather is ever materialized in HBM (the jnp
path's bandwidth bottleneck at high concurrency).

Raggedness is per-row: ``lengths[b]`` masks both whole pages (``pl.when``
skip, so a short request costs only its own pages' FLOPs) and rows inside
the final partial page (iota mask).  Online softmax (m, l, acc in VMEM
scratch) carries state across page iterations exactly as
``flash_attention.py`` does across kv blocks for prefill.

VMEM per step (G=8 q heads/group, ps=64, hd=128, fp32): q+k+v+acc
≈ 4·64·128·4 ≈ 130 KB — far under the ~16 MB v5e budget, so pages
double-buffer freely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, page_size, n_pg, window,
            softcap):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    # page-level skip: pages entirely past the valid rows (or entirely
    # older than the sliding window) are never computed
    needed = i * page_size < length
    if window is not None:
        needed = jnp.logical_and(
            needed, (i + 1) * page_size > length - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [ps, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        G = s.shape[0]
        tpos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (G, page_size), 1)
        valid = tpos < length
        if window is not None:
            valid = jnp.logical_and(valid, tpos >= length - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]                            # [G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == n_pg - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_tables, lengths, *,
                           window=None, softcap=None, interpret=False):
    """q [B, 1, H, hd]; k/v_pages [n_pages, ps, K, hd]; page_tables
    [B, max_pages] int32; lengths [B] int32 (valid rows per lane, current
    token's K/V already written).  Returns [B, 1, H, hd].

    Lane ``b``'s logical rows [i*ps, (i+1)*ps) live in physical page
    ``page_tables[b, i]``; entries past ``ceil(lengths[b]/ps)`` may point
    anywhere (the engine's sentinel page) — they are skipped/masked.
    Rows with ``lengths[b] == 0`` produce zeros (nothing to attend).
    """
    B, _, H, hd = q.shape
    n_pages, ps, K, _ = k_pages.shape
    G = H // K
    P = page_tables.shape[1]
    scale = hd ** -0.5
    qg = q.reshape(B, K, G, hd)

    kernel = functools.partial(_kernel, scale=scale, page_size=ps, n_pg=P,
                               window=window, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, k, i, tbl, lens: (b, k, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, k, i, tbl, lens: (tbl[b, i], 0, k, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, k, i, tbl, lens: (tbl[b, i], 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, i, tbl, lens: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_tables, lengths, qg, k_pages, v_pages)
    return out.reshape(B, 1, H, hd)
