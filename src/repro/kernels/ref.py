"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q,k,v [B,H,S,hd] (kv pre-broadcast to H). fp32 reference."""
    B, H, S, hd = q.shape
    scale = scale or hd ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, page_tables, lengths, *,
                               window=None, softcap=None):
    """q [B,1,H,hd]; k/v_pages [n_pages,ps,K,hd]; page_tables [B,max_pages];
    lengths [B] (valid rows per lane, current token already written).

    Gathers each lane's pages into logical order and applies exactly the
    math of ``models.layers.attention_decode`` — the serving engine's CPU
    path, so paged and slot engines are token-identical there.  One edge
    differs from the kernel: a lane with ``lengths[b] == 0`` (nothing
    valid) yields a softmax over all-masked rows here vs. zeros in the
    kernel; callers never attend such lanes.
    """
    B, _, H, hd = q.shape
    ps, K = k_pages.shape[1], k_pages.shape[2]
    G = H // K
    scale = hd ** -0.5
    # [B, max_pages, ps, K, hd] -> logical [B, T, K, hd]
    k_cache = k_pages[page_tables].reshape(B, -1, K, hd)
    v_cache = v_pages[page_tables].reshape(B, -1, K, hd)
    T = k_cache.shape[1]
    qh = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qh,
                   k_cache.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t_idx = jnp.arange(T)[None]
    valid = t_idx < lengths[:, None]
    if window is not None:
        valid &= t_idx >= (lengths[:, None] - window)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def moe_gmm_ref(buf, w):
    """buf [E,C,D] @ w [E,D,F] -> [E,C,F] (per-expert matmul)."""
    return jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(buf.dtype)


def block_sparse_matmul_ref(x, w, block_mask, bk, bn):
    """x [M,K] @ (w [K,N] with [K/bk, N/bn] block mask) -> [M,N]."""
    K, N = w.shape
    mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=0), bn, axis=1)
    wm = w * mask[:K, :N].astype(w.dtype)
    return (x.astype(jnp.float32) @ wm.astype(jnp.float32)).astype(x.dtype)


def unpack_blocks_ref(pool, block_index):
    """Block pool [n_slots, bk, bn] + index [Kb, Nb] -> dense [Kb*bk, Nb*bn].

    Slot 0 is the all-zero dead-block sentinel, so ``pool[block_index]``
    reconstructs exactly the masked dense matrix the pack stage consumed
    (same float values — no arithmetic happens, only gather/transpose).
    """
    Kb, Nb = block_index.shape
    _, bk, bn = pool.shape
    blocks = pool[block_index]                        # [Kb, Nb, bk, bn]
    return blocks.transpose(0, 2, 1, 3).reshape(Kb * bk, Nb * bn)


def block_sparse_gather_matmul_ref(x, pool, block_index):
    """x [M,K] @ unpacked(pool, index) -> [M,N]; fp32 accumulation."""
    w = unpack_blocks_ref(pool, block_index)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def wanda_mask_apply_ref(w, xnorm, thresh):
    """w [K,N], xnorm [K], thresh [N] -> w masked where |w|·xnorm <= thresh."""
    score = jnp.abs(w.astype(jnp.float32)) * xnorm.astype(jnp.float32)[:, None]
    return jnp.where(score > thresh.astype(jnp.float32)[None, :], w,
                     jnp.zeros_like(w))


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t, h_0 = 0. a,b [B,S,W] fp32."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, jnp.zeros(a[:, 0].shape, jnp.float32),
                         (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
