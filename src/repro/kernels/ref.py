"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q,k,v [B,H,S,hd] (kv pre-broadcast to H). fp32 reference."""
    B, H, S, hd = q.shape
    scale = scale or hd ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def moe_gmm_ref(buf, w):
    """buf [E,C,D] @ w [E,D,F] -> [E,C,F] (per-expert matmul)."""
    return jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(buf.dtype)


def block_sparse_matmul_ref(x, w, block_mask, bk, bn):
    """x [M,K] @ (w [K,N] with [K/bk, N/bn] block mask) -> [M,N]."""
    K, N = w.shape
    mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=0), bn, axis=1)
    wm = w * mask[:K, :N].astype(w.dtype)
    return (x.astype(jnp.float32) @ wm.astype(jnp.float32)).astype(x.dtype)


def wanda_mask_apply_ref(w, xnorm, thresh):
    """w [K,N], xnorm [K], thresh [N] -> w masked where |w|·xnorm <= thresh."""
    score = jnp.abs(w.astype(jnp.float32)) * xnorm.astype(jnp.float32)[:, None]
    return jnp.where(score > thresh.astype(jnp.float32)[None, :], w,
                     jnp.zeros_like(w))


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t, h_0 = 0. a,b [B,S,W] fp32."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, jnp.zeros(a[:, 0].shape, jnp.float32),
                         (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
