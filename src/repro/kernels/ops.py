"""Public jit'd wrappers: kernel fast path on TPU, jnp oracle elsewhere.

``use_pallas()`` decides per-call: real TPU backend -> compiled kernel;
CPU/dry-run -> the pure-jnp reference (identical numerics to the oracle,
bounded memory).  `force` overrides for interpret-mode validation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.block_sparse_matmul import (block_sparse_gather_matmul,
                                               block_sparse_matmul)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.wanda_score import wanda_mask_apply


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention_op(q, k, v, *, causal=True, window=None, force=None):
    """q,k,v [B,H,S,hd]; GQA callers broadcast kv heads first."""
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window)
    if mode == "interpret":
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=True, block_q=64, block_k=64)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def paged_attention_op(q, k_pages, v_pages, page_tables, lengths, *,
                       window=None, softcap=None, force=None):
    """Paged ragged-decode attention: one query token per lane against
    that lane's paged KV history.

    Shapes/dtypes: ``q`` [B, 1, H, hd] (model dtype); ``k_pages`` /
    ``v_pages`` [n_pages, page_size, K, hd] with H divisible by K (GQA);
    ``page_tables`` [B, max_pages] int32 physical-page ids (sentinel page
    0 where unassigned); ``lengths`` [B] int32 valid rows per lane — the
    current token's K/V must already be written, so an active lane has
    ``lengths[b] >= 1``.  Returns [B, 1, H, hd] in ``q.dtype``; softmax
    runs in fp32 with optional sliding ``window`` and logit ``softcap``.

    Failure modes: out-of-range page ids are clamped by XLA's gather (no
    error — keep tables well-formed, see PagedKVCache invariants), and a
    lane with ``lengths[b] == 0`` is garbage (all rows masked): callers
    must discard idle lanes' output.  ``force="interpret"`` validates the
    Pallas kernel off-TPU; the jnp reference runs on CPU by default.
    """
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode == "pallas":
        return paged_decode_attention(q, k_pages, v_pages, page_tables,
                                      lengths, window=window,
                                      softcap=softcap)
    if mode == "interpret":
        return paged_decode_attention(q, k_pages, v_pages, page_tables,
                                      lengths, window=window,
                                      softcap=softcap, interpret=True)
    return ref.paged_decode_attention_ref(q, k_pages, v_pages, page_tables,
                                          lengths, window=window,
                                          softcap=softcap)


def gmm_op(buf, w, *, force=None):
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode == "pallas":
        return moe_gmm(buf, w)
    if mode == "interpret":
        return moe_gmm(buf, w, block_c=32, block_f=32, block_d=32,
                       interpret=True)
    return ref.moe_gmm_ref(buf, w)


def choose_block_m(M: int, cap: int = 128) -> int:
    """Largest divisor of M that is <= cap — the one shape-driven tile
    chooser for every block-sparse dispatch (kernel asserts M % bm == 0,
    so a non-divisor tile is a shape error, not a slow path).  A ragged M
    (e.g. prime) degrades gracefully toward smaller tiles instead of
    failing; M itself is always a valid fallback when M <= cap."""
    for bm in range(min(M, cap), 0, -1):
        if M % bm == 0:
            return bm
    return 1  # pragma: no cover — range above always hits a divisor


def sparse_matmul_op(x, w, block_mask, *, block_k=128, block_n=128,
                     force=None):
    """x [M,K] @ w [K,N] skipping dead blocks of ``block_mask``
    [K/block_k, N/block_n].  block_k/block_n are fixed by the caller's
    bitmap; the M tile is chosen from the shape by ``choose_block_m`` on
    both kernel paths (previously the interpret branch hardcoded
    block_m=32, which broke for M not divisible by 32)."""
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode in ("pallas", "interpret"):
        return block_sparse_matmul(x, w, block_mask,
                                   block_m=choose_block_m(x.shape[0]),
                                   block_n=block_n, block_k=block_k,
                                   interpret=mode == "interpret")
    return ref.block_sparse_matmul_ref(x, w, block_mask, block_k, block_n)


def sparse_gather_matmul_op(x, pool, block_index, *, force=None):
    """x [M,K] @ block-compressed weight -> [M,N] (see
    ``block_sparse_gather_matmul``): ``pool`` [n_slots, bk, bn] with slot
    0 the all-zero sentinel, ``block_index`` [K/bk, N/bn] int32 (0 =
    dead).  The sparse runtime's expert-FFN execute path dispatches here;
    the jnp reference unpacks the pool and runs one dense matmul, so the
    CPU path is bit-identical to serving the mask-multiplied weight."""
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode in ("pallas", "interpret"):
        return block_sparse_gather_matmul(
            x, pool, block_index, block_m=choose_block_m(x.shape[0]),
            interpret=mode == "interpret")
    return ref.block_sparse_gather_matmul_ref(x, pool, block_index)


def wanda_prune_op(w, xnorm, sparsity: float, *, force=None):
    """Fused Wanda prune of one weight matrix: threshold in jnp, mask apply
    in the kernel."""
    K, N = w.shape
    score = jnp.abs(w.astype(jnp.float32)) * xnorm.astype(jnp.float32)[:, None]
    k_prune = int(sparsity * K)
    if k_prune == 0:
        return w
    thresh = jnp.sort(score, axis=0)[k_prune - 1, :]     # per output column
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode == "pallas":
        return wanda_mask_apply(w, xnorm, thresh)
    if mode == "interpret":
        return wanda_mask_apply(w, xnorm, thresh, block_k=64, block_n=64,
                                interpret=True)
    return ref.wanda_mask_apply_ref(w, xnorm, thresh)


def lru_scan_op(a, b, *, force=None):
    mode = force or ("pallas" if on_tpu() else "ref")
    if mode == "pallas":
        return rglru_scan(a, b)
    if mode == "interpret":
        return rglru_scan(a, b, block_w=32, sub=32, interpret=True)
    return ref.rglru_scan_ref(a, b)
