"""Core dense layers: RMSNorm, RoPE, GQA attention (naive / chunked-flash /
decode), SwiGLU MLP.  Pure functions over param dicts.

Attention has two portable implementations:
  * ``naive``   — materializes [.., S, T] scores; smoke tests / tiny shapes.
  * ``chunked`` — flash-style online softmax over KV chunks via ``lax.scan``;
                  bounded memory, used by the dry-run for 4k/32k sequences.
The Pallas TPU kernel (repro/kernels/flash_attention.py) is selected by
``attn_impl="pallas"`` and validated against these in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm_bf16grad(x, scale, eps: float = 1e-6):
    """rmsnorm whose backward keeps the residual-stream cotangent in the
    model dtype.

    Without this, XLA's excess-precision pass hoists the bwd's
    bf16->fp32 convert across the tensor-parallel psum, doubling the
    dominant activation-gradient all-reduce payload (measured on
    deepseek-67b train_4k — see EXPERIMENTS.md §Perf).  An
    optimization_barrier pins the convert after the collective.
    """
    return rmsnorm(x, scale, eps)


def _rms_fwd(x, scale, eps):
    # barrier BEFORE the upcast: stops XLA hoisting the bf16->f32 convert
    # across the TP psum that produced x (which would make the forward
    # all-reduce fp32)
    x = lax.optimization_barrier(x)
    return rmsnorm(x, scale, eps), (x, scale)


def _rms_bwd(eps, res, g):
    x, scale = res
    g = lax.optimization_barrier(g)          # keep psum in model dtype
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = lax.rsqrt(var + eps)
    xhat = x32 * inv
    s32 = 1.0 + scale.astype(jnp.float32)
    dscale = jnp.sum(g32 * xhat,
                     axis=tuple(range(g.ndim - 1))).astype(scale.dtype)
    gy = g32 * s32
    dx = inv * (gy - xhat * jnp.mean(gy * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dscale


rmsnorm_bf16grad.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions [..., S] -> (sin, cos) each [..., S, head_dim//2] fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [B,S,H,hd]; sin/cos [B,S,half] or [S,half]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # [S, half] -> broadcast batch
        sin = sin[None]
        cos = cos[None]
    sin = sin[..., None, :]  # head axis
    cos = cos[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, window: Optional[int], kv_len=None):
    """[..., S, T] boolean mask: True = attend.

    ``kv_len`` (scalar or [B]) additionally masks kv positions past the
    number of *valid* entries — prefill-with-cache uses it so queries never
    attend to unwritten / padded cache rows.
    """
    m = q_pos[..., :, None] >= kv_pos[..., None, :]
    if window is not None:
        m &= (q_pos[..., :, None] - kv_pos[..., None, :]) < window
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        if kl.ndim == 0:
            m = m & (kv_pos[..., None, :] < kl)
        else:  # [B] — broadcast a batch axis onto the mask
            m = m & (kv_pos[..., None, :] < kl[:, None, None])
    return m


def attention_naive(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
                    kv_len=None, allow=None):
    """q [B,S,H,hd], k/v [B,T,K,hd], q_pos [S] or [B,S], kv_pos [T] or [B,T].

    ``allow`` ([S,T] or [B,S,T] bool, optional) is ANDed into the
    positional mask — tree-draft verification needs it because sibling
    draft branches share absolute positions, so causality alone cannot
    keep a branch from attending another branch's rows.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qh = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qh.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    m = _mask(q_pos, kv_pos, window, kv_len)  # [S,T] or [B,S,T]
    if allow is not None:
        m = m & allow
    if m.ndim == 3:
        m = m[:, None, None]
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def attention_chunked(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
                      chunk: int = 512, unroll: bool = False, kv_len=None,
                      allow=None):
    """Flash-style online-softmax attention, scanning KV in chunks.

    ``unroll`` replaces the lax.scan with a python loop (identical math) so
    dry-run cost probes see every chunk in the HLO (see dryrun.py).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    if T % chunk != 0:
        chunk = T  # degenerate fallback for tiny shapes
    n_chunks = T // chunk
    qh = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    if kv_pos.ndim == 1:
        pc = kv_pos.reshape(n_chunks, chunk)
    else:
        pc = kv_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    if allow is not None:
        if allow.ndim == 2:
            allow = jnp.broadcast_to(allow[None], (B, S, T))
        ac = allow.reshape(B, S, n_chunks, chunk).transpose(2, 0, 1, 3)
    else:
        ac = None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        if ac is None:
            kch, vch, pch = inp
            ach = None
        else:
            kch, vch, pch, ach = inp
        s = jnp.einsum("bskgh,bckh->bkgsc", qh, kch.astype(jnp.float32)) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        msk = _mask(q_pos, pch, window, kv_len)  # [S,c] or [B,S,c]
        if ach is not None:
            msk = msk & ach
        if msk.ndim == 3:
            msk = msk[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgsc,bckh->bskgh", p, vch.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    xs = (kc, vc, pc) if ac is None else (kc, vc, pc, ac)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, tuple(x[i] for x in xs))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), xs)
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention_decode(q, k_cache, v_cache, cache_len, *, window=None,
                     softcap=None):
    """Single-token decode: q [B,1,H,hd] vs cache [B,T,K,hd].

    ``cache_len`` [B] — number of valid cache entries per row (the new
    token's K/V must already be written into the cache).
    """
    B, _, H, hd = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = hd ** -0.5
    qh = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qh, k_cache.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    t_idx = jnp.arange(T)[None]          # [1,T]
    valid = t_idx < cache_len[:, None]
    if window is not None:
        valid &= t_idx >= (cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def attention(q, k, v, q_pos, kv_pos, *, impl="chunked", window=None,
              softcap=None, chunk=512, unroll=False, kv_len=None, allow=None):
    if impl == "naive" or q.shape[1] <= chunk:
        return attention_naive(q, k, v, q_pos, kv_pos, window=window,
                               softcap=softcap, kv_len=kv_len, allow=allow)
    if impl in ("chunked", "pallas"):
        # pallas fast path is swapped in by kernels/ops.py when enabled;
        # portable lowering uses the chunked scan.
        return attention_chunked(q, k, v, q_pos, kv_pos, window=window,
                                 softcap=softcap, chunk=chunk, unroll=unroll,
                                 kv_len=kv_len, allow=allow)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu(x, w_gate, w_up, w_down, b_gate=None, b_up=None, b_down=None):
    g = x @ w_gate
    u = x @ w_up
    if b_gate is not None:
        g = g + b_gate
    if b_up is not None:
        u = u + b_up
    y = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = y @ w_down
    if b_down is not None:
        y = y + b_down
    return y
