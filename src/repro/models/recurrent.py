"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block: x -> [linear->GeLU] ⊙ [linear->conv1d->RG-LRU] -> out-proj.
RG-LRU (arXiv:2402.19427):
    r_t = σ(W_a x_t + b_a)              recurrence gate
    i_t = σ(W_x x_t + b_x)              input gate
    log a_t = -c · softplus(Λ) · r_t    (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
Driven by the same chunked linear-recurrence engine as the SSM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import causal_conv1d, linear_recurrence_chunked

RG_LRU_C = 8.0


def rg_lru(x, p, cfg, *, state=None):
    """x [B,S,W] -> (h [B,S,W], h_last [B,W])."""
    B, S, W = x.shape
    r = jax.nn.sigmoid((x @ p["w_a"] + p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"] + p["b_i"]).astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                   # [B,S,W]
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
    h0 = jnp.zeros((B, W), jnp.float32) if state is None else state
    h, h_last = linear_recurrence_chunked(
        a, gated, h0, min(cfg.ssm_chunk, S),
        unroll=getattr(cfg, "unroll_scans", False))
    return h.astype(x.dtype), h_last


def recurrent_block(x, p, cfg, *, conv_state=None, lru_state=None):
    """Griffin recurrent mixer. x [B,S,D] -> (y [B,S,D], new states)."""
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    u = x @ p["w_in"]                                    # [B,S,W]
    u, new_conv = causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)
    h, new_lru = rg_lru(u, p, cfg, state=lru_state)
    y = (h * gate) @ p["w_out"]
    return y, (new_conv, new_lru)
