"""Mixture-of-Experts block: top-k router + grouped sort/scatter dispatch.

Dispatch strategy (see DESIGN.md §5): tokens are processed in *groups* (one
group per sequence) so the per-group argsort stays local to its data shard —
no global sort collectives.  Each (token, choice) pair is scattered into a
capacity-bounded per-group expert buffer ``[G, E, C, D]``; a sharding
constraint moves the buffer onto the expert-parallel axis before the batched
expert matmul, which XLA lowers to an all-to-all-class collective.  Compiled
FLOPs stay ≈ active-FLOPs × capacity_factor (GShard one-hot dispatch einsums
would inflate dispatch FLOPs ~quadratically in group size).

The router weight is stored as ``[E, D]`` — rows are exactly the W_i vectors
STUN's behavioral similarity (Eq. 8) clusters on.

Expert FFN weights may be *packed* sparse entries instead of dense
arrays (``repro.sparse``): every expert matmul goes through
``sparse.maybe_expert_einsum``, which runs the identical einsum for dense
weights and dispatches packed ones through the block-sparse execute path
(Pallas gather kernel on TPU, bit-exact densify elsewhere;
``cfg.sparse_exec`` overrides).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import swiglu
from repro.sparse.execute import maybe_expert_einsum, sparse_exec_force


def router_probs(x_flat, router_w):
    """x [T, D], router_w [E, D] -> probs [T, E] fp32 (Eq. 1)."""
    logits = jnp.einsum("td,ed->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def moe_block(x, params, cfg, *, mesh=None, capacity_factor=None,
              expert_mask=None):
    """x [B, S, D] -> [B, S, D].

    ``expert_mask`` [E] float (1=alive, 0=pruned) implements *runtime* expert
    pruning (router logits of pruned experts forced to -inf) — used to
    evaluate pruning decisions without re-materializing a smaller checkpoint.
    """
    B, S, D = x.shape
    E = cfg.n_experts
    k = cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    G = B                      # one dispatch group per sequence
    Tg = S                     # tokens per group
    C = max(k, int(math.ceil(Tg * k / E * cf)))

    router_w = params["router"]
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :] > 0, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)          # [B,S,E] fp32
    top_p, top_i = lax.top_k(probs, k)               # [B,S,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # --- dispatch: per-group stable sort by expert id ---
    flat_e = top_i.reshape(G, Tg * k)                         # [G, T*k]
    order = jnp.argsort(flat_e, axis=-1, stable=True)         # local sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # rank within expert = position - start offset of that expert
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(flat_e)  # [G,E]
    starts = jnp.cumsum(counts, axis=-1) - counts                   # excl.
    rank = jnp.arange(Tg * k)[None] - jnp.take_along_axis(starts, sorted_e,
                                                          axis=-1)
    slot = sorted_e * C + rank                                      # [G,T*k]
    overflow = rank >= C
    slot = jnp.where(overflow, E * C, slot)  # drop -> scratch row

    token_of = order // k                                           # [G,T*k]
    x_g = x.reshape(G, Tg, D)
    gathered = jnp.take_along_axis(x_g, token_of[..., None], axis=1)
    buf = jnp.zeros((G, E * C + 1, D), x.dtype)
    buf = jax.vmap(lambda b, s, g: b.at[s].set(g))(buf, slot, gathered)
    buf = buf[:, : E * C].reshape(G, E, C, D)
    if mesh is not None and "model" in mesh.axis_names and E % mesh.shape["model"] == 0:
        batch_ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        batch_ax = tuple(a for a in batch_ax if a in mesh.axis_names)
        buf = lax.with_sharding_constraint(
            buf, jax.NamedSharding(mesh, P(batch_ax if len(batch_ax) > 1 else batch_ax[0],
                                           "model", None, None)))

    # --- expert computation (batched over E; TPU fast path = moe_gmm) ---
    sf = sparse_exec_force(cfg)
    g = maybe_expert_einsum("gecd,edf->gecf", buf, params["we_gate"],
                            n_experts=E, force=sf)
    u = maybe_expert_einsum("gecd,edf->gecf", buf, params["we_up"],
                            n_experts=E, force=sf)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = maybe_expert_einsum("gecf,efd->gecd", h, params["we_down"],
                            n_experts=E, force=sf)                  # [G,E,C,D]

    # --- combine: scatter-add back to tokens with router weights ---
    y_flat = y.reshape(G, E * C, D)
    y_sorted = jnp.take_along_axis(
        y_flat, jnp.minimum(slot, E * C - 1)[..., None], axis=1)
    w_sorted = jnp.take_along_axis(top_p.reshape(G, Tg * k), order, axis=-1)
    w_sorted = jnp.where(overflow, 0.0, w_sorted)
    contrib = y_sorted.astype(jnp.float32) * w_sorted[..., None]
    out = jnp.zeros((G, Tg, D), jnp.float32)
    out = jax.vmap(lambda o, t, c: o.at[t].add(c))(out, token_of, contrib)
    out = out.astype(x.dtype).reshape(B, S, D)

    if cfg.shared_expert:
        out = out + swiglu(x, params["shared_gate"], params["shared_up"],
                           params["shared_down"])
    return out


def moe_block_dense(x, params, cfg, expert_mask=None):
    """Reference dense MoE: every expert on every token (tiny shapes only)."""
    B, S, D = x.shape
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :] > 0, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    gate = jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts, dtype=jnp.float32)
                   * top_p[..., None], axis=-2)                   # [B,S,E]
    sf = sparse_exec_force(cfg)
    E = cfg.n_experts
    g = maybe_expert_einsum("bsd,edf->bsef", x, params["we_gate"],
                            n_experts=E, force=sf)
    u = maybe_expert_einsum("bsd,edf->bsef", x, params["we_up"],
                            n_experts=E, force=sf)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = maybe_expert_einsum("bsef,efd->bsed", h, params["we_down"],
                            n_experts=E, force=sf)
    out = jnp.einsum("bsed,bse->bsd", y.astype(jnp.float32), gate)
    out = out.astype(x.dtype)
    if cfg.shared_expert:
        out = out + swiglu(x, params["shared_gate"], params["shared_up"],
                           params["shared_down"])
    return out


def moe_apply(x, params, cfg, *, mesh=None, expert_mask=None):
    if cfg.moe_impl == "dense":
        return moe_block_dense(x, params, cfg, expert_mask=expert_mask)
    return moe_block(x, params, cfg, mesh=mesh, expert_mask=expert_mask)
