from repro.models.transformer import (  # noqa: F401
    abstract_params,
    cache_specs,
    decode_step,
    decode_step_ragged,
    forward,
    init_cache,
    loss_fn,
    prefill_step,
)
from repro.models import param  # noqa: F401
