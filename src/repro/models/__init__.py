from repro.models.transformer import (  # noqa: F401
    abstract_params,
    cache_specs,
    decode_step,
    forward,
    init_cache,
    loss_fn,
)
from repro.models import param  # noqa: F401
