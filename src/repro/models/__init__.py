from repro.models.transformer import (  # noqa: F401
    abstract_params,
    cache_specs,
    decode_step,
    decode_step_paged,
    decode_step_ragged,
    forward,
    init_cache,
    init_paged_cache,
    loss_fn,
    paged_cache_specs,
    prefill_step,
    prefill_step_paged,
    verify_step_paged,
)
from repro.models import param  # noqa: F401
