"""Parameter specs: shapes + logical sharding axes + initializers.

Models declare an *abstract* parameter tree of `ParamSpec`s.  From it we
derive (a) materialized params for real runs, (b) ShapeDtypeStructs with
NamedShardings for the compile-only dry-run, (c) in_shardings for pjit.
No flax — params are plain nested dicts of arrays.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import named_sharding


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | constant
    scale: float = 1.0        # stddev multiplier (normal) or constant value
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="normal", scale=1.0, dtype="bfloat16") -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(s: ParamSpec, key) -> jax.Array:
    dt = jnp.dtype(s.dtype)
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "constant":
        return jnp.full(s.shape, s.scale, dt)
    if s.init == "normal":
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = s.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(dt)
    raise ValueError(s.init)


def tree_paths(tree, prefix=()):
    if is_spec(tree):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from tree_paths(tree[k], prefix + (k,))


def init_params(abstract, rng):
    """Materialize a ParamSpec tree into arrays (deterministic per path)."""
    leaves = list(tree_paths(abstract))
    keys = jax.random.split(rng, len(leaves))

    def build(tree, prefix=()):
        if is_spec(tree):
            idx = paths.index(prefix)
            return _init_one(tree, keys[idx])
        return {k: build(v, prefix + (k,)) for k, v in tree.items()}

    paths = [p for p, _ in leaves]
    return build(abstract)


def abstract_arrays(abstract, mesh=None, rules=None):
    """ShapeDtypeStructs (with shardings if mesh given) for .lower()."""
    def conv(tree):
        if is_spec(tree):
            sharding = None
            if mesh is not None:
                sharding = named_sharding(tree.axes, tree.shape, mesh, rules)
            return jax.ShapeDtypeStruct(tree.shape, jnp.dtype(tree.dtype),
                                        sharding=sharding)
        return {k: conv(v) for k, v in tree.items()}
    return conv(abstract)


def shardings(abstract, mesh, rules=None):
    """NamedSharding pytree matching the param tree (for in_shardings)."""
    def conv(tree):
        if is_spec(tree):
            return named_sharding(tree.axes, tree.shape, mesh, rules)
        return {k: conv(v) for k, v in tree.items()}
    return conv(abstract)


def param_count(abstract) -> int:
    return sum(int(np.prod(s.shape)) for _, s in tree_paths(abstract))


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
