"""Mamba-1 selective SSM block (falcon-mamba) + shared chunked linear
recurrence.

TPU adaptation (DESIGN.md §5): the CUDA "selective scan" kernel is re-thought
as a *chunked associative scan* — sequence is split into chunks; within a
chunk ``lax.associative_scan`` exposes parallelism to the VPU, across chunks
a small ``lax.scan`` carries the [B, d_inner, N] state.  Discretization
(dA, dBx) is computed per-chunk inside the scan body so the full [B,S,di,N]
tensor is never materialized.  The same engine drives the RG-LRU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _combine(e1, e2):
    """Compose h->a1*h+b1 then h->a2*h+b2 (associative)."""
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def linear_recurrence_chunked(a, b, h0, chunk: int, unroll: bool = False):
    """h_t = a_t * h_{t-1} + b_t over axis 1 (time). a,b [B,S,...] fp32.

    Returns (h_all [B,S,...], h_last [B,...]).  ``unroll`` replaces the
    chunk lax.scan with a python loop (dry-run cost probes).
    """
    B, S = a.shape[0], a.shape[1]
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    rest = a.shape[2:]
    a_c = a.reshape((B, nc, chunk) + rest).transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))
    b_c = b.reshape((B, nc, chunk) + rest).transpose((1, 0, 2) + tuple(range(3, b.ndim + 1)))

    def body(h, inp):
        ac, bc = inp
        aa, bb = lax.associative_scan(_combine, (ac, bc), axis=1)
        h_all = aa * h[:, None] + bb
        return h_all[:, -1], h_all

    if unroll:
        h, chunks = h0, []
        for i in range(nc):
            h, h_all = body(h, (a_c[i], b_c[i]))
            chunks.append(h_all)
        h_last, h_chunks = h, jnp.stack(chunks)
    else:
        h_last, h_chunks = lax.scan(body, h0, (a_c, b_c))
    h_all = h_chunks.transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))
    return h_all.reshape((B, S) + rest), h_last


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv. x [B,S,C], w [C,K], b [C].

    ``state`` [B,K-1,C] carries the last K-1 inputs for decode; returns
    (y, new_state).
    """
    B, S, C = x.shape
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # [B, S+K-1, C]
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        y = y + xp[:, i: i + S].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = xp[:, S:]
    return y.astype(x.dtype), new_state


def mamba_mixer(x, p, cfg, *, conv_state=None, ssm_state=None):
    """Mamba-1 mixer. x [B,S,D] -> (y [B,S,D], (conv_state, ssm_state)).

    States given => stateful (decode/chunked-prefill) mode.
    """
    B, S, D = x.shape
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
    xz = x @ p["w_in"]                      # [B,S,2*di]
    xs, z = xz[..., :di], xz[..., di:]
    xs, new_conv = causal_conv1d(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)

    proj = xs @ p["w_x"]                    # [B,S,R+2N]
    dt, Bm, Cm = proj[..., :R], proj[..., R:R + N], proj[..., R + N:]
    dt = jax.nn.softplus((dt @ p["w_dt"] + p["dt_bias"]).astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # [di,N]
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    xs32 = xs.astype(jnp.float32)

    if ssm_state is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)
    else:
        h0 = ssm_state

    chunk = min(cfg.ssm_chunk, S)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk

    def body(h, inp):
        dt_c, B_c, C_c, x_c = inp            # [B,c,...]
        dA = jnp.exp(dt_c[..., None] * A)                # [B,c,di,N]
        dBx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]
        aa, bb = lax.associative_scan(_combine, (dA, dBx), axis=1)
        h_all = aa * h[:, None] + bb                     # [B,c,di,N]
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, C_c)
        return h_all[:, -1], y_c

    def chunked(t):  # [B,S,...] -> [nc,B,c,...]
        return t.reshape((B, nc, chunk) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    if getattr(cfg, "unroll_scans", False):
        h, ys = h0, []
        xs_in = (chunked(dt), chunked(Bm), chunked(Cm), chunked(xs32))
        for i in range(nc):
            h, y_i = body(h, tuple(t[i] for t in xs_in))
            ys.append(y_i)
        h_last, y_c = h, jnp.stack(ys)
    else:
        h_last, y_c = lax.scan(body, h0, (chunked(dt), chunked(Bm),
                                          chunked(Cm), chunked(xs32)))
    y = y_c.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + xs32 * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["w_out"], (new_conv, h_last)
