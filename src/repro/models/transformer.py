"""Unified decoder model covering all assigned families.

families: dense | moe | ssm (mamba) | hybrid (rg-lru + local attn) |
          audio / vlm (dense backbone + frontend-stub embeddings).

Params are ParamSpec trees (models/param.py).  Homogeneous stacks are
scanned (`lax.scan` over stacked [L, ...] params, jax.checkpoint remat
inside) so HLO size is O(1) in depth; the heterogeneous hybrid stack is
unrolled (26 small layers).

MoE expert FFN weights may be *packed* sparse entries
(``repro.sparse.install_sparse_ffn``) instead of dense arrays: an entry
is itself a pytree (block pool + index + permutations), so every path
here — ``forward``, chunked prefill, ragged/paged decode, and the
spec-decode draft/verify steps — carries it transparently (``lax.scan``
slices its leading layer axis exactly like a dense weight) and
``models.moe`` dispatches the expert matmuls through the block-sparse
execute path.  Oracle: packed forward/decode logits are bit-identical
to the dense-masked params' (tests/test_sparse_runtime.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import param as pm
from repro.models.layers import (apply_rope, attention, attention_decode,
                                 rmsnorm, rmsnorm_bf16grad, rope_tables,
                                 swiglu)
from repro.kernels.ops import paged_attention_op
from repro.models.moe import moe_apply
from repro.models.recurrent import recurrent_block
from repro.models.ssm import mamba_mixer


def _norm(x, scale, cfg):
    if getattr(cfg, "norm_bf16_grad", False):
        return rmsnorm_bf16grad(x, scale, cfg.norm_eps)
    return rmsnorm(x, scale, cfg.norm_eps)

# ---------------------------------------------------------------------------
# Abstract parameter trees
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, L=None):
    d, H, K, hd = cfg.d_model, cfg.heads_eff, cfg.kv_eff, cfg.head_dim
    s = lambda shape, axes, **kw: pm.spec(  # noqa: E731
        ((L,) + shape) if L else shape,
        (("layers",) + axes) if L else axes, **kw)
    out = {
        "wq": s((d, H, hd), ("fsdp", "heads", "head_dim")),
        "wk": s((d, K, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": s((d, K, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": s((H, hd, d), ("heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        out["bq"] = s((H, hd), ("heads", "head_dim"), init="zeros")
        out["bk"] = s((K, hd), ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = s((K, hd), ("kv_heads", "head_dim"), init="zeros")
    return out


def _mlp_specs(cfg: ModelConfig, L=None):
    d, f = cfg.d_model, cfg.d_ff
    s = lambda shape, axes, **kw: pm.spec(  # noqa: E731
        ((L,) + shape) if L else shape,
        (("layers",) + axes) if L else axes, **kw)
    return {
        "w_gate": s((d, f), ("fsdp", "mlp")),
        "w_up": s((d, f), ("fsdp", "mlp")),
        "w_down": s((f, d), ("mlp", "fsdp")),
    }


def _moe_specs(cfg: ModelConfig, L=None):
    d, fe, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    s = lambda shape, axes, **kw: pm.spec(  # noqa: E731
        ((L,) + shape) if L else shape,
        (("layers",) + axes) if L else axes, **kw)
    out = {
        "router": s((E, d), ("experts", "fsdp"), scale=0.02),
        "we_gate": s((E, d, fe), ("experts", "fsdp", "expert_mlp")),
        "we_up": s((E, d, fe), ("experts", "fsdp", "expert_mlp")),
        "we_down": s((E, fe, d), ("experts", "expert_mlp", "fsdp")),
    }
    if cfg.shared_expert:
        out["shared_gate"] = s((d, fe), ("fsdp", "expert_mlp"))
        out["shared_up"] = s((d, fe), ("fsdp", "expert_mlp"))
        out["shared_down"] = s((fe, d), ("expert_mlp", "fsdp"))
    return out


def _ssm_specs(cfg: ModelConfig, L=None):
    d, di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_actual
    Kc = cfg.ssm_conv
    s = lambda shape, axes, **kw: pm.spec(  # noqa: E731
        ((L,) + shape) if L else shape,
        (("layers",) + axes) if L else axes, **kw)
    return {
        "w_in": s((d, 2 * di), ("fsdp", "d_inner")),
        "conv_w": s((di, Kc), ("d_inner", "conv"), init="normal", scale=0.5),
        "conv_b": s((di,), ("d_inner",), init="zeros"),
        "w_x": s((di, R + 2 * N), ("d_inner", None)),
        "w_dt": s((R, di), ("dt_rank", "d_inner")),
        "dt_bias": s((di,), ("d_inner",), init="constant", scale=-4.0),
        "A_log": s((di, N), ("d_inner", "state"), init="constant", scale=0.5),
        "D": s((di,), ("d_inner",), init="ones"),
        "w_out": s((di, d), ("d_inner", "fsdp")),
    }


def _rec_specs(cfg: ModelConfig):
    d, W = cfg.d_model, (cfg.lru_width or cfg.d_model)
    Kc = cfg.ssm_conv
    return {
        "w_gate": pm.spec((d, W), ("fsdp", "lru")),
        "w_in": pm.spec((d, W), ("fsdp", "lru")),
        "conv_w": pm.spec((W, Kc), ("lru", "conv"), scale=0.5),
        "conv_b": pm.spec((W,), ("lru",), init="zeros"),
        "w_a": pm.spec((W, W), ("lru", None), scale=0.02),
        "b_a": pm.spec((W,), ("lru",), init="zeros"),
        "w_i": pm.spec((W, W), ("lru", None), scale=0.02),
        "b_i": pm.spec((W,), ("lru",), init="zeros"),
        "lambda": pm.spec((W,), ("lru",), init="constant", scale=1.0),
        "w_out": pm.spec((W, d), ("lru", "fsdp")),
    }


def _layer_specs(cfg: ModelConfig, kind: str, L=None):
    s = lambda shape, axes, **kw: pm.spec(  # noqa: E731
        ((L,) + shape) if L else shape,
        (("layers",) + axes) if L else axes, **kw)
    norm = lambda: s((cfg.d_model,), (None,), init="zeros")  # noqa: E731
    out = {"ln1": norm()}
    if kind == "attn":
        out["attn"] = _attn_specs(cfg, L)
        if cfg.family == "moe":
            out["moe"] = _moe_specs(cfg, L)
        else:
            out["mlp"] = _mlp_specs(cfg, L)
        out["ln2"] = norm()
    elif kind == "ssm":
        out["ssm"] = _ssm_specs(cfg, L)
    elif kind == "rec":
        out["rec"] = _rec_specs(cfg)
        out["mlp"] = _mlp_specs(cfg, None)
        out["ln2"] = norm()
    elif kind == "local_attn":
        out["attn"] = _attn_specs(cfg, None)
        out["mlp"] = _mlp_specs(cfg, None)
        out["ln2"] = norm()
    else:
        raise ValueError(kind)
    return out


def abstract_params(cfg: ModelConfig):
    Vp, d = cfg.padded_vocab, cfg.d_model
    tree = {
        "embed": pm.spec((Vp, d), ("vocab", "fsdp"), scale=1.0),
        "final_norm": pm.spec((d,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = pm.spec((d, Vp), ("fsdp", "vocab"))
    if cfg.family == "hybrid":
        pat = cfg.effective_pattern()
        tree["layers"] = {
            str(i): _layer_specs(cfg, "rec" if k == "rec" else "local_attn")
            for i, k in enumerate(pat)
        }
    elif cfg.scan_layers:
        kind = "ssm" if cfg.family == "ssm" else "attn"
        tree["layers"] = _layer_specs(cfg, kind, cfg.n_layers)
    else:
        kind = "ssm" if cfg.family == "ssm" else "attn"
        tree["layers"] = {str(i): _layer_specs(cfg, kind)
                          for i in range(cfg.n_layers)}
    return tree


def pad_attention_params(params, cfg_plain: ModelConfig,
                         cfg_padded: ModelConfig):
    """Migrate a checkpoint to the head-padded layout — mathematically
    exact: real q heads are permuted into group-aligned slots, padded q
    slots get arbitrary weights (their wo rows are masked to zero at
    apply time), kv heads are duplicated `kv_eff//K` times.
    """
    import numpy as np

    H, K = cfg_plain.n_heads, cfg_plain.n_kv_heads
    He, Ke = cfg_padded.heads_eff, cfg_padded.kv_eff
    per_real, per_eff = H // K, He // K
    q_slot = np.array([g * per_eff + r for g in range(K)
                       for r in range(per_real)])   # real q head -> slot
    # kv slot j serves q slots [j·G_eff, (j+1)·G_eff); those belong to real
    # kv group (j·G_eff)//per_eff  (clipped: slots past the real range only
    # serve wo-masked padded q heads)
    g_eff = He // Ke
    kv_src = np.array([min(j * g_eff // per_eff, K - 1) for j in range(Ke)])

    def fix(tree):
        if "attn" not in tree:
            return tree
        a = dict(tree["attn"])
        stacked = np.asarray(a["wq"]).ndim == 4  # [L, D, H, hd]
        ax = 2 if stacked else 1

        def pad_q(w):
            w = np.asarray(w, np.float32)
            shape = list(w.shape)
            shape[ax] = He
            out = np.zeros(shape, w.dtype)
            np.put_along_axis  # noqa: B018
            idx = [slice(None)] * w.ndim
            for h_real, slot in enumerate(q_slot):
                idx[ax] = slot
                src = [slice(None)] * w.ndim
                src[ax] = h_real
                out[tuple(idx)] = w[tuple(src)]
            return out

        def dup_kv(w):
            w = np.asarray(w, np.float32)
            return np.take(w, kv_src, axis=ax)

        def pad_q_bias(b):  # [H, hd] or [L, H, hd]
            b = np.asarray(b, np.float32)
            axb = 1 if b.ndim == 3 else 0
            shape = list(b.shape)
            shape[axb] = He
            out = np.zeros(shape, b.dtype)
            for h_real, slot in enumerate(q_slot):
                idx = [slice(None)] * b.ndim
                idx[axb] = slot
                src = [slice(None)] * b.ndim
                src[axb] = h_real
                out[tuple(idx)] = b[tuple(src)]
            return out

        def pad_wo(w):  # [H, hd, D] or [L, H, hd, D]
            w = np.asarray(w, np.float32)
            axo = 1 if w.ndim == 4 else 0
            shape = list(w.shape)
            shape[axo] = He
            out = np.zeros(shape, w.dtype)
            for h_real, slot in enumerate(q_slot):
                idx = [slice(None)] * w.ndim
                idx[axo] = slot
                src = [slice(None)] * w.ndim
                src[axo] = h_real
                out[tuple(idx)] = w[tuple(src)]
            return out

        def dup_kv_bias(b):
            b = np.asarray(b, np.float32)
            axb = 1 if b.ndim == 3 else 0
            return np.take(b, kv_src, axis=axb)

        a["wq"] = jnp.asarray(pad_q(a["wq"]), jnp.dtype(cfg_padded.dtype))
        a["wk"] = jnp.asarray(dup_kv(a["wk"]), jnp.dtype(cfg_padded.dtype))
        a["wv"] = jnp.asarray(dup_kv(a["wv"]), jnp.dtype(cfg_padded.dtype))
        a["wo"] = jnp.asarray(pad_wo(a["wo"]), jnp.dtype(cfg_padded.dtype))
        if "bq" in a:
            a["bq"] = jnp.asarray(pad_q_bias(a["bq"]),
                                  jnp.dtype(cfg_padded.dtype))
            a["bk"] = jnp.asarray(dup_kv_bias(a["bk"]),
                                  jnp.dtype(cfg_padded.dtype))
            a["bv"] = jnp.asarray(dup_kv_bias(a["bv"]),
                                  jnp.dtype(cfg_padded.dtype))
        return {**tree, "attn": a}

    out = dict(params)
    layers = params["layers"]
    if isinstance(layers, dict) and "attn" in layers:       # scan-stacked
        out["layers"] = fix(layers)
    elif isinstance(layers, dict):                          # dict of layers
        out["layers"] = {k: fix(v) for k, v in layers.items()}
    return out


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _qkv_proj(x, p, cfg, sin, cos):
    """Shared QKV projection: bias, RoPE, and the pad_heads wo mask.

    Returns (q, k, v, wo) — the single source of truth for both the
    forward/decode block and the chunked-prefill block."""
    wo = p["wo"]
    if cfg.pad_heads and cfg.heads_eff != cfg.n_heads:
        # exact head padding: zero-mask wo rows of padded q-head slots
        mask = jnp.asarray(cfg.head_slot_mask(), wo.dtype)[:, None, None]
        wo = wo * mask
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v, wo


def _attn_block(x, p, cfg, sin, cos, q_pos, kv_pos, *, window=None,
                cache=None, cache_len=None):
    """Returns (out, (new_k_slice, new_v_slice)) — cache slices when decoding."""
    q, k, v, wo = _qkv_proj(x, p, cfg, sin, cos)
    if cache is None:
        o = attention(q, k, v, q_pos, kv_pos, impl=cfg.attn_impl,
                      window=window, softcap=cfg.attn_logit_softcap,
                      chunk=cfg.attn_chunk, unroll=cfg.unroll_scans)
        new_kv = (k, v)
    else:
        k_cache, v_cache, write_idx = cache
        widx = jnp.asarray(write_idx)
        if widx.ndim == 0:               # uniform position for the batch
            k_cache = lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), widx, axis=1)
            v_cache = lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), widx, axis=1)
        else:                            # ragged: per-request position [B]
            upd = jax.vmap(lambda c, kv, i: lax.dynamic_update_slice_in_dim(
                c, kv, i, axis=0))
            k_cache = upd(k_cache, k.astype(k_cache.dtype), widx)
            v_cache = upd(v_cache, v.astype(v_cache.dtype), widx)
        # hybrid ring-buffer callers pass window=None (the buffer itself
        # bounds the horizon); full-length absolute-position caches pass
        # their sliding window through so decode matches prefill masking
        o = attention_decode(q, k_cache, v_cache, cache_len,
                             window=window,
                             softcap=cfg.attn_logit_softcap)
        new_kv = (k_cache, v_cache)
    out = jnp.einsum("bshk,hkd->bsd", o, wo)
    return out, new_kv


def _mlp_block(x, p):
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _remat(fn, cfg):
    if cfg.remat_policy == "full":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "nothing": save only layer boundaries


def _embed_in(params, cfg, batch):
    if "embeds" in batch:
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        h = params["embed"][batch["tokens"]]
    return h


def _norm_expert_mask(cfg: ModelConfig, expert_mask):
    """Normalize a runtime expert-alive mask to [L, E] fp32 (or None).

    Accepts [E] (shared across layers) or [L, E] (per-layer, e.g. the
    keep-mask from ``expert_prune_moe(mode="mask")``).
    """
    if expert_mask is None or cfg.family != "moe":
        return None
    em = jnp.asarray(expert_mask, jnp.float32)
    if em.ndim == 1:
        em = jnp.broadcast_to(em[None], (cfg.n_layers, em.shape[0]))
    return em


def forward(params, cfg: ModelConfig, batch, *, mesh=None, expert_mask=None):
    """Full-sequence forward -> logits [B, S, padded_vocab].

    ``expert_mask`` ([E] or [L, E], 1=alive) applies runtime expert pruning
    in every MoE layer (router logits of dead experts forced to -inf).
    """
    em = _norm_expert_mask(cfg, expert_mask)
    h = _embed_in(params, cfg, batch)
    B, S, D = h.shape
    pos = jnp.arange(S)
    sin, cos = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    if mesh is not None:
        batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        ax = batch_ax[0] if len(batch_ax) == 1 else batch_ax
        h = lax.with_sharding_constraint(h, jax.NamedSharding(mesh, P(ax, None, None)))

    fam = cfg.family
    if fam == "hybrid":
        pat = cfg.effective_pattern()
        for i, kind in enumerate(pat):
            p = params["layers"][str(i)]

            def layer(h, p=p, kind=kind):
                if kind == "rec":
                    mix, _ = recurrent_block(_norm(h, p["ln1"], cfg),
                                             p["rec"], cfg)
                else:
                    mix, _ = _attn_block(_norm(h, p["ln1"], cfg),
                                         p["attn"], cfg, sin, cos, pos, pos,
                                         window=cfg.local_window)
                h = h + mix
                h = h + _mlp_block(_norm(h, p["ln2"], cfg), p["mlp"])
                return h

            h = _remat(layer, cfg)(h)
    else:
        def body(h, lp, em_row=None):
            if fam == "ssm":
                mix, _ = mamba_mixer(_norm(h, lp["ln1"], cfg),
                                     lp["ssm"], cfg)
                return h + mix, None
            mix, _ = _attn_block(_norm(h, lp["ln1"], cfg),
                                 lp["attn"], cfg, sin, cos, pos, pos,
                                 window=cfg.local_window)
            h = h + mix
            x2 = _norm(h, lp["ln2"], cfg)
            if cfg.family == "moe":
                h = h + moe_apply(x2, lp["moe"], cfg, mesh=mesh,
                                  expert_mask=em_row)
            else:
                h = h + _mlp_block(x2, lp["mlp"])
            return h, None
        if cfg.scan_layers:
            if em is None:
                h, _ = lax.scan(_remat(body, cfg), h, params["layers"])
            else:
                h, _ = lax.scan(
                    _remat(lambda hh, x: body(hh, x[0], x[1]), cfg),
                    h, (params["layers"], em))
        else:
            for i in range(cfg.n_layers):
                lp = params["layers"][str(i)]
                em_i = None if em is None else em[i]
                h = _remat(lambda hh, lp=lp, em_i=em_i:
                           body(hh, lp, em_i)[0], cfg)(h)

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return logits


def loss_fn(params, cfg: ModelConfig, batch, *, mesh=None):
    """Mean next-token cross-entropy via logsumexp.

    Logits stay in the model dtype (bf16) — the fp32 cast happens inside
    the reductions, so no [B,S,V] fp32 tensor is materialized (at 256k
    vocab that tensor is the single largest temp in the step).  Padded
    vocab columns are suppressed with an additive bias (fusable broadcast)
    rather than a where() over the full logits.
    """
    logits = forward(params, cfg, batch, mesh=mesh)
    labels = batch["labels"]
    Vp = cfg.padded_vocab
    if Vp != cfg.vocab:
        pad_bias = jnp.where(jnp.arange(Vp) < cfg.vocab, 0.0, -1e30
                             ).astype(logits.dtype)
        logits = logits + pad_bias[None, None, :]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)   # [B,S]
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1
                             )[..., 0].astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# KV / state caches + decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch_size: int, max_len: int):
    """ParamSpec tree for the decode cache (dry-run uses ShapeDtypeStructs)."""
    B = batch_size
    K, hd = cfg.kv_eff, cfg.head_dim
    fam = cfg.family
    if fam == "hybrid":
        pat = cfg.effective_pattern()
        W = cfg.lru_width or cfg.d_model
        tree = {}
        for i, kind in enumerate(pat):
            if kind == "rec":
                tree[str(i)] = {
                    "conv": pm.spec((B, cfg.ssm_conv - 1, W),
                                    ("batch", None, "lru"), init="zeros",
                                    dtype=cfg.dtype),
                    "lru": pm.spec((B, W), ("batch", "lru"), init="zeros",
                                   dtype="float32"),
                }
            else:
                T = min(max_len, cfg.local_window or max_len)
                kvdt = cfg.kv_cache_dtype or cfg.dtype
                tree[str(i)] = {
                    "k": pm.spec((B, T, K, hd),
                                 ("batch", None, "kv_heads", "head_dim"),
                                 init="zeros", dtype=kvdt),
                    "v": pm.spec((B, T, K, hd),
                                 ("batch", None, "kv_heads", "head_dim"),
                                 init="zeros", dtype=kvdt),
                }
        return tree
    if fam == "ssm":
        L, di, N = cfg.n_layers, cfg.d_inner, cfg.ssm_state
        return {
            "conv": pm.spec((L, B, cfg.ssm_conv - 1, di),
                            ("layers", "batch", None, "d_inner"),
                            init="zeros", dtype=cfg.dtype),
            "ssm_h": pm.spec((L, B, di, N),
                             ("layers", "batch", "d_inner", "state"),
                             init="zeros", dtype="float32"),
        }
    L = cfg.n_layers
    kvdt = cfg.kv_cache_dtype or cfg.dtype
    return {
        "k": pm.spec((L, B, max_len, K, hd),
                     ("layers", "batch", None, "kv_heads", "head_dim"),
                     init="zeros", dtype=kvdt),
        "v": pm.spec((L, B, max_len, K, hd),
                     ("layers", "batch", None, "kv_heads", "head_dim"),
                     init="zeros", dtype=kvdt),
    }


def init_cache(cfg, batch_size, max_len):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        cache_specs(cfg, batch_size, max_len), is_leaf=pm.is_spec)


def paged_cache_specs(cfg: ModelConfig, n_pages: int, page_size: int):
    """ParamSpec tree for the paged decode cache: K/V pools
    [L, n_pages, page_size, K, hd] addressed through per-lane page tables
    (serving/kv_cache.PagedKVCache owns the tables; page 0 is the
    engine's sentinel).  Attention families only."""
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"paged KV cache requires an attention cache; "
            f"family={cfg.family!r} keeps recurrent state instead")
    L, K, hd = cfg.n_layers, cfg.kv_eff, cfg.head_dim
    kvdt = cfg.kv_cache_dtype or cfg.dtype
    return {
        "k": pm.spec((L, n_pages, page_size, K, hd),
                     ("layers", None, None, "kv_heads", "head_dim"),
                     init="zeros", dtype=kvdt),
        "v": pm.spec((L, n_pages, page_size, K, hd),
                     ("layers", None, None, "kv_heads", "head_dim"),
                     init="zeros", dtype=kvdt),
    }


def init_paged_cache(cfg, n_pages, page_size):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        paged_cache_specs(cfg, n_pages, page_size), is_leaf=pm.is_spec)


def decode_step(params, cfg: ModelConfig, cache, tokens, cur_len, *,
                mesh=None, expert_mask=None):
    """One decode step. tokens [B,1] int32; cur_len scalar int32 (uniform).

    Returns (logits [B, padded_vocab], new_cache).  Attention families
    delegate to ``decode_step_ragged`` with uniform positions; the bodies
    below cover the recurrent-state families, where ``expert_mask`` is a
    no-op (no MoE layers).
    """
    if cfg.family not in ("ssm", "hybrid"):
        seq_lens = jnp.full((tokens.shape[0],), cur_len, jnp.int32)
        return decode_step_ragged(params, cfg, cache, tokens, seq_lens,
                                  mesh=mesh, expert_mask=expert_mask)
    h = params["embed"][tokens]                      # [B,1,D]
    B = h.shape[0]
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    sin, cos = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    cache_len = jnp.full((B,), cur_len + 1, jnp.int32)
    fam = cfg.family

    if fam == "hybrid":
        pat = cfg.effective_pattern()
        new_cache = {}
        for i, kind in enumerate(pat):
            p = params["layers"][str(i)]
            c = cache[str(i)]
            if kind == "rec":
                mix, (nconv, nlru) = recurrent_block(
                    _norm(h, p["ln1"], cfg), p["rec"], cfg,
                    conv_state=c["conv"], lru_state=c["lru"])
                new_cache[str(i)] = {"conv": nconv, "lru": nlru}
            else:
                T = c["k"].shape[1]
                write_idx = jnp.mod(cur_len, T)      # ring buffer (window)
                eff_len = jnp.minimum(cache_len, T)
                mix, (nk, nv) = _attn_block(
                    _norm(h, p["ln1"], cfg), p["attn"], cfg,
                    sin, cos, None, None,
                    cache=(c["k"], c["v"], write_idx), cache_len=eff_len)
                new_cache[str(i)] = {"k": nk, "v": nv}
            h = h + mix
            h = h + _mlp_block(_norm(h, p["ln2"], cfg), p["mlp"])
    elif fam == "ssm":
        def body(h, inp):
            lp, conv_c, ssm_c = inp
            mix, (nconv, nh) = mamba_mixer(_norm(h, lp["ln1"], cfg),
                                           lp["ssm"], cfg,
                                           conv_state=conv_c, ssm_state=ssm_c)
            return h + mix, (nconv, nh)
        if cfg.scan_layers:
            h, (nconv, nh) = lax.scan(body, h, (params["layers"],
                                                cache["conv"], cache["ssm_h"]))
        else:
            convs, hs = [], []
            for i in range(cfg.n_layers):
                h, (nc_, nh_) = body(h, (params["layers"][str(i)],
                                         cache["conv"][i], cache["ssm_h"][i]))
                convs.append(nc_)
                hs.append(nh_)
            nconv, nh = jnp.stack(convs), jnp.stack(hs)
        new_cache = {"conv": nconv, "ssm_h": nh}
    else:  # attention families are handled by the delegation above
        raise AssertionError(fam)

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0]
    return logits, new_cache


def decode_step_ragged(params, cfg: ModelConfig, cache, tokens, seq_lens, *,
                       mesh=None, expert_mask=None):
    """One continuous-batching decode step with per-request positions.

    tokens [B,1] int32 — one token per cache slot; seq_lens [B] int32 — the
    number of tokens already in each slot's cache (the new token is written
    at index ``seq_lens[b]``, RoPE'd at that position, and attends to
    ``seq_lens[b]+1`` cache rows).  Slots whose lane is unused still compute
    (lanes are fixed under jit) — callers simply discard those logits.
    NOTE: unused lanes also write their placeholder token's K/V at row
    ``seq_lens[b]`` (0 for a free slot); this is safe only because slot
    prefill always rewrites a slot from row 0 before it is attended — any
    future prefill that starts mid-slot must first clear row 0.

    Only KV-cache families (dense/moe/audio/vlm transformers) support ragged
    decode; recurrent families keep uniform-position ``decode_step``.
    Returns (logits [B, padded_vocab], new_cache).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"ragged decode requires a KV cache; family={cfg.family!r}")
    h = params["embed"][tokens]                      # [B,1,D]
    pos = seq_lens[:, None]                          # [B,1] per-request
    sin, cos = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    cache_len = seq_lens + 1
    em = _norm_expert_mask(cfg, expert_mask)

    def body(h, inp):
        if em is None:
            lp, kc, vc = inp
            em_row = None
        else:
            lp, kc, vc, em_row = inp
        mix, (nk, nv) = _attn_block(
            _norm(h, lp["ln1"], cfg), lp["attn"], cfg,
            sin, cos, None, None, window=cfg.local_window,
            cache=(kc, vc, seq_lens), cache_len=cache_len)
        h = h + mix
        x2 = _norm(h, lp["ln2"], cfg)
        if cfg.family == "moe":
            h = h + moe_apply(x2, lp["moe"], cfg, mesh=mesh,
                              expert_mask=em_row)
        else:
            h = h + _mlp_block(x2, lp["mlp"])
        return h, (nk, nv)

    if cfg.scan_layers:
        xs = (params["layers"], cache["k"], cache["v"])
        if em is not None:
            xs = xs + (em,)
        h, (nk, nv) = lax.scan(body, h, xs)
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            inp = (params["layers"][str(i)], cache["k"][i], cache["v"][i])
            if em is not None:
                inp = inp + (em[i],)
            h, (nk_, nv_) = body(h, inp)
            ks.append(nk_)
            vs.append(nv_)
        nk, nv = jnp.stack(ks), jnp.stack(vs)
    new_cache = {"k": nk, "v": nv}

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0]
    return logits, new_cache


def decode_step_paged(params, cfg: ModelConfig, cache, tokens, seq_lens,
                      page_tables, *, mesh=None, expert_mask=None):
    """One continuous-batching decode step over the paged KV cache.

    tokens [B,1] int32 — one token per batch lane; seq_lens [B] int32 —
    valid rows already in each lane; page_tables [B, max_pages] int32 —
    physical page of each lane's logical page (sentinel page 0 where
    unassigned).  Lane ``b``'s new K/V is scattered to flat row
    ``page_tables[b, seq_lens[b]//ps]*ps + seq_lens[b]%ps`` of the
    [n_pages*ps, K, hd] pool, RoPE'd at position ``seq_lens[b]``, and the
    lane attends ``seq_lens[b]+1`` logical rows through the fused paged
    kernel (jnp gather reference off-TPU).  Inactive lanes carry an
    all-sentinel table row, so their placeholder write lands in page 0 —
    allocated pages are never dirtied by idle lanes (unlike the slot
    layout, no prefill-from-row-0 invariant is needed).

    Returns (logits [B, padded_vocab], new_cache).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"paged decode requires a KV cache; family={cfg.family!r}")
    h = params["embed"][tokens]                      # [B,1,D]
    B = tokens.shape[0]
    pos = seq_lens[:, None]                          # [B,1] per-request
    sin, cos = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    cache_len = seq_lens + 1
    em = _norm_expert_mask(cfg, expert_mask)
    n_pages, ps = cache["k"].shape[1], cache["k"].shape[2]
    widx = page_tables[jnp.arange(B), seq_lens // ps] * ps + seq_lens % ps

    def body(h, inp):
        if em is None:
            lp, kc, vc = inp
            em_row = None
        else:
            lp, kc, vc, em_row = inp
        x = _norm(h, lp["ln1"], cfg)
        q, k, v, wo = _qkv_proj(x, lp["attn"], cfg, sin, cos)
        kshape = kc.shape                            # [n_pages, ps, K, hd]
        kc = kc.reshape(n_pages * ps, *kshape[2:])
        vc = vc.reshape(n_pages * ps, *kshape[2:])
        kc = kc.at[widx].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[widx].set(v[:, 0].astype(vc.dtype))
        kc = kc.reshape(kshape)
        vc = vc.reshape(kshape)
        o = paged_attention_op(q, kc, vc, page_tables, cache_len,
                               window=cfg.local_window,
                               softcap=cfg.attn_logit_softcap)
        h = h + jnp.einsum("bshk,hkd->bsd", o, wo)
        x2 = _norm(h, lp["ln2"], cfg)
        if cfg.family == "moe":
            h = h + moe_apply(x2, lp["moe"], cfg, mesh=mesh,
                              expert_mask=em_row)
        else:
            h = h + _mlp_block(x2, lp["mlp"])
        return h, (kc, vc)

    if cfg.scan_layers:
        xs = (params["layers"], cache["k"], cache["v"])
        if em is not None:
            xs = xs + (em,)
        h, (nk, nv) = lax.scan(body, h, xs)
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            inp = (params["layers"][str(i)], cache["k"][i], cache["v"][i])
            if em is not None:
                inp = inp + (em[i],)
            h, (nk_, nv_) = body(h, inp)
            ks.append(nk_)
            vs.append(nv_)
        nk, nv = jnp.stack(ks), jnp.stack(vs)
    new_cache = {"k": nk, "v": nv}

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0]
    return logits, new_cache


def verify_step_paged(params, cfg: ModelConfig, cache, tokens, seq_lens,
                      page_tables, *, mesh=None, expert_mask=None,
                      depth=None, allow_block=None):
    """Score a ragged block of draft tokens with the (dense) model — the
    verifier half of self-speculative decoding.

    tokens [B, W] int32 — per lane, position 0 is the lane's last emitted
    token (not yet in cache) and positions 1..W-1 are the W-1 draft
    proposals; seq_lens [B] int32 — valid rows already in each lane;
    page_tables [B, max_pages] int32 (sentinel page 0 where unassigned).
    Lane ``b``'s token ``j`` sits at cache row ``seq_lens[b]+j``: its K/V
    is scattered through the page table to that row (overwriting whatever
    the draft pass wrote there — the cache prefix stays pure verifier K/V
    for every row that can ever be attended again).

    **Chain blocks** (``depth=None``): token ``j`` is RoPE'd at absolute
    position ``seq_lens[b]+j`` and attends rows [0, seq_lens[b]+j]
    causally.

    **Tree blocks**: ``depth`` [W] int32 gives each block row's depth
    below the anchor (``depth[0] == 0``), and ``allow_block`` [W, W] bool
    gives intra-block attendability (``allow_block[r, s]`` — may query
    row ``r`` attend block row ``s``; ancestors-or-self only).  Token
    ``j`` still *writes* cache row ``seq_lens[b]+j`` but is RoPE'd at
    position ``seq_lens[b]+depth[j]``, and attention uses tree positions
    for the causal/window mask ANDed with ``allow_block`` — required
    because sibling branches share absolute positions, so positional
    causality alone would let branches attend each other.  Both must be
    device arrays of static shape (or None together).

    Greedy *chain* acceptance is computed in-dispatch: the drafted token
    ``j+1`` is accepted iff it equals the verifier's argmax at block
    position ``j``, and acceptance stops at the first mismatch.  For tree
    blocks (and for rejection *sampling* at temperature > 0) the
    accept/resample decision instead lives in
    ``serving.speculative.accept_block``, which consumes the returned
    dense logits in the same jitted dispatch — the chain-greedy outputs
    returned here are then unused and DCE'd by XLA.

    Returns ``(accept_len [B], next_token [B], logits [B, W, padded_vocab],
    new_cache)`` — ``accept_len`` in [0, W-1] counts accepted draft
    tokens; ``next_token`` is the verifier's argmax after the accepted
    prefix (the correction at the first mismatch, or the bonus token when
    every draft was accepted).  The caller emits
    ``draft[:accept_len] + [next_token]`` and rolls ``seq_len`` back to
    drop the rejected suffix — rolled-back rows are rewritten before they
    can be attended, so no page frees are needed.

    Requires every block write to land inside the lane's page reservation
    (``PagedKVCache(overdraft=W-1)``); writes past it would fall onto the
    shared sentinel page, and a same-dispatch query could then attend
    another lane's scribble.  Attention families only.
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"paged verify requires a KV cache; family={cfg.family!r}")
    h = params["embed"][tokens]                      # [B,W,D]
    B, W = tokens.shape
    row = seq_lens[:, None] + jnp.arange(W)[None]    # [B,W] cache rows
    if depth is None:
        q_pos = row                                  # chain: position == row
    else:
        q_pos = seq_lens[:, None] + depth[None]      # tree: position by depth
    sin, cos = rope_tables(q_pos, cfg.head_dim, cfg.rope_theta)
    em = _norm_expert_mask(cfg, expert_mask)
    n_pages, ps = cache["k"].shape[1], cache["k"].shape[2]
    widx = (page_tables[jnp.arange(B)[:, None], row // ps] * ps
            + row % ps).reshape(-1)                  # [B*W] flat pool rows
    lane_idx = (page_tables[:, :, None] * ps
                + jnp.arange(ps)[None, None, :]).reshape(B, -1)  # [B,T]
    T = lane_idx.shape[1]
    kv_len = seq_lens + W                            # rows valid after write
    if depth is None:
        kv_pos = jnp.arange(T)                       # [T]: position == row
        allow = None
    else:
        # lane-view row t holds position t for history rows and
        # seq_lens[b]+depth[s] for block row seq_lens[b]+s
        oh = jnp.arange(T)[None, None, :] == row[:, :, None]     # [B,W,T]
        shift = (depth - jnp.arange(W)).astype(jnp.int32)
        kv_pos = (jnp.arange(T)[None]
                  + (oh * shift[None, :, None]).sum(axis=1))     # [B,T]
        in_block = oh.any(axis=1)[:, None, :]                    # [B,1,T]
        ab = jnp.einsum("bst,rs->brt", oh.astype(jnp.float32),
                        allow_block.astype(jnp.float32)) > 0.5   # [B,W,T]
        allow = jnp.where(in_block, ab, True)

    def body(h, inp):
        if em is None:
            lp, kc, vc = inp
            em_row = None
        else:
            lp, kc, vc, em_row = inp
        x = _norm(h, lp["ln1"], cfg)
        q, k, v, wo = _qkv_proj(x, lp["attn"], cfg, sin, cos)
        kshape = kc.shape                            # [n_pages, ps, K, hd]
        kc = kc.reshape(n_pages * ps, *kshape[2:])
        vc = vc.reshape(n_pages * ps, *kshape[2:])
        kc = kc.at[widx].set(k.reshape(B * W, *kshape[2:]).astype(kc.dtype))
        vc = vc.at[widx].set(v.reshape(B * W, *kshape[2:]).astype(vc.dtype))
        # gather each lane's logical view (block included) and attend the
        # written prefix under per-lane causal + length masking
        ks = kc[lane_idx]                            # [B,T,K,hd]
        vs = vc[lane_idx]
        o = attention(q, ks, vs, q_pos, kv_pos, impl=cfg.attn_impl,
                      window=cfg.local_window, softcap=cfg.attn_logit_softcap,
                      chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
                      kv_len=kv_len, allow=allow)
        h = h + jnp.einsum("bshk,hkd->bsd", o, wo)
        x2 = _norm(h, lp["ln2"], cfg)
        if cfg.family == "moe":
            h = h + moe_apply(x2, lp["moe"], cfg, mesh=mesh,
                              expert_mask=em_row)
        else:
            h = h + _mlp_block(x2, lp["mlp"])
        return h, (kc.reshape(kshape), vc.reshape(kshape))

    if cfg.scan_layers:
        xs = (params["layers"], cache["k"], cache["v"])
        if em is not None:
            xs = xs + (em,)
        h, (nk, nv) = lax.scan(body, h, xs)
    else:
        ks_, vs_ = [], []
        for i in range(cfg.n_layers):
            inp = (params["layers"][str(i)], cache["k"][i], cache["v"][i])
            if em is not None:
                inp = inp + (em[i],)
            h, (nk_, nv_) = body(h, inp)
            ks_.append(nk_)
            vs_.append(nv_)
        nk, nv = jnp.stack(ks_), jnp.stack(vs_)
    new_cache = {"k": nk, "v": nv}

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)       # [B,W,Vp]

    greedy = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    match = (greedy[:, :-1] == tokens[:, 1:]).astype(jnp.int32)   # [B,W-1]
    accept_len = jnp.cumprod(match, axis=1).sum(axis=1).astype(jnp.int32)
    next_token = jnp.take_along_axis(greedy, accept_len[:, None],
                                     axis=1)[:, 0]
    return accept_len, next_token, logits, new_cache


def prefill_step_paged(params, cfg: ModelConfig, cache, tokens, page_row,
                       start, *, mesh=None, expert_mask=None):
    """Single-dispatch chunked prefill writing K/V through a page table.

    Processes one fixed-size chunk of one request's prompt: ``tokens``
    [1, C] int32 (right-padded), ``page_row`` [max_pages] int32 (the
    lane's page-table row; sentinel 0 past the reserved pages), ``start``
    scalar int32 (absolute position of the chunk's first token — a
    multiple of C).  Row ``p`` of the chunk lands at flat pool row
    ``page_row[p//ps]*ps + p%ps``; padded positions past the reservation
    fall through to the sentinel page and are never attended (the chunk
    attends its lane's gathered logical rows [0, start+C) under the same
    causal + length mask as the slot path).

    Returns (logits [1, C, padded_vocab], new_cache).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"chunked prefill requires a KV cache; family={cfg.family!r}")
    h = params["embed"][tokens]                      # [1,C,D]
    C = h.shape[1]
    q_pos = start + jnp.arange(C)                    # [C]
    sin, cos = rope_tables(q_pos, cfg.head_dim, cfg.rope_theta)
    em = _norm_expert_mask(cfg, expert_mask)
    n_pages, ps = cache["k"].shape[1], cache["k"].shape[2]
    widx = page_row[q_pos // ps] * ps + q_pos % ps             # [C]
    lane_idx = (page_row[:, None] * ps
                + jnp.arange(ps)[None, :]).reshape(-1)         # [T]
    T = lane_idx.shape[0]

    def body(h, inp):
        if em is None:
            lp, kc, vc = inp
            em_row = None
        else:
            lp, kc, vc, em_row = inp
        x = _norm(h, lp["ln1"], cfg)
        q, k, v, wo = _qkv_proj(x, lp["attn"], cfg, sin, cos)
        kshape = kc.shape
        kc = kc.reshape(n_pages * ps, *kshape[2:])
        vc = vc.reshape(n_pages * ps, *kshape[2:])
        kc = kc.at[widx].set(k[0].astype(kc.dtype))
        vc = vc.at[widx].set(v[0].astype(vc.dtype))
        # gather the lane's logical view (chunk included) and attend the
        # written prefix under causal + kv_len masking
        ks = kc[lane_idx][None]                      # [1,T,K,hd]
        vs = vc[lane_idx][None]
        o = attention(q, ks, vs, q_pos, jnp.arange(T), impl=cfg.attn_impl,
                      window=cfg.local_window, softcap=cfg.attn_logit_softcap,
                      chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
                      kv_len=start + C)
        mix = jnp.einsum("bshk,hkd->bsd", o, wo)
        h = h + mix
        x2 = _norm(h, lp["ln2"], cfg)
        if cfg.family == "moe":
            h = h + moe_apply(x2, lp["moe"], cfg, mesh=mesh,
                              expert_mask=em_row)
        else:
            h = h + _mlp_block(x2, lp["mlp"])
        return h, (kc.reshape(kshape), vc.reshape(kshape))

    if cfg.scan_layers:
        xs = (params["layers"], cache["k"], cache["v"])
        if em is not None:
            xs = xs + (em,)
        h, (nk, nv) = lax.scan(body, h, xs)
    else:
        ks_, vs_ = [], []
        for i in range(cfg.n_layers):
            inp = (params["layers"][str(i)], cache["k"][i], cache["v"][i])
            if em is not None:
                inp = inp + (em[i],)
            h, (nk_, nv_) = body(h, inp)
            ks_.append(nk_)
            vs_.append(nv_)
        nk, nv = jnp.stack(ks_), jnp.stack(vs_)
    new_cache = {"k": nk, "v": nv}

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return logits, new_cache


def prefill_step(params, cfg: ModelConfig, cache, tokens, slot, start, *,
                 mesh=None, expert_mask=None):
    """Single-dispatch chunked prefill with fused cache writes.

    Processes one fixed-size chunk of one request's prompt: ``tokens``
    [1, C] int32 (right-padded), ``slot`` scalar int32 (cache slot to fill),
    ``start`` scalar int32 (absolute position of the chunk's first token —
    a multiple of C).  The chunk's K/V are written into
    ``cache[k|v][:, slot, start:start+C]`` and the chunk attends to the
    slot's cache rows ``[0, start+C)`` under a causal + length mask, so an
    S-token prompt costs ``ceil(S/C)`` jitted dispatches instead of S and
    padded rows never contaminate attention.

    Returns (logits [1, C, padded_vocab], new_cache).
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"chunked prefill requires a KV cache; family={cfg.family!r}")
    h = params["embed"][tokens]                      # [1,C,D]
    C = h.shape[1]
    q_pos = start + jnp.arange(C)                    # [C]
    sin, cos = rope_tables(q_pos, cfg.head_dim, cfg.rope_theta)
    em = _norm_expert_mask(cfg, expert_mask)

    def body(h, inp):
        if em is None:
            lp, kc, vc = inp
            em_row = None
        else:
            lp, kc, vc, em_row = inp
        x = _norm(h, lp["ln1"], cfg)
        q, k, v, wo = _qkv_proj(x, lp["attn"], cfg, sin, cos)
        # slice this slot's cache, splice the chunk in, attend to the
        # written prefix, then write the slot back
        ks = lax.dynamic_slice_in_dim(kc, slot, 1, axis=0)   # [1,T,K,hd]
        vs = lax.dynamic_slice_in_dim(vc, slot, 1, axis=0)
        ks = lax.dynamic_update_slice(ks, k.astype(ks.dtype), (0, start, 0, 0))
        vs = lax.dynamic_update_slice(vs, v.astype(vs.dtype), (0, start, 0, 0))
        T = ks.shape[1]
        o = attention(q, ks, vs, q_pos, jnp.arange(T), impl=cfg.attn_impl,
                      window=cfg.local_window, softcap=cfg.attn_logit_softcap,
                      chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
                      kv_len=start + C)
        mix = jnp.einsum("bshk,hkd->bsd", o, wo)
        kc = lax.dynamic_update_slice(kc, ks, (slot, 0, 0, 0))
        vc = lax.dynamic_update_slice(vc, vs, (slot, 0, 0, 0))
        h = h + mix
        x2 = _norm(h, lp["ln2"], cfg)
        if cfg.family == "moe":
            h = h + moe_apply(x2, lp["moe"], cfg, mesh=mesh,
                              expert_mask=em_row)
        else:
            h = h + _mlp_block(x2, lp["mlp"])
        return h, (kc, vc)

    if cfg.scan_layers:
        xs = (params["layers"], cache["k"], cache["v"])
        if em is not None:
            xs = xs + (em,)
        h, (nk, nv) = lax.scan(body, h, xs)
    else:
        ks_, vs_ = [], []
        for i in range(cfg.n_layers):
            inp = (params["layers"][str(i)], cache["k"][i], cache["v"][i])
            if em is not None:
                inp = inp + (em[i],)
            h, (nk_, nv_) = body(h, inp)
            ks_.append(nk_)
            vs_.append(nv_)
        nk, nv = jnp.stack(ks_), jnp.stack(vs_)
    new_cache = {"k": nk, "v": nv}

    h = _norm(h, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return logits, new_cache
