"""Logical-axis sharding rules with divisibility-aware fallback.

Every tensor dim in the framework is tagged with a *logical* axis name.
Rules map logical names to an ordered tuple of mesh axes; at spec-resolution
time each candidate mesh axis is kept only if it exists in the mesh AND
divides the dim size (composite candidates like ("pod","data") are kept as a
group when the product divides).  This resolves, per-architecture, cases
like qwen2's 28 heads on a 16-way model axis: "heads" falls back to
unsharded while "mlp" still shards — never a silent wrong sharding, never a
compile failure.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> ordered candidates. Each candidate is a tuple of mesh axes
# used jointly for that dim (first fitting candidate wins).
DEFAULT_RULES = {
    # activations
    "batch": (("pod", "data"), ("data",)),
    "seq": ((),),                      # sequence kept unsharded by default (SP is a perf knob)
    "embed": ((),),                    # activation d_model replicated; TP reduces after proj
    # params: tensor-parallel dims
    "vocab": (("model",),),
    "heads": (("model",), ()),
    "kv_heads": (("model",), ()),
    "mlp": (("model",),),
    "experts": (("model",), ()),
    "d_inner": (("model",),),          # mamba inner dim
    "lru": (("model",),),              # rg-lru width
    # params: FSDP dim (weight-stationary dim sharded over data axis)
    "fsdp": (("data",), ()),
    # never sharded
    "head_dim": ((),),
    "state": ((),),
    "conv": ((),),
    "layers": ((),),
    "expert_mlp": ((),),               # per-expert hidden (EP shards experts instead)
    "dt_rank": ((),),
    None: ((),),
}


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve_dim(logical: Optional[str], size: int, mesh: Mesh, rules) -> Tuple[str, ...]:
    """Pick the first rule candidate whose mesh-axis product divides `size`."""
    candidates = rules.get(logical, ((),))
    sizes = _mesh_axis_sizes(mesh)
    for cand in candidates:
        axes = tuple(a for a in cand if a in sizes)
        if not axes:
            if cand == ():
                return ()
            continue
        prod = math.prod(sizes[a] for a in axes)
        if prod > 0 and size % prod == 0:
            return axes
    return ()


def logical_to_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                    mesh: Mesh, rules=None) -> P:
    """Resolve logical axes (one per dim) into a PartitionSpec for `mesh`."""
    rules = rules or DEFAULT_RULES
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    spec = []
    for logical, size in zip(axes, shape):
        resolved = _resolve_dim(logical, size, mesh, rules)
        # a mesh axis may appear at most once in a PartitionSpec
        resolved = tuple(a for a in resolved if a not in used)
        if resolved:
            prod = math.prod(_mesh_axis_sizes(mesh)[a] for a in resolved)
            if size % prod != 0:
                resolved = ()
        used.update(resolved)
        if len(resolved) == 0:
            spec.append(None)
        elif len(resolved) == 1:
            spec.append(resolved[0])
        else:
            spec.append(tuple(resolved))
    return P(*spec)


def named_sharding(axes, shape, mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))


def input_sharding(mesh: Mesh, *axes, shape=None, rules=None) -> NamedSharding:
    """Sharding for step inputs, e.g. input_sharding(mesh, "batch", "seq")."""
    if shape is None:
        # divisibility unknown -> assume divisible (inputs are sized to mesh)
        shape = tuple(10 ** 9 if a is not None else 1 for a in axes)
        # 1e9 divisible by any pod/data/model size in use (powers of two)
        shape = tuple(2 ** 30 for _ in axes)
    return named_sharding(axes, shape, mesh, rules)
