from repro.distributed.sharding import (  # noqa: F401
    DEFAULT_RULES,
    logical_to_spec,
    named_sharding,
    input_sharding,
)
