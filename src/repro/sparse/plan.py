"""Plan stage of the sparse pruned-artifact runtime.

Turns the element-unstructured stage-2 masks (Wanda/OWL, ``core.
unstructured``) into a *hardware-skippable* layout for every expert FFN
matrix: a per-matrix block bitmap aligned to MXU tiles, plus the lossless
and lossy transforms that maximize dead-block yield:

  * **expert-mask folding** — STUN stage-1 keep-masks ([E] or [L, E])
    zero whole experts; folded in, every block of a pruned expert is dead
    (the dominant yield source for mask-form serving).
  * **row/column permutation** (lossless) — rows are sorted by occupancy
    per expert, columns likewise, so near-empty rows/columns cluster into
    fully-dead tiles.  Exact: the pack stage stores permuted blocks and
    the permutation; execute un-permutes (or gathers activations), so the
    computed product is unchanged.
  * **N:M re-rounding** (lossy, optional) — intersects the mask with a
    keep-top-n-of-every-m pattern along the input axis
    (``core.unstructured.nm_rounding``), the accelerator-friendly
    structure the paper's limitation section points at.
  * **block re-rounding** (lossy, optional, ``target_block_sparsity``) —
    OWL's insight at tile granularity: reallocate the element budget so
    dead weight *concentrates* into skippable blocks.  The cheapest live
    blocks (lowest surviving |W| score mass) are killed and, element for
    element, the highest-score pruned weights inside surviving blocks are
    revived — total nonzeros are preserved, so "40% total sparsity"
    still means 40%.

The plan's ``element_masks()`` are the masks the packed artifact actually
realizes — any dense-masked baseline (serving oracle, benchmarks) must
use them, which is what makes packed-vs-dense comparisons exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.unstructured import nm_rounding

FFN_PATHS = (("moe", "we_gate"), ("moe", "we_up"), ("moe", "we_down"))

_BLOCK_CANDIDATES = (128, 64, 32, 16, 8, 4, 2, 1)


def _auto_block_dim(n: int) -> int:
    for b in _BLOCK_CANDIDATES:
        if n % b == 0:
            return b
    return 1


@dataclasses.dataclass
class MatrixPlan:
    """Block-sparse layout decision for one [E, K, N] expert weight."""
    layer: int
    path: Tuple[str, ...]
    block: Tuple[int, int]           # (bk, bn)
    perm_k: np.ndarray               # [E, K] int32: packed row r <- perm_k[r]
    perm_n: np.ndarray               # [E, N] int32: packed col c <- perm_n[c]
    element_mask: np.ndarray         # [E, K, N] bool, ORIGINAL coordinates
    block_mask: np.ndarray           # [E, Kb, Nb] bool (permuted), True=live

    @property
    def n_blocks(self) -> int:
        return self.block_mask.size

    @property
    def n_live(self) -> int:
        return int(self.block_mask.sum())

    @property
    def block_sparsity(self) -> float:
        return 1.0 - self.n_live / max(self.n_blocks, 1)

    def permuted_mask(self) -> np.ndarray:
        """element_mask in packed (permuted) coordinates [E, K, N]."""
        return np.stack([self.element_mask[e][self.perm_k[e]]
                         [:, self.perm_n[e]]
                         for e in range(self.element_mask.shape[0])])


@dataclasses.dataclass
class SparsePlan:
    matrices: Dict[Tuple[int, Tuple[str, ...]], MatrixPlan]
    report: dict

    def element_masks(self) -> Dict:
        """Masks the packed artifact realizes — the dense-masked baseline
        (``ServeEngine(weight_masks=...)``) must use these for packed ==
        dense-masked equivalence to hold when lossy transforms ran."""
        return {key: mp.element_mask for key, mp in self.matrices.items()}


def _fold_expert_mask(mask: np.ndarray, expert_mask, layer: int
                      ) -> np.ndarray:
    em = np.asarray(expert_mask)
    if em.ndim == 2:
        em = em[layer]
    dead = em <= 0
    out = mask.copy()
    out[dead] = False
    return out


def _occupancy_perms(mask: np.ndarray):
    """Per-expert stable occupancy sort of rows and columns (ascending:
    emptiest first, so dead/near-dead lines cluster at the low corner)."""
    E = mask.shape[0]
    pk = np.stack([np.argsort(mask[e].sum(axis=1), kind="stable")
                   for e in range(E)]).astype(np.int32)
    pn = np.stack([np.argsort(mask[e].sum(axis=0), kind="stable")
                   for e in range(E)]).astype(np.int32)
    return pk, pn


def _to_blocks(a: np.ndarray, bk: int, bn: int) -> np.ndarray:
    """[E, K, N] -> [E, Kb, Nb, bk, bn]."""
    E, K, N = a.shape
    return a.reshape(E, K // bk, bk, N // bn, bn).transpose(0, 1, 3, 2, 4)


def _from_blocks(b: np.ndarray) -> np.ndarray:
    E, Kb, Nb, bk, bn = b.shape
    return b.transpose(0, 1, 3, 2, 4).reshape(E, Kb * bk, Nb * bn)


def _block_reround(mask_p: np.ndarray, score_p: np.ndarray, bk: int, bn: int,
                   target: float):
    """Kill the cheapest live blocks until ``target`` of all blocks are
    dead, reviving an equal number of top-score pruned elements inside
    surviving blocks (total nonzeros preserved).  Operates in permuted
    coordinates.  Returns (new mask_p, n_killed, n_revived)."""
    mb = _to_blocks(mask_p, bk, bn)                  # [E,Kb,Nb,bk,bn] bool
    sb = _to_blocks(score_p, bk, bn)
    E, Kb, Nb = mb.shape[:3]
    live = mb.any(axis=(3, 4))                       # [E,Kb,Nb]
    n_blocks = live.size
    n_dead = n_blocks - int(live.sum())
    n_need = int(np.ceil(target * n_blocks)) - n_dead
    if n_need <= 0:
        return mask_p, 0, 0
    kept_cost = np.where(mb, sb, 0.0).sum(axis=(3, 4))       # [E,Kb,Nb]
    flat_live = np.flatnonzero(live.reshape(-1))
    order = flat_live[np.argsort(kept_cost.reshape(-1)[flat_live],
                                 kind="stable")]
    # feasibility: revivals must fit in the pruned slots of blocks that
    # STAY live — shrink the kill set from the expensive end if not
    kill = order[:n_need]
    while len(kill) > 0:
        kill_mask = np.zeros(n_blocks, bool)
        kill_mask[kill] = True
        kill_b = kill_mask.reshape(E, Kb, Nb)
        n_revive = int(mb[kill_b].sum())
        stay = live & ~kill_b
        capacity = int((~mb[stay]).sum())
        if n_revive <= capacity:
            break
        kill = kill[:-1]
    else:
        return mask_p, 0, 0
    if len(kill) == 0:
        return mask_p, 0, 0
    # kill: drop every survivor in the killed blocks
    mb = mb.copy()
    mb[kill_b] = False
    # revive: top-score pruned elements within blocks that stay live
    stay_elems = np.broadcast_to(stay[..., None, None], mb.shape)
    cand = (~mb) & stay_elems
    cand_flat = np.flatnonzero(cand.reshape(-1))
    top = cand_flat[np.argsort(-sb.reshape(-1)[cand_flat],
                               kind="stable")[:n_revive]]
    mbf = mb.reshape(-1)
    mbf[top] = True
    mb = mbf.reshape(mb.shape)
    return _from_blocks(mb), len(kill), n_revive


def plan_sparse_ffn(masks: Dict, weights: Optional[Dict] = None, *,
                    block="auto", permute: bool = True,
                    nm: Optional[Tuple[int, int]] = None,
                    expert_mask=None,
                    target_block_sparsity: Optional[float] = None
                    ) -> SparsePlan:
    """Plan block-compressed storage for every expert FFN mask.

    Args:
      masks: ``{(layer, path) -> bool [E, K, N]}`` from
        ``core.unstructured.sparsify_model`` (non-FFN paths are ignored —
        attention masks stay dense-masked).
      weights: ``{(layer, path) -> ndarray}`` of the matching weights
        (see ``ffn_weights_from_params``) — required for ``nm`` and
        ``target_block_sparsity`` scoring, unused otherwise.
      block: ``(bk, bn)`` tile, or ``"auto"`` (largest power-of-two
        divisor <= 128 per dim — the MXU tile when shapes allow).
      permute: sort rows/columns by occupancy per expert (lossless).
      nm: ``(n, m)`` re-rounding along the input axis (lossy).
      expert_mask: stage-1 keep mask [E] or [L, E] folded into the
        element masks (mask-form serving: pruned experts become all-dead
        blocks).
      target_block_sparsity: dead-block fraction to reach per matrix via
        sparsity-preserving block re-rounding (lossy, see module doc).

    Returns a ``SparsePlan``; ``plan.report`` has per-layer and overall
    planned block sparsity plus a bytes estimate.
    """
    if nm is not None and weights is None:
        raise ValueError("nm re-rounding needs `weights` for scoring")
    if target_block_sparsity is not None and weights is None:
        raise ValueError("target_block_sparsity needs `weights` for scoring")
    matrices: Dict = {}
    per_layer: Dict[int, list] = {}
    killed = revived = 0
    for (layer, path), mask in sorted(masks.items(), key=lambda kv: (
            kv[0][0], kv[0][1])):
        if tuple(path) not in FFN_PATHS:
            continue
        m = np.asarray(mask, bool)
        E, K, N = m.shape
        if expert_mask is not None:
            m = _fold_expert_mask(m, expert_mask, layer)
        W = (np.abs(np.asarray(weights[(layer, path)], np.float32))
             if weights is not None else None)
        if nm is not None:
            score = np.where(m, W, -np.inf)
            m = m & nm_rounding(score, 1, *nm)
        bk, bn = ((_auto_block_dim(K), _auto_block_dim(N))
                  if block == "auto" else block)
        if K % bk or N % bn:
            raise ValueError(f"block ({bk},{bn}) does not divide "
                             f"{path} shape ({K},{N})")
        if permute:
            perm_k, perm_n = _occupancy_perms(m)
        else:
            perm_k = np.broadcast_to(np.arange(K, dtype=np.int32),
                                     (E, K)).copy()
            perm_n = np.broadcast_to(np.arange(N, dtype=np.int32),
                                     (E, N)).copy()
        mp = np.stack([m[e][perm_k[e]][:, perm_n[e]] for e in range(E)])
        if target_block_sparsity is not None:
            sp = np.stack([W[e][perm_k[e]][:, perm_n[e]] for e in range(E)])
            mp, nk, nr = _block_reround(mp, sp, bk, bn,
                                        target_block_sparsity)
            killed += nk
            revived += nr
        block_mask = _to_blocks(mp, bk, bn).any(axis=(3, 4))
        # back to original coordinates
        m_final = np.zeros_like(m)
        for e in range(E):
            m_final[e][np.ix_(perm_k[e], perm_n[e])] = mp[e]
        plan_m = MatrixPlan(layer, tuple(path), (bk, bn), perm_k, perm_n,
                            m_final, block_mask)
        matrices[(layer, tuple(path))] = plan_m
        per_layer.setdefault(layer, []).append(plan_m)

    layer_report = {
        l: {
            "n_blocks": sum(p.n_blocks for p in ps),
            "n_live": sum(p.n_live for p in ps),
            "block_sparsity": 1.0 - (sum(p.n_live for p in ps)
                                     / max(sum(p.n_blocks for p in ps), 1)),
        }
        for l, ps in sorted(per_layer.items())
    }
    n_blocks = sum(p.n_blocks for p in matrices.values())
    n_live = sum(p.n_live for p in matrices.values())
    report = {
        "per_layer": layer_report,
        "n_blocks": n_blocks,
        "n_live": n_live,
        "block_sparsity": 1.0 - n_live / max(n_blocks, 1),
        "element_sparsity": 1.0 - (
            sum(int(p.element_mask.sum()) for p in matrices.values())
            / max(sum(p.element_mask.size for p in matrices.values()), 1)),
        "blocks_rerounded": killed,
        "elements_revived": revived,
    }
    return SparsePlan(matrices, report)


def ffn_weights_from_params(params, cfg) -> Dict:
    """Extract ``{(layer, path) -> [E, K, N] ndarray}`` for plan scoring,
    handling both scan-stacked ([L, E, K, N]) and per-layer param trees."""
    out = {}
    stacked = cfg.family != "hybrid" and cfg.scan_layers
    for l in range(cfg.n_layers):
        tree = params["layers"] if stacked else params["layers"][str(l)]
        if "moe" not in tree:
            continue
        for path in FFN_PATHS:
            W = np.asarray(tree[path[0]][path[1]])
            out[(l, path)] = W[l] if stacked else W
    return out
