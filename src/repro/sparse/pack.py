"""Pack stage: realize a ``SparsePlan`` as block-compressed storage.

Paged-KV-for-weights: per (layer, matrix) the live blocks of every expert
drop into one ``[n_slots, bk, bn]`` pool — slot 0 is an all-zero sentinel
— and a per-expert ``[Kb, Nb]`` int32 index maps logical blocks to slots
(0 = dead).  A φ-block-sparse expert FFN therefore *loads* at
~(1 - φ_block) of its dense bytes; dead blocks have no storage at all,
exactly like unreserved pages in the paged KV cache.

Artifact layout (plain dict of arrays — checkpoint- and scan-friendly):

  scan-stacked model (``cfg.scan_layers``)::

      packed = {"we_gate": {"pool":   [L, S, bk, bn]  (weight dtype),
                            "index":  [L, E, Kb, Nb]  int32,
                            "perm_k": [L, E, K]       int32,
                            "perm_n": [L, E, N]       int32},
                "we_up": ..., "we_down": ...}

  per-layer model::

      packed = {"0": {"we_gate": {... same, no leading L ...}}, "1": ...}

Layer pools are zero-padded to the deepest layer's slot count so the
stacked leaves scan cleanly; padding slots are never referenced by any
index.  ``install_sparse_ffn`` substitutes these entries for the dense
``we_*`` leaves of a param tree (adding host-precomputed inverse
permutations and slot coordinate maps the execute stage needs), and the
model's forward/prefill/decode/verify paths consume them transparently —
the packed entry is a pytree, so ``lax.scan`` slices its leading layer
axis just like a dense weight.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.sparse.plan import FFN_PATHS, SparsePlan

ARTIFACT_KEYS = ("pool", "index", "perm_k", "perm_n")


def _is_stacked(cfg) -> bool:
    return cfg.family != "hybrid" and cfg.scan_layers


def _pack_matrix(W: np.ndarray, mp) -> Tuple[np.ndarray, np.ndarray]:
    """W [E, K, N] + MatrixPlan -> (pool [1+n_live, bk, bn], index
    [E, Kb, Nb] int32).  Blocks are stored in permuted coordinates with
    the planned mask applied, enumerated in (e, kb, nb) order.
    Vectorized — at real checkpoint scale this runs per (layer, matrix)
    over millions of blocks."""
    E, K, N = W.shape
    bk, bn = mp.block
    Kb, Nb = K // bk, N // bn
    wp = np.take_along_axis(W, mp.perm_k[:, :, None], axis=1)
    wp = np.take_along_axis(wp, mp.perm_n[:, None, :], axis=2)
    wp = wp * mp.permuted_mask().astype(W.dtype)
    blocks = wp.reshape(E, Kb, bk, Nb, bn).transpose(0, 1, 3, 2, 4)
    live = mp.block_mask                                  # [E, Kb, Nb]
    index = np.zeros((E, Kb, Nb), np.int32)
    index[live] = np.arange(1, int(live.sum()) + 1, dtype=np.int32)
    pool = np.concatenate([np.zeros((1, bk, bn), W.dtype), blocks[live]])
    return pool, index


def pack_sparse_ffn(params, cfg, plan: SparsePlan) -> Tuple[Dict, Dict]:
    """Pack every planned expert FFN matrix of ``params``.

    Returns ``(packed, report)``: the artifact dict described in the
    module docstring, and a report with ``dense_bytes`` /
    ``packed_bytes`` / ``bytes_ratio`` plus the plan's block-sparsity
    numbers.  Raises if the plan does not cover every (layer, FFN path)
    of the model — stacked storage cannot mix packed and dense layers.
    """
    stacked = _is_stacked(cfg)
    L = cfg.n_layers
    for l in range(L):
        for path in FFN_PATHS:
            if (l, path) not in plan.matrices:
                raise ValueError(f"plan is missing layer {l} {path}")

    dense_bytes = 0
    per_path: Dict[str, list] = {}
    for path in FFN_PATHS:
        name = path[1]
        for l in range(L):
            tree = (params["layers"] if stacked
                    else params["layers"][str(l)])
            W = np.asarray(tree[path[0]][path[1]])
            if stacked:
                W = W[l]
            dense_bytes += W.nbytes
            mp = plan.matrices[(l, path)]
            pool, index = _pack_matrix(W, mp)
            per_path.setdefault(name, []).append(
                {"pool": pool, "index": index,
                 "perm_k": mp.perm_k.astype(np.int32),
                 "perm_n": mp.perm_n.astype(np.int32)})

    if stacked:
        packed: Dict = {}
        for name, entries in per_path.items():
            S = max(e["pool"].shape[0] for e in entries)
            pools = [np.concatenate(
                [e["pool"],
                 np.zeros((S - e["pool"].shape[0],) + e["pool"].shape[1:],
                          e["pool"].dtype)]) for e in entries]
            packed[name] = {
                "pool": np.stack(pools),
                "index": np.stack([e["index"] for e in entries]),
                "perm_k": np.stack([e["perm_k"] for e in entries]),
                "perm_n": np.stack([e["perm_n"] for e in entries]),
            }
    else:
        packed = {str(l): {name: entries[l]
                           for name, entries in per_path.items()}
                  for l in range(L)}

    packed_bytes = sparse_ffn_bytes(packed)
    report = {
        "dense_bytes": int(dense_bytes),
        "packed_bytes": int(packed_bytes),
        "bytes_ratio": packed_bytes / max(dense_bytes, 1),
        **plan.report,
    }
    return packed, report


def sparse_ffn_bytes(packed: Dict) -> int:
    """Bytes of the stored artifact (pool + index + permutations)."""
    total = 0
    for sub in packed.values():
        entries = sub.values() if "pool" not in sub else [sub]
        for e in entries:
            total += sum(np.asarray(e[k]).nbytes for k in ARTIFACT_KEYS)
    return total


def _alive_experts(index: np.ndarray) -> np.ndarray:
    """Experts that still own at least one live block (index row != 0)."""
    return np.flatnonzero((np.asarray(index) > 0).any(axis=(1, 2))
                          ).astype(np.int32)


def _is_identity_perm(perm: np.ndarray) -> bool:
    perm = np.asarray(perm)
    return np.array_equal(perm, np.broadcast_to(
        np.arange(perm.shape[-1], dtype=perm.dtype), perm.shape))


def _runtime_entry(entry: Dict, n_alive: Optional[int] = None,
                   keep_perms: Optional[Dict[str, bool]] = None) -> Dict:
    """Artifact entry (one layer) -> execute-ready entry: device arrays
    plus host-precomputed inverse permutations and the slot -> (alive
    expert, kb, nb) coordinate maps the FLOP-skipping gather path uses.
    Derived arrays are recomputed at install, so the stored artifact
    stays minimal.

    Two static (pytree-structure) specializations, so jit traces the
    cheap path without runtime branches:

      * identity permutations are dropped entirely (the common case
        when the plan ran with ``permute=False``).  ``keep_perms``
        overrides the per-layer decision: stacked callers pass the OR
        over all layers, because key presence is pytree structure and
        must be layer-uniform — a layer whose permutation happens to be
        identity still stores it when any sibling layer's is not;
      * with ``n_alive`` set, fully-dead experts (STUN stage-1 in mask
        form) are stripped — only alive experts' index/permutation rows
        are kept, plus the ``alive_e`` scatter map, so their FLOPs are
        skipped in every execute mode.  Rows past the layer's alive
        count are padded with an all-dead index (exact-zero product) and
        the out-of-range expert id (scatter-dropped), which keeps
        stacked layers with different alive sets scannable.
    """
    index = np.asarray(entry["index"])                # [E, Kb, Nb]
    E, Kb, Nb = index.shape
    S = int(np.asarray(entry["pool"]).shape[0])
    alive = _alive_experts(index)
    strip = n_alive is not None
    if strip:
        pad = n_alive - len(alive)
        assert pad >= 0, (n_alive, alive)
        alive_pad = np.concatenate([alive, np.full(pad, E, np.int32)])
        index_rt = np.concatenate(
            [index[alive], np.zeros((pad, Kb, Nb), np.int32)])
    else:
        index_rt = index
    # slot maps address the RUNTIME expert axis (alive position)
    pos = np.zeros(E, np.int32)
    pos[alive] = np.arange(len(alive), dtype=np.int32)
    slot_e = np.zeros(S, np.int32)
    slot_kb = np.zeros(S, np.int32)
    slot_nb = np.zeros(S, np.int32)
    e_i, kb_i, nb_i = np.nonzero(index > 0)
    slots = index[e_i, kb_i, nb_i]
    slot_e[slots] = pos[e_i] if strip else e_i
    slot_kb[slots] = kb_i
    slot_nb[slots] = nb_i
    out = {
        "pool": jnp.asarray(entry["pool"]),
        "index": jnp.asarray(index_rt),
        "slot_e": jnp.asarray(slot_e),
        "slot_kb": jnp.asarray(slot_kb),
        "slot_nb": jnp.asarray(slot_nb),
    }
    if strip:
        out["alive_e"] = jnp.asarray(alive_pad)
    for ax in ("k", "n"):
        perm = np.asarray(entry[f"perm_{ax}"])
        dim = perm.shape[-1]
        keep = (keep_perms[ax] if keep_perms is not None
                else not _is_identity_perm(perm))
        if not keep:
            continue
        if strip:
            perm = np.concatenate(
                [perm[alive],
                 np.broadcast_to(np.arange(dim, dtype=perm.dtype),
                                 (n_alive - len(alive), dim))])
        out[f"perm_{ax}"] = jnp.asarray(perm)
        out[f"inv_perm_{ax}"] = jnp.asarray(
            np.argsort(perm, axis=-1).astype(np.int32))
    return out


def install_sparse_ffn(params, cfg, packed: Dict):
    """Substitute packed entries for the dense ``we_*`` leaves.

    Returns a new param tree whose expert FFN weights are the execute-
    ready packed entries (dicts — valid pytree leaves-of-subtrees, so
    every model path that scans or indexes ``params["layers"]`` keeps
    working unchanged).  The dense router / shared-expert / attention
    weights are untouched.
    """
    stacked = _is_stacked(cfg)
    if stacked:
        stacked_rt: Dict[str, Dict] = {}
        for name, entry in packed.items():
            index = np.asarray(entry["index"])
            L, E = index.shape[:2]
            n_alive = max(max(len(_alive_experts(index[l]))
                              for l in range(L)), 1)
            # strip dead experts only when some layer actually has one,
            # and keep a permutation axis if ANY layer's is non-identity
            # (key presence is pytree structure — must be layer-uniform)
            n_alive = None if n_alive == E else n_alive
            keep_perms = {
                ax: any(not _is_identity_perm(
                    np.asarray(entry[f"perm_{ax}"])[l]) for l in range(L))
                for ax in ("k", "n")}
            per_layer = [
                _runtime_entry({k: np.asarray(entry[k])[l]
                                for k in ARTIFACT_KEYS}, n_alive,
                               keep_perms)
                for l in range(L)]
            stacked_rt[name] = {
                k: jnp.stack([p[k] for p in per_layer])
                for k in per_layer[0]}
        moe = {**params["layers"]["moe"], **stacked_rt}
        return {**params, "layers": {**params["layers"], "moe": moe}}
    layers = dict(params["layers"])
    for l_str, sub in packed.items():
        moe = {**layers[l_str]["moe"], **{
            name: _runtime_entry(
                entry,
                (lambda a, e: None if a == e else a)(
                    max(len(_alive_experts(entry["index"])), 1),
                    np.asarray(entry["index"]).shape[0]))
            for name, entry in sub.items()}}
        layers[l_str] = {**layers[l_str], "moe": moe}
    return {**params, "layers": layers}
