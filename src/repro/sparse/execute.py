"""Execute stage: expert FFN matmuls straight from block-compressed
weights.

``expert_einsum`` is the single entry point ``models.moe`` dispatches
through whenever a weight leaf is a packed entry (see ``pack``).  The
runtime entry stores only the experts that still own live blocks: a
fully-dead expert (STUN stage-1 in mask form) is *absent* — its compute
is skipped in every mode and its output rows are exact zeros scattered
through the ``alive_e`` map, which is what the dense-masked path also
produces for an all-zero weight (bitwise: x @ 0 == 0).

Modes:

  * ``"pallas"`` (TPU default) / ``"interpret"`` — per-alive-expert
    dispatch through ``kernels.block_sparse_matmul.
    block_sparse_gather_matmul``: the scalar-prefetched block index
    gathers live blocks out of the pool and skips dead ones entirely (no
    bytes, no MXU dots).  Activations are gathered through ``perm_k``
    before the kernel and un-permuted through ``inv_perm_n`` after, so
    permutation costs two cheap gathers on activations, never a weight
    materialization.
  * ``"exact"`` (CPU default) — unpacks the pool to the dense masked
    matrices of the alive experts (gather + transpose + inverse
    permutation: pure data movement, no arithmetic) and replays the
    *identical* einsum the dense path runs, restricted to alive experts.
    Packed serving is therefore bit-identical to dense-masked serving
    (the property the serving oracle pins) while skipping the dead
    experts' FLOPs.
  * ``"gather"`` — FLOP-proportional jnp path: per live pool slot, the
    matching activation tile multiplies its block and scatter-adds into
    the output (compute scales with live blocks, like the kernel).
    Numerically allclose, not bit-equal (different reduction order).

The mode comes from ``force`` (or ``cfg.sparse_exec`` via the model),
else the backend default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

#: entry marker — a packed weight is a dict with these keys (see pack.py)
_PACKED_KEY = "pool"

#: einsum specs models.moe dispatches: (x layout, w layout) -> out layout
SUPPORTED_SPECS = ("bsd,edf->bsef", "gecd,edf->gecf",
                   "bsef,efd->bsed", "gecf,efd->gecd")


def is_packed(w) -> bool:
    return isinstance(w, dict) and _PACKED_KEY in w


def densify(entry):
    """Packed entry (one layer) -> dense [A, K, N] in original
    coordinates for the A stored (alive) experts, elementwise equal to
    ``W * element_mask``.  Gathers and transposes only — no arithmetic —
    so feeding the result to the dense einsum reproduces dense-masked
    serving bit for bit."""
    pool, index = entry["pool"], entry["index"]
    A, Kb, Nb = index.shape
    bk, bn = pool.shape[-2], pool.shape[-1]
    blocks = pool[index]                              # [A, Kb, Nb, bk, bn]
    w = blocks.transpose(0, 1, 3, 2, 4).reshape(A, Kb * bk, Nb * bn)
    if "inv_perm_n" in entry:
        w = jnp.take_along_axis(w, entry["inv_perm_n"][:, None, :], axis=2)
    if "inv_perm_k" in entry:
        w = jnp.take_along_axis(w, entry["inv_perm_k"][:, :, None], axis=1)
    return w


def densify_full(entry, n_experts: int):
    """Like ``densify`` but scattered back to all ``n_experts`` rows
    (zeros for dead experts) — the exact operand dense-masked serving
    multiplies with.  Debug/oracle helper; execute paths never build
    it."""
    w = densify(entry)
    if "alive_e" not in entry:
        return w
    full = jnp.zeros((n_experts,) + w.shape[1:], w.dtype)
    return full.at[entry["alive_e"]].set(w)


def _default_mode() -> str:
    return "pallas" if ops.on_tpu() else "exact"


def _gather_matmul(xA, entry):
    """FLOP-skipping jnp path: xA [A, M, K] (already perm_k-gathered,
    permuted coords) -> y [A, M, N] (permuted coords), fp32 accumulate.
    Work scales with pool slots: slot s multiplies activation tile
    (slot_e[s], slot_kb[s]) by pool[s] and scatter-adds at slot_nb[s];
    the sentinel slot 0 contributes exact zeros."""
    pool = entry["pool"]
    A, M, K = xA.shape
    S, bk, bn = pool.shape
    Kb = K // bk
    Nb = entry["index"].shape[-1]
    xt = xA.reshape(A, M, Kb, bk).transpose(0, 2, 1, 3)    # [A, Kb, M, bk]
    xg = xt[entry["slot_e"], entry["slot_kb"]]             # [S, M, bk]
    yb = jnp.einsum("smk,skn->smn", xg.astype(jnp.float32),
                    pool.astype(jnp.float32))
    acc = jnp.zeros((A, Nb, M, bn), jnp.float32)
    acc = acc.at[entry["slot_e"], entry["slot_nb"]].add(yb)
    return acc.transpose(0, 2, 1, 3).reshape(A, M, Nb * bn)


def _kernel_matmul(xA, entry, mode):
    """Per-alive-expert dispatch through the Pallas gather kernel (or
    its interpreter).  xA [A, M, K] in permuted coords -> [A, M, N]."""
    A = xA.shape[0]
    return jnp.stack([
        ops.sparse_gather_matmul_op(xA[e], entry["pool"],
                                    entry["index"][e], force=mode)
        for e in range(A)])


def _resolve_n_experts(spec, x, entry, n_experts):
    if n_experts is not None:
        return n_experts
    if spec in ("gecd,edf->gecf", "gecf,efd->gecd"):
        return x.shape[1]
    if spec == "bsef,efd->bsed":
        return x.shape[2]
    if "alive_e" in entry:                       # "bsd" carries no E
        raise ValueError("expert_einsum needs n_experts= for spec "
                         f"{spec!r} when dead experts were stripped")
    return entry["index"].shape[0]


def expert_einsum(spec: str, x, entry, *, n_experts=None, force=None):
    """Contract activations with a packed expert FFN weight.

    ``spec`` must be one of ``SUPPORTED_SPECS`` — the exact einsums
    ``models.moe`` uses, so the ``"exact"`` mode can replay them verbatim
    on the densified operand.  ``entry`` is one layer's packed entry
    (leading layer axis already sliced off by ``lax.scan`` or indexing);
    ``n_experts`` is the model's expert count (``cfg.n_experts``) —
    required for the ``"bsd,..."`` spec when the entry stripped dead
    experts, derivable from ``x`` otherwise.  Entries whose ``alive_e``
    holds the out-of-range sentinel in padded rows rely on jax scatter
    semantics (out-of-bounds updates are dropped) and on those rows'
    all-dead block index (their product is exactly zero).
    """
    if spec not in SUPPORTED_SPECS:
        raise ValueError(f"unsupported packed einsum {spec!r}; "
                         f"known: {SUPPORTED_SPECS}")
    mode = force or _default_mode()
    E = _resolve_n_experts(spec, x, entry, n_experts)
    alive = entry.get("alive_e")                 # None -> all E stored

    if mode in ("exact", "ref"):
        w = densify(entry)                       # [A, K, N]
        if alive is None:
            return jnp.einsum(spec, x, w)
        if spec == "bsd,edf->bsef":
            ya = jnp.einsum(spec, x, w)          # [B, S, A, F]
            B, S = x.shape[:2]
            out = jnp.zeros((B, S, E, ya.shape[-1]), ya.dtype)
            return out.at[:, :, alive].set(ya)
        if spec == "bsef,efd->bsed":
            ya = jnp.einsum(spec, x[:, :, alive], w)
            B, S = x.shape[:2]
            out = jnp.zeros((B, S, E, ya.shape[-1]), ya.dtype)
            return out.at[:, :, alive].set(ya)
        # "gecd,edf->gecf" / "gecf,efd->gecd"
        ya = jnp.einsum(spec, x[:, alive], w)
        G, _, C = x.shape[:3]
        out = jnp.zeros((G, E, C, ya.shape[-1]), ya.dtype)
        return out.at[:, alive].set(ya)

    A = entry["index"].shape[0]
    # normalize x to [A, M, K] and remember how to restore the output
    if spec == "bsd,edf->bsef":
        B, S, D = x.shape
        xA = jnp.broadcast_to(x.reshape(1, B * S, D), (A, B * S, D))
        restore = lambda y: y.transpose(1, 0, 2).reshape(  # noqa: E731
            B, S, E, -1)
    elif spec == "bsef,efd->bsed":
        B, S = x.shape[:2]
        xT = x.transpose(2, 0, 1, 3)
        xA = (xT if alive is None else xT[alive]).reshape(A, B * S, -1)
        restore = lambda y: y.transpose(1, 0, 2).reshape(  # noqa: E731
            B, S, E, -1)
    else:  # "gecd,edf->gecf" / "gecf,efd->gecd"
        G, _, C = x.shape[:3]
        xT = x.transpose(1, 0, 2, 3)
        xA = (xT if alive is None else xT[alive]).reshape(A, G * C, -1)
        restore = lambda y: y.reshape(E, G, C, -1).transpose(  # noqa: E731
            1, 0, 2, 3)

    # activations into packed row coordinates (x column k multiplies
    # original weight row k; packed row r holds original row perm_k[r])
    if "perm_k" in entry:
        xA = jnp.take_along_axis(xA, entry["perm_k"][:, None, :], axis=2)
    if mode == "gather":
        y = _gather_matmul(xA, entry)
    elif mode in ("pallas", "interpret"):
        y = _kernel_matmul(xA, entry, mode)
    else:
        raise ValueError(f"unknown sparse exec mode {mode!r}")
    # outputs back to original column coordinates, dead experts to zeros
    if "inv_perm_n" in entry:
        y = jnp.take_along_axis(y, entry["inv_perm_n"][:, None, :], axis=2)
    y = y.astype(x.dtype)
    if alive is not None:
        full = jnp.zeros((E,) + y.shape[1:], y.dtype)
        y = full.at[alive].set(y)
    return restore(y)


def maybe_expert_einsum(spec: str, x, w, *, n_experts=None, force=None):
    """Dense or packed: one call site for models.moe."""
    if is_packed(w):
        return expert_einsum(spec, x, w, n_experts=n_experts, force=force)
    return jnp.einsum(spec, x, w)


def sparse_exec_force(cfg):
    """Model-config override for the execute mode ('' -> backend
    default)."""
    mode = getattr(cfg, "sparse_exec", "")
    return mode or None
