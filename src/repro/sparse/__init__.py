"""Sparse pruned-artifact runtime: plan -> pack -> execute.

Bridges ``core`` (mask production) and ``serving`` (mask consumption):
stage-2 unstructured masks become block-compressed weights that are
*physically smaller* and execute through the Pallas block-sparse path.
See docs/sparse.md for the artifact format and contracts.
"""
from repro.sparse.execute import (  # noqa: F401
    densify,
    densify_full,
    expert_einsum,
    is_packed,
    maybe_expert_einsum,
)
from repro.sparse.pack import (  # noqa: F401
    install_sparse_ffn,
    pack_sparse_ffn,
    sparse_ffn_bytes,
)
from repro.sparse.plan import (  # noqa: F401
    FFN_PATHS,
    MatrixPlan,
    SparsePlan,
    ffn_weights_from_params,
    plan_sparse_ffn,
)
