from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
)
from repro.optim.compress import (  # noqa: F401
    compress_decompress,
    compression_init,
)
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
