"""LR schedules (pure scalar functions of step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int):
    return jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine_schedule(step, total_steps: int, warmup_steps: int = 0,
                    min_ratio: float = 0.1):
    warm = linear_warmup(step, warmup_steps)
    frac = jnp.clip((step - warmup_steps) /
                    max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * (min_ratio + (1 - min_ratio) * cos)
