"""AdamW with bf16 params / fp32 moments, global-norm clipping.

Hand-rolled (no optax in the container).  Moments are stored fp32 and
sharded identically to their parameters (jax.tree.map preserves structure,
pjit propagates shardings), so optimizer memory scales with FSDP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0
                 ) -> Tuple[Any, Any, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"],
                     grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
