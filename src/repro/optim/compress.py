"""Int8 gradient compression with error feedback (DP all-reduce trick).

At 1000+-node scale the cross-pod gradient all-reduce dominates step time
for small models; int8 quantization cuts that payload 4× (vs fp32) / 2×
(vs bf16).  Error feedback accumulates the quantization residual locally
and re-injects it next step, keeping the long-run update unbiased
(Seide et al. 2014; Karimireddy et al. 2019).

Applied around the pod-axis reduction: compress -> all-reduce int8* ->
decompress.  (*XLA reduces in the compute dtype; in deployment this runs
inside a shard_map over the "pod" axis — see launch/train.py.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compression_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant_dequant(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, error_state):
    """Returns (dequantized grads, new error state)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        deq = _quant_dequant(g32)
        return deq, g32 - deq
    flat = jax.tree.map(one, grads, error_state)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, err
