"""Jitted train/eval/serve step builders with explicit shardings."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(cfg, opt_cfg: AdamWConfig, total_steps: int = 10_000,
                    warmup: int = 100, mesh=None, compress: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    When `compress` is on, int8 error-feedback compression wraps the
    gradients before the optimizer (the DP-reduce payload analogue; see
    optim/compress.py).  The error buffer lives in opt_state["err"].
    """
    from repro.optim.compress import compress_decompress

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, mesh=mesh))(params)
        # pin the DP gradient all-reduce to the gradient dtype (bf16): the
        # optimizer's astype(f32) would otherwise be hoisted into the psum
        # by XLA's excess-precision pass, doubling the dominant collective
        # payload (deepseek-67b: measured 2x — EXPERIMENTS.md §Perf)
        grads = jax.lax.optimization_barrier(grads)
        if compress:
            grads, err = compress_decompress(grads, opt_state["err"])
        lr_scale = cosine_schedule(opt_state["adam"]["step"], total_steps,
                                   warmup)
        new_params, new_adam, om = adamw_update(params, grads,
                                                opt_state["adam"], opt_cfg,
                                                lr_scale)
        new_opt = {"adam": new_adam}
        if compress:
            new_opt["err"] = err
        elif "err" in opt_state:
            new_opt["err"] = opt_state["err"]
        metrics = {"loss": loss, **om}
        # NaN guard: skip the update if loss or grads went non-finite
        ok = jnp.isfinite(loss) & jnp.isfinite(om["grad_norm"])
        new_params = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_params, params)
        metrics["skipped_nonfinite"] = (~ok).astype(jnp.int32)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg, mesh=None):
    @jax.jit
    def eval_step(params, batch):
        return loss_fn(params, cfg, batch, mesh=mesh)
    return eval_step
