from repro.runtime.train_loop import TrainLoopConfig, train_loop  # noqa: F401
from repro.runtime.step import make_train_step  # noqa: F401
