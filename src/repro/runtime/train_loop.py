"""Fault-tolerant training loop (DESIGN.md §8).

Scale features exercised here and relied on at 1000+ nodes:
  * checkpoint every K steps (async writer, atomic publish, keep-last-k);
  * restore-on-start, tolerant of a different mesh (elastic restart: the
    checkpoint stores numpy, `device_put` re-shards onto the live mesh);
  * per-step retry on transient XlaRuntimeError (flaky host / preempted
    core), NaN-loss skip (inside the jitted step), straggler watchdog
    (steps exceeding `deadline × median` are logged and counted — the
    multi-host deployment hooks a reschedule here);
  * SIGTERM -> synchronous final checkpoint (preemption grace window).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.step import make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    max_step_retries: int = 3
    straggler_deadline: float = 3.0     # × median step time
    warmup_steps: int = 10
    compress_grads: bool = False


def train_loop(cfg, params, batches: Iterator, loop_cfg: TrainLoopConfig,
               opt_cfg: AdamWConfig = AdamWConfig(), mesh=None,
               log_fn: Callable = print):
    """Runs the loop; returns (params, opt_state, history)."""
    opt_state = {"adam": adamw_init(params)}
    if loop_cfg.compress_grads:
        from repro.optim.compress import compression_init
        opt_state["err"] = compression_init(params)

    start = 0
    ckpt = None
    if loop_cfg.checkpoint_dir:
        ckpt = AsyncCheckpointer(loop_cfg.checkpoint_dir)
        if latest_step(loop_cfg.checkpoint_dir) is not None:
            start, tree = restore_checkpoint(loop_cfg.checkpoint_dir)
            params = jax.tree.map(
                lambda old, new: jax.numpy.asarray(new, old.dtype),
                params, tree["params"])
            opt_state = jax.tree.map(jax.numpy.asarray, tree["opt"])
            opt_state["adam"]["step"] = jax.numpy.asarray(
                tree["opt"]["adam"]["step"])
            log_fn(f"[restore] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, loop_cfg.total_steps,
                                      loop_cfg.warmup_steps, mesh=mesh,
                                      compress=loop_cfg.compress_grads))

    # preemption: first SIGTERM triggers a final checkpoint + clean exit
    preempted = {"flag": False}

    def _sigterm(signum, frame):
        preempted["flag"] = True
    old_handler = signal.signal(signal.SIGTERM, _sigterm)

    history = []
    step_times = []
    stragglers = 0
    try:
        for step in range(start, loop_cfg.total_steps):
            batch = next(batches)
            t0 = time.monotonic()
            for attempt in range(loop_cfg.max_step_retries):
                try:
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    break
                except jax.errors.JaxRuntimeError as e:  # transient failure
                    log_fn(f"[retry] step {step} attempt {attempt}: {e}")
                    if attempt == loop_cfg.max_step_retries - 1:
                        raise
            dt = time.monotonic() - t0
            step_times.append(dt)
            med = float(np.median(step_times[-50:]))
            if len(step_times) > 5 and dt > loop_cfg.straggler_deadline * med:
                stragglers += 1
                log_fn(f"[straggler] step {step} took {dt:.3f}s "
                       f"(median {med:.3f}s)")
            history.append({"step": step, **metrics, "time": dt})
            if step % loop_cfg.log_every == 0:
                log_fn(f"step {step}: loss={metrics['loss']:.4f} "
                       f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.1f}ms")
            if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
            if preempted["flag"]:
                log_fn(f"[preempt] SIGTERM at step {step}; checkpointing")
                break
        if ckpt and history:
            ckpt.wait()
            final_step = history[-1]["step"] + 1
            if latest_step(loop_cfg.checkpoint_dir) != final_step:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(loop_cfg.checkpoint_dir, final_step,
                                {"params": jax.tree.map(np.asarray, params),
                                 "opt": jax.tree.map(np.asarray, opt_state)})
    finally:
        signal.signal(signal.SIGTERM, old_handler)
    return params, opt_state, {"history": history, "stragglers": stragglers}
