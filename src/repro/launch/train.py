"""Production training launcher.

    python -m repro.launch.train --arch olmoe-1b-7b --steps 100 \
        --batch 256 --seq 4096 --mesh pod --checkpoint-dir /ckpt

On this CPU container use --local (1×1 mesh) with a reduced config
(--reduced); on hardware the same script drives the 16×16 / 2×16×16 mesh.
XLA latency-hiding-scheduler flags are set for collective/compute overlap
(the multi-pod DP all-reduce hides under the backward pass).
"""
import os

# collective/compute overlap on real backends (harmless on CPU)
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    if os.environ.get("REPRO_TPU") else "")

import argparse  # noqa: E402
import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_config, reduced  # noqa: E402
from repro.data.synthetic import batch_iterator  # noqa: E402
from repro.distributed.sharding import named_sharding  # noqa: E402
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.models import abstract_params  # noqa: E402
from repro.models import param as pm  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.runtime import TrainLoopConfig, train_loop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "pod", "multipod"])
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32",
                                  remat_policy="full", moe_impl="dense")
    mesh = (make_local_mesh() if args.mesh == "local" else
            make_production_mesh(multi_pod=(args.mesh == "multipod")))

    with mesh:
        ab = abstract_params(cfg)
        params = pm.init_params(ab, jax.random.PRNGKey(0))
        if args.reduced:
            params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, named_sharding(
                s.axes, s.shape, mesh)), params, ab,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
        it = batch_iterator(cfg, args.batch, args.seq, seed=0)
        lc = TrainLoopConfig(total_steps=args.steps,
                             checkpoint_every=args.checkpoint_every,
                             checkpoint_dir=args.checkpoint_dir,
                             compress_grads=args.compress_grads)
        params, _, hist = train_loop(cfg, params, it, lc,
                                     AdamWConfig(lr=args.lr), mesh=mesh)
    print(f"done: final loss {hist['history'][-1]['loss']:.4f}, "
          f"{hist['stragglers']} straggler steps flagged")


if __name__ == "__main__":
    main()
