"""Offline STUN pruning CLI: checkpoint in -> pruned checkpoint out.

    python -m repro.launch.prune --arch olmoe-1b-7b \
        --checkpoint-dir /ckpt/in --out-dir /ckpt/pruned \
        --sparsity 0.4 --expert-ratio 0.25 --unstructured owl

Mirrors the paper's deployment recipe: the whole decision is host-side
(router weights only for λ=(1,0)) — one machine, no accelerator required,
O(1) in the number of experts.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.core import stun_prune
from repro.data.synthetic import calibration_batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--checkpoint-dir", required=True)
    ap.add_argument("--out-dir", required=True)
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--expert-ratio", type=float, default=0.25)
    ap.add_argument("--unstructured", default="owl",
                    choices=["owl", "wanda", "magnitude"])
    ap.add_argument("--lam2", type=float, default=0.0,
                    help="coactivation weight (0 = no forward passes)")
    ap.add_argument("--kappa", type=int, default=3)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32",
                                  moe_impl="dense", remat_policy="full")
    step, tree = restore_checkpoint(args.checkpoint_dir)
    params = jax.tree.map(jax.numpy.asarray, tree["params"])
    batches = calibration_batches(cfg, n_batches=4)
    structured = args.expert_ratio if cfg.family == "moe" else 0.05
    pruned, pcfg, masks, report = stun_prune(
        params, cfg, batches, target_sparsity=args.sparsity,
        expert_ratio=structured, unstructured=args.unstructured,
        lam2=args.lam2, kappa=args.kappa)
    save_checkpoint(args.out_dir, step,
                    {"params": jax.tree.map(np.asarray, pruned)})
    print(f"pruned checkpoint written to {args.out_dir}")
    print(f"  structured: {report.structured_ratio:.1%}  "
          f"unstructured: {report.unstructured_ratio:.1%}  "
          f"forward passes: {report.forward_passes}")
    if pcfg.n_experts != cfg.n_experts:
        print(f"  experts: {cfg.n_experts} -> {pcfg.n_experts} "
              f"(update serving config accordingly)")


if __name__ == "__main__":
    main()
