"""Offline STUN pruning CLI: checkpoint in -> pruned checkpoint out.

    python -m repro.launch.prune --arch olmoe-1b-7b \
        --checkpoint-dir /ckpt/in --out-dir /ckpt/pruned \
        --sparsity 0.4 --expert-ratio 0.25 --unstructured owl --pack

Mirrors the paper's deployment recipe: the whole decision is host-side
(router weights only for λ=(1,0)) — one machine, no accelerator required,
O(1) in the number of experts.

The output checkpoint always carries the stage-2 ``masks`` subtree (see
``checkpoint.sparse_artifact``) so pruning runs are resumable and
inspectable without recomputing Wanda/OWL scores.  ``--pack``
additionally plans + packs the expert FFN masks into the block-compressed
``sparse_ffn`` artifact (``repro.sparse``), served directly via
``launch.serve --sparse-runtime``.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import (masks_to_tree, restore_checkpoint,
                              save_checkpoint)
from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.core import stun_prune
from repro.data.synthetic import calibration_batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--checkpoint-dir", required=True)
    ap.add_argument("--out-dir", required=True)
    ap.add_argument("--sparsity", type=float, default=0.4)
    ap.add_argument("--expert-ratio", type=float, default=0.25)
    ap.add_argument("--unstructured", default="owl",
                    choices=["owl", "wanda", "magnitude"])
    ap.add_argument("--lam2", type=float, default=0.0,
                    help="coactivation weight (0 = no forward passes)")
    ap.add_argument("--kappa", type=int, default=3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--pack", action="store_true",
                    help="also emit the block-compressed sparse_ffn "
                         "artifact (MoE archs): expert FFN masks are "
                         "planned into MXU-tile block bitmaps and live "
                         "blocks packed into per-matrix pools "
                         "(repro.sparse), so the pruned model is "
                         "physically smaller at serve time")
    ap.add_argument("--pack-block", type=int, default=0,
                    help="square block size for --pack (0 = auto: "
                         "largest power-of-two divisor <= 128)")
    ap.add_argument("--pack-block-sparsity", type=float, default=None,
                    help="optional dead-block target for --pack: "
                         "sparsity-preserving block re-rounding "
                         "concentrates the element budget into "
                         "skippable blocks (see docs/sparse.md)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32",
                                  moe_impl="dense", remat_policy="full")
    if args.pack and cfg.family != "moe":
        ap.error("--pack packs expert FFNs; "
                 f"--arch {args.arch} is family {cfg.family!r}")
    step, tree = restore_checkpoint(args.checkpoint_dir)
    params = jax.tree.map(jax.numpy.asarray, tree["params"])
    batches = calibration_batches(cfg, n_batches=4)
    structured = args.expert_ratio if cfg.family == "moe" else 0.05
    pruned, pcfg, masks, report = stun_prune(
        params, cfg, batches, target_sparsity=args.sparsity,
        expert_ratio=structured, unstructured=args.unstructured,
        lam2=args.lam2, kappa=args.kappa, keep_stage1=args.pack)
    pruned = jax.tree.map(np.asarray, pruned)
    out_tree = {"params": pruned, "masks": masks_to_tree(masks)}
    if args.pack:
        from repro import sparse
        from repro.serving import apply_weight_masks

        # plan on the PRE-stage-2 weights: block re-rounding revives
        # pruned weights, whose values are zeros in `pruned` but live in
        # report.stage1_params
        stage1 = jax.tree.map(np.asarray, report.stage1_params)
        plan = sparse.plan_sparse_ffn(
            masks, sparse.ffn_weights_from_params(stage1, pcfg),
            block=("auto" if args.pack_block == 0
                   else (args.pack_block, args.pack_block)),
            target_block_sparsity=args.pack_block_sparsity)
        # the plan's (possibly re-rounded) masks are what the artifact
        # realizes — persist them and re-derive params from the stage-1
        # weights so revived elements carry their real values
        masks.update(plan.element_masks())
        out_tree["masks"] = masks_to_tree(masks)
        pruned = jax.tree.map(np.asarray,
                              apply_weight_masks(stage1, pcfg, masks))
        out_tree["params"] = pruned
        packed, prep = sparse.pack_sparse_ffn(stage1, pcfg, plan)
        out_tree["sparse_ffn"] = packed
        print(f"  packed: {prep['packed_bytes']}B / {prep['dense_bytes']}B "
              f"expert-FFN ({prep['bytes_ratio']:.2f}x), block sparsity "
              f"{prep['block_sparsity']:.1%}"
              + (f", {prep['blocks_rerounded']} blocks re-rounded"
                 if prep["blocks_rerounded"] else ""))
        if prep["bytes_ratio"] >= 0.95:
            print("  note: little block yield — compact checkpoints have "
                  "no dead experts to fold; pass --pack-block-sparsity "
                  "(e.g. 0.3) to concentrate the element budget into "
                  "skippable blocks (sparsity-preserving re-rounding)")
    save_checkpoint(args.out_dir, step, out_tree)
    print(f"pruned checkpoint written to {args.out_dir} "
          f"(masks persisted{'; sparse_ffn packed' if args.pack else ''})")
    print(f"  structured: {report.structured_ratio:.1%}  "
          f"unstructured: {report.unstructured_ratio:.1%}  "
          f"forward passes: {report.forward_passes}")
    if pcfg.n_experts != cfg.n_experts:
        print(f"  experts: {cfg.n_experts} -> {pcfg.n_experts} "
              f"(update serving config accordingly)")


if __name__ == "__main__":
    main()
