"""Serving launcher: load a (possibly STUN-pruned) checkpoint and serve
batched requests through the continuous-batching engine.

    python -m repro.launch.serve --arch olmoe-1b-7b --reduced \
        --checkpoint-dir /ckpt/pruned --n-requests 8 --new-tokens 16

``--frontend`` serves the same requests through the asyncio streaming
frontend (per-request token streams over the running step loop) instead
of the synchronous batch API; ``--qps`` offers them open-loop at a
Poisson arrival rate rather than all upfront — the wall-clock serving
mode ``benchmarks/bench_slo.py`` measures.  ``--trace out.json``
records span telemetry (docs/observability.md) and exports Chrome-trace
JSON loadable in Perfetto.

On hardware the engine runs under the production mesh (EP over "model");
pruned checkpoints re-shard onto the same mesh with a smaller expert axis.
"""
import argparse
import asyncio
import dataclasses

import jax
import numpy as np

from repro.checkpoint import restore_checkpoint
from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.serving import AsyncFrontend, Request, ServeEngine, Tracer


def _run_frontend(eng, reqs, qps):
    """Stream every request through ``AsyncFrontend``; with ``qps`` the
    clients arrive open-loop on a Poisson process instead of all at once.
    """
    rs = np.random.RandomState(0)
    arrivals = (np.cumsum(rs.exponential(1.0 / qps, len(reqs)))
                if qps else np.zeros(len(reqs)))

    async def client(fe, i, req, due, outs):
        if due > 0:
            await asyncio.sleep(due)
        stream = await fe.submit(req)
        outs[i] = await stream.drain()

    async def main():
        outs = [None] * len(reqs)
        async with AsyncFrontend(eng) as fe:
            await asyncio.gather(*(
                client(fe, i, r, float(a), outs)
                for i, (r, a) in enumerate(zip(reqs, arrivals))))
        return outs

    return asyncio.run(main())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--checkpoint-dir", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n-requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="cache slots (concurrent in-flight requests)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per prefill dispatch")
    ap.add_argument("--schedule", choices=["interleaved", "blocking"],
                    default="interleaved",
                    help="interleaved (default): at most --prefill-budget "
                         "prompt tokens of chunked prefill per step next "
                         "to the decode dispatch, so decode lanes never "
                         "stall behind a long prompt; blocking: each "
                         "admitted prompt prefills to completion first "
                         "(the PR-1 reference)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prompt tokens of prefill per engine step under "
                         "--schedule=interleaved (rounded down to whole "
                         "chunks, min one; default one --prefill-chunk)")
    ap.add_argument("--kv-layout", choices=["paged", "slot"],
                    default="paged",
                    help="paged KV cache (default) or the legacy "
                         "slot-granular layout")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV rows per page (paged layout)")
    ap.add_argument("--page-budget", type=int, default=None,
                    help="total KV pages; default fits max-batch requests "
                         "of max-len — set lower to pack short requests "
                         "into less HBM")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix caching over the paged KV "
                         "cache: admissions claim the longest cached "
                         "page-aligned prompt prefix (a fully cached "
                         "prompt skips prefill entirely); finished "
                         "prompts' pages stay resident until LRU "
                         "eviction reclaims them under page pressure")
    ap.add_argument("--prefix-cache-max-pages", type=int, default=None,
                    help="cap trie residency below what page pressure "
                         "alone would allow (default: unlimited — the "
                         "page budget is the only bound)")
    ap.add_argument("--frontend", action="store_true",
                    help="serve through the asyncio streaming frontend "
                         "(per-request token streams, admission "
                         "backpressure, cancel-on-disconnect) instead of "
                         "the synchronous batch API")
    ap.add_argument("--qps", type=float, default=None,
                    help="offer requests open-loop at this Poisson "
                         "arrival rate (requires --frontend; default: "
                         "all requests submitted upfront)")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="record span telemetry for the whole run and "
                         "export Chrome-trace JSON here (load in Perfetto "
                         "or chrome://tracing; span taxonomy in "
                         "docs/observability.md)")
    ap.add_argument("--trace-fence-rate", type=float, default=0.0,
                    help="fraction of dispatch spans closed with a "
                         "block_until_ready fence so durations measure "
                         "device work, not dispatch overhead (0 = never "
                         "fence, the async-dispatch default; 1 = always)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = softmax sampling")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: a pruned drafter "
                         "proposes --spec-k tokens per round, the dense "
                         "model verifies the block in one dispatch "
                         "(greedy output token-identical to plain decode; "
                         "temperature>0 served via rejection sampling, "
                         "distribution-identical to plain sampling)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--spec-tree", type=int, default=1,
                    help="draft-tree branches per round (>1 scores an "
                         "N-branch token tree in one verify dispatch; "
                         "1 = chain)")
    ap.add_argument("--spec-expert-drop", type=float, default=0.25,
                    help="fraction of experts masked off in the drafter "
                         "(MoE archs; non-MoE archs draft with the dense "
                         "model itself)")
    ap.add_argument("--sparse-runtime", action="store_true",
                    help="serve the block-compressed sparse_ffn artifact "
                         "from the checkpoint (written by launch.prune "
                         "--pack): expert FFN weights stay packed in "
                         "memory and execute through the block-sparse "
                         "path instead of being densified at load")
    ap.add_argument("--sparse-exec", default=None,
                    choices=["exact", "gather", "pallas", "interpret"],
                    help="force the packed execute path (default: Pallas "
                         "gather kernel on TPU, bit-exact unpack "
                         "elsewhere)")
    args = ap.parse_args()
    if args.qps is not None and not args.frontend:
        ap.error("--qps needs --frontend (open-loop arrivals are a "
                 "frontend property; the batch API submits upfront)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32",
                                  moe_impl="dense", remat_policy="full")
    _, tree = restore_checkpoint(args.checkpoint_dir)
    params = jax.tree.map(jax.numpy.asarray, tree["params"])
    sparse_kwargs = {}
    if args.sparse_runtime:
        if "sparse_ffn" not in tree:
            ap.error("--sparse-runtime: checkpoint has no sparse_ffn "
                     "artifact (re-run launch.prune with --pack)")
        sparse_kwargs = {"sparse_weights": tree["sparse_ffn"],
                         "sparse_exec": args.sparse_exec}
        from repro.sparse import sparse_ffn_bytes
        print(f"sparse runtime: packed expert-FFN artifact = "
              f"{sparse_ffn_bytes(tree['sparse_ffn'])} bytes")
    # infer pruned expert count from the checkpoint (compact STUN output)
    if cfg.family == "moe":
        e = params["layers"]["moe"]["router"].shape[1]
        if e != cfg.n_experts:
            cfg = dataclasses.replace(cfg, n_experts=e,
                                      top_k=min(cfg.top_k, e))
            print(f"detected pruned checkpoint: {e} experts")

    rs = np.random.RandomState(0)
    reqs = [Request(rs.randint(0, cfg.vocab, 8).astype(np.int32),
                    args.new_tokens, eos_id=args.eos_id,
                    temperature=args.temperature)
            for _ in range(args.n_requests)]
    spec_kwargs = {}
    if args.spec_decode:
        spec_kwargs = {"spec_decode": "pruned", "spec_k": args.spec_k,
                       "spec_tree": args.spec_tree}
        if cfg.family == "moe" and args.spec_expert_drop > 0:
            n_drop = int(cfg.n_experts * args.spec_expert_drop)
            n_drop = min(n_drop, cfg.n_experts - cfg.top_k)
            mask = np.ones(cfg.n_experts, np.float32)
            if n_drop:
                mask[-n_drop:] = 0.0
            spec_kwargs["expert_mask"] = mask
            print(f"spec drafter: {n_drop}/{cfg.n_experts} experts masked")
        else:
            print("spec drafter: dense (identity) — non-MoE arch or "
                  "--spec-expert-drop 0")
    tracer = (Tracer(fence_rate=args.trace_fence_rate)
              if args.trace else None)
    eng = ServeEngine(params, cfg, max_len=args.max_len,
                      max_batch=args.max_batch,
                      prefill_chunk=args.prefill_chunk,
                      kv_layout=args.kv_layout, page_size=args.page_size,
                      page_budget=args.page_budget,
                      schedule=args.schedule,
                      prefill_budget=args.prefill_budget,
                      prefix_cache=args.prefix_cache,
                      prefix_cache_max_pages=args.prefix_cache_max_pages,
                      trace=tracer,
                      **sparse_kwargs, **spec_kwargs)
    if args.frontend:
        outs = _run_frontend(eng, reqs, args.qps)
    else:
        outs = [o.tolist() for o in eng.generate(reqs)]
    for i, o in enumerate(outs):
        print(f"req{i}: {o}")
    stats = eng.latency_stats()
    lat = {k: f"{v * 1e3:.1f}ms" for k, v in stats.items()
           if k.endswith("_s")}
    spec = {k: round(v, 3) for k, v in stats.items()
            if k.startswith("spec_")}
    gauges = {k: round(v, 3) for k, v in stats.items()
              if not k.endswith("_s") and not k.startswith("spec_")}
    if lat:
        print("latency:", lat)
    if gauges:
        print("cache:", gauges)
    if spec:
        print("spec:", spec)
    print(f"dispatches: prefill={eng.prefill_dispatches} "
          f"decode={eng.decode_dispatches}")
    if tracer is not None:
        tracer.export(args.trace)
        print(f"trace: {args.trace} ({len(tracer.events)} events, "
              f"{tracer.n_spans} spans, {tracer.n_fences} fenced)")


if __name__ == "__main__":
    main()
