import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods × 256 chips.  Per cell we record:

  * the REAL lowering (scan-over-layers) — compile success +
    memory_analysis (the fits-in-HBM proof) + raw cost numbers;
  * PROBE lowerings — 1/2-layer unrolled variants (inner chunk scans also
    python-unrolled) whose HLO contains every op explicitly.  XLA's
    HloCostAnalysis visits while-loop bodies ONCE (verified empirically:
    flops constant in n_layers), so scanned models under-count by the trip
    count; the probes give exact per-layer marginals which we extrapolate
    linearly to full depth:  total = base + Σ_kind n_kind · marginal_kind.

Roofline terms (§Roofline, single-pod only per spec) use the extrapolated
numbers; the multi-pod pass proves the "pod" axis shards and checks memory.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]

Results cache to experiments/dryrun/<mesh>/<arch>__<shape>.json; existing
files are skipped (the sweep itself is restartable).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.distributed.sharding import named_sharding
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, cache_specs
from repro.models import param as pm
from repro.models.transformer import decode_step, forward
from repro.optim import AdamWConfig
from repro.roofline import collective_bytes_from_hlo, roofline_terms
from repro.roofline.analysis import model_flops
from repro.runtime.step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def input_specs(cfg, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every model input of the given benchmark cell."""
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    tok_sh = named_sharding(("batch", "seq"), (B, S), mesh)
    if shape.kind in ("train", "prefill"):
        if cfg.frontend_stub:
            emb_sh = named_sharding(("batch", "seq", None),
                                    (B, S, cfg.d_model), mesh)
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                    jnp.bfloat16,
                                                    sharding=emb_sh)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                                    sharding=tok_sh)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                                   sharding=tok_sh)
        return batch
    tok1_sh = named_sharding(("batch", "seq"), (B, 1), mesh)
    cache = pm.abstract_arrays(cache_specs(cfg, B, S), mesh)
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok1_sh),
        "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def _abstract_opt(params_sds):
    mk = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,  # noqa: E731
                                        sharding=s.sharding)
    return {"adam": {"m": jax.tree.map(mk, params_sds),
                     "v": jax.tree.map(mk, params_sds),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def lower_cell(cfg, shape_name: str, mesh):
    shape = SHAPES[shape_name]
    params_sds = pm.abstract_arrays(abstract_params(cfg), mesh)
    specs = input_specs(cfg, shape_name, mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, AdamWConfig(), mesh=mesh)
        opt_sds = _abstract_opt(params_sds)
        fn = jax.jit(step, donate_argnums=(0, 1))
        with mesh:
            return fn.lower(params_sds, opt_sds, specs)
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits = forward(params, cfg, batch, mesh=mesh)
            return logits[:, -1]
        with mesh:
            return jax.jit(prefill_step).lower(params_sds, specs)

    def serve_step(params, cache, tokens, cur_len):
        return decode_step(params, cfg, cache, tokens, cur_len, mesh=mesh)
    fn = jax.jit(serve_step, donate_argnums=(1,))
    with mesh:
        return fn.lower(params_sds, specs["cache"], specs["tokens"],
                        specs["cur_len"])


def _metrics(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": {k: float(coll.get(k, 0)) for k in _COLL_KINDS},
            "coll_count": coll.get("count", 0)}


def _probe_cfgs(cfg):
    """(probe_cfgs, combine) — combine(list of metric dicts) -> totals.

    Probe configs make every inner loop trip count 1 so HloCostAnalysis
    counts all work exactly: chunk sizes -> S (a single associative_scan /
    the naive-attention path replaces the KV-chunk while loop — identical
    FLOPs, since the chunked path computes all blocks and masks).
    """
    BIG = 1 << 30
    probe_over = dict(scan_layers=False, unroll_scans=True,
                      attn_chunk=BIG, ssm_chunk=BIG)
    if cfg.family == "hybrid":
        pats = [("rec",), ("rec", "rec"), ("rec", "rec", "attn")]
        probes = [dataclasses.replace(cfg, n_layers=len(p), layer_pattern=p,
                                      **probe_over)
                  for p in pats]
        pat = cfg.effective_pattern()
        n_rec = sum(1 for k in pat if k == "rec")
        n_attn = len(pat) - n_rec

        def combine(ms):
            f1, f2, f3 = ms

            def tot(g):
                m_rec = max(g(f2) - g(f1), 0.0)
                m_attn = max(g(f3) - g(f2), 0.0)
                base = max(g(f1) - m_rec, 0.0)
                return base + n_rec * m_rec + n_attn * m_attn
            return _combine_metrics(tot)
        return probes, combine

    probes = [dataclasses.replace(cfg, n_layers=k, **probe_over)
              for k in (1, 2)]
    L = cfg.n_layers

    def combine(ms):
        f1, f2 = ms

        def tot(g):
            m = max(g(f2) - g(f1), 0.0)
            base = max(g(f1) - m, 0.0)
            return base + L * m
        return _combine_metrics(tot)
    return probes, combine


def _combine_metrics(tot):
    out = {"flops": tot(lambda f: f["flops"]),
           "bytes": tot(lambda f: f["bytes"]),
           "coll": {k: tot(lambda f, k=k: f["coll"][k])
                    for k in _COLL_KINDS}}
    out["coll"]["count"] = 0
    return out


# ---------------------------------------------------------------------------
# Perf variants (§Perf hillclimbs) — config transforms applied per cell.
# "opt" is the beyond-paper optimized configuration; "stun" additionally
# applies the paper's 25% expert pruning to MoE archs (serving cells).
# ---------------------------------------------------------------------------


def _variant_cfg(cfg, shape_name: str, variant: str):
    shape = SHAPES[shape_name]
    if variant in ("opt", "stun"):
        if shape.kind in ("train", "prefill"):
            # exact head padding (sharded attention instead of replication /
            # involuntary remat) + bf16 residual-grad psums
            cfg = dataclasses.replace(cfg, pad_heads=True,
                                      norm_bf16_grad=True)
        else:  # decode
            over = {"kv_cache_dtype": "float8_e4m3fn"}
            if cfg.n_kv_heads == cfg.n_heads and cfg.n_heads % 16 != 0:
                # MHA: padding makes the KV cache shardable over "model" —
                # a 16x cache-residency reduction that dwarfs the 1.33-1.6x
                # padding overhead
                over["pad_heads"] = True
            cfg = dataclasses.replace(cfg, **over)
    if variant == "stun" and cfg.family == "moe":
        # the paper's structured stage: 25% of experts pruned (O(1) method)
        keep = int(round(cfg.n_experts * 0.75))
        cfg = dataclasses.replace(cfg, n_experts=keep,
                                  top_k=min(cfg.top_k, keep))
    return cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str, force=False,
             probes: bool = True, variant: str = "") -> dict:
    dirname = mesh_kind + (f"-{variant}" if variant else "")
    outdir = os.path.join(RESULTS_DIR, dirname)
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir, f"{arch}__{shape_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    if variant:
        cfg = _variant_cfg(cfg, shape_name, variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[{mesh_kind}] {arch} × {shape_name}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "n_chips": n_chips}
    try:
        # --- real lowering: compile proof + memory analysis ---
        t0 = time.monotonic()
        compiled = lower_cell(cfg, shape_name, mesh).compile()
        rec["compile_s"] = time.monotonic() - t0
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: getattr(mem, k, None)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")}
        print(mem)
        rec["raw_cost"] = _metrics(compiled)
        del compiled

        # --- probes: trip-count-exact costing (single-pod roofline) ---
        if probes:
            probe_cfgs, combine = _probe_cfgs(cfg)
            pms = []
            for pc in probe_cfgs:
                c = lower_cell(pc, shape_name, mesh).compile()
                pms.append(_metrics(c))
                del c
            rec["probe_metrics"] = pms
            total = combine(pms)
            rec["extrapolated"] = total
            terms = roofline_terms(
                {"flops": total["flops"], "bytes accessed": total["bytes"]},
                total["coll"], n_chips,
                mem_analysis=rec["memory_analysis"])
        else:
            terms = roofline_terms(
                {"flops": rec["raw_cost"]["flops"],
                 "bytes accessed": rec["raw_cost"]["bytes"]},
                rec["raw_cost"]["coll"], n_chips,
                mem_analysis=rec["memory_analysis"])
        rec["roofline"] = terms
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mf = model_flops(cfg, tokens, shape.kind)
        rec["model_flops_total"] = mf
        total_flops = terms["per_chip_flops"] * n_chips
        rec["useful_flops_ratio"] = mf / total_flops if total_flops else None
        rec["status"] = "ok"
        print(f"[{mesh_kind}] {arch} × {shape_name}: ok "
              f"dominant={terms['dominant']} "
              f"bound={terms['bound_step_time_s']:.4f}s "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)} "
              f"(compile {rec['compile_s']:.0f}s)")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[{mesh_kind}] {arch} × {shape_name}: FAILED "
              f"{type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--variant", default="", choices=["", "opt", "stun"])
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    failures = 0
    for mk in meshes:
        # roofline probes are a single-pod deliverable; multipod pass
        # proves sharding + memory only
        use_probes = (mk == "pod") and not args.no_probes
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mk, force=args.force,
                               probes=use_probes, variant=args.variant)
                if rec.get("status") == "error":
                    failures += 1
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
