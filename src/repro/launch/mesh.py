"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests see 1 CPU device, only
launch/dryrun.py (which sets XLA_FLAGS first) sees 512.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax.sharding.AxisType landed after 0.4.x; Auto is the default there
    anyway, so omit the kwarg on older jax instead of crashing."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a 2-pod outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py does this) or on real hardware")
    return jax.make_mesh(shape, axes, devices=devices,
                         **_axis_type_kwargs(len(axes)))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n],
                         **_axis_type_kwargs(2))
