"""STUN pipeline: Structured-Then-UNstructured pruning (paper §4.1).

  stage 1 (structured):  O(1) expert pruning (MoE) or light FFN-column
                         pruning (non-MoE, RQ5 variant) — "until the loss is
                         negligible" (fixed ratio per paper's Impl. Details:
                         20% Arctic / 12.5% Mixtral-8x7B / 10% 8x22B).
  stage 2 (unstructured): Wanda or OWL at the ratio that brings *total*
                          sparsity to the target.

Total sparsity accounting follows the paper: a target sparsity φ_total over
the original parameter count. Stage 1 removes a fraction φ_s of prunable
params; stage 2 then prunes φ_u of the *remaining* weights with
φ_u = (φ_total - φ_s) / (1 - φ_s).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.calibration import (CalibStats, coactivation_tensor,
                                    run_calibration)
from repro.core.expert_prune import expert_prune_moe
from repro.core.robustness import model_kurtosis
from repro.core.structured_nonmoe import structured_prune_ffn
from repro.core.unstructured import sparsify_model


@dataclasses.dataclass
class StunReport:
    structured_ratio: float
    unstructured_ratio: float
    total_sparsity: float
    kurtosis_before: Dict[str, float]
    kurtosis_after_structured: Dict[str, float]
    kurtosis_after_unstructured: Dict[str, float]
    expert_report: Optional[object] = None
    unstructured_report: Optional[dict] = None
    forward_passes: int = 0
    # post-stage-1 / pre-stage-2 params (host tree), kept only on
    # request: consumers that re-plan the stage-2 masks (e.g. the sparse
    # runtime's block re-rounding, which revives pruned weights) need
    # the pre-masking values — they are zeros in the returned params
    stage1_params: Optional[object] = None


def stun_prune(params, cfg, calib_batches, *, target_sparsity: float,
               expert_ratio: float = 0.25, unstructured: str = "owl",
               lam1: float = 1.0, lam2: float = 0.0, kappa: int = 3,
               cluster_method: str = "agglomerative",
               nm: Optional[tuple] = None, keep_stage1: bool = False):
    """Full STUN. Returns (pruned_params, pruned_cfg, masks, StunReport).

    ``keep_stage1=True`` additionally stows the post-stage-1 params on
    ``report.stage1_params`` (see the field's comment)."""
    kurt0 = model_kurtosis(params)
    fwd = 0

    # ---- stage 1: structured ----
    if cfg.family == "moe":
        coact = None
        if lam2 != 0.0:
            stats = run_calibration(params, cfg, calib_batches)
            coact = coactivation_tensor(stats, cfg)
            fwd += len(calib_batches)
        params1, cfg1, keep_mask, erep = expert_prune_moe(
            params, cfg, expert_ratio, kappa=kappa, lam1=lam1, lam2=lam2,
            coact=coact, method=cluster_method, mode="compact")
        structured_ratio = expert_ratio * _expert_param_fraction(cfg)
    else:
        stats0 = run_calibration(params, cfg, calib_batches)
        fwd += len(calib_batches)
        params1, cfg1, _kept = structured_prune_ffn(params, cfg,
                                                    stats0.norms(),
                                                    ratio=expert_ratio)
        erep = None
        structured_ratio = expert_ratio * _ffn_param_fraction(cfg)
    kurt1 = model_kurtosis(params1)

    # ---- stage 2: unstructured on the pruned network ----
    phi_u = max(0.0, (target_sparsity - structured_ratio)
                / max(1e-9, 1.0 - structured_ratio))
    stats = run_calibration(params1, cfg1, calib_batches)
    fwd += len(calib_batches)
    params2, masks, urep = sparsify_model(params1, cfg1, stats.norms(),
                                          phi_u, method=unstructured, nm=nm)
    kurt2 = model_kurtosis(params2)

    report = StunReport(
        structured_ratio=structured_ratio,
        unstructured_ratio=phi_u,
        total_sparsity=target_sparsity,
        kurtosis_before=kurt0,
        kurtosis_after_structured=kurt1,
        kurtosis_after_unstructured=kurt2,
        expert_report=erep,
        unstructured_report=urep,
        forward_passes=fwd,
        stage1_params=params1 if keep_stage1 else None,
    )
    return params2, cfg1, masks, report


def unstructured_only(params, cfg, calib_batches, *, target_sparsity: float,
                      method: str = "owl", nm=None):
    """The paper's baseline: Wanda/OWL directly at the target sparsity."""
    stats = run_calibration(params, cfg, calib_batches)
    return sparsify_model(params, cfg, stats.norms(), target_sparsity,
                          method=method, nm=nm)


def _expert_param_fraction(cfg) -> float:
    """Fraction of prunable params that live in expert weights."""
    d = cfg.d_model
    expert = cfg.n_experts * 3 * d * cfg.moe_d_ff
    attn = (d * cfg.n_heads * cfg.head_dim
            + 2 * d * cfg.n_kv_heads * cfg.head_dim
            + cfg.n_heads * cfg.head_dim * d)
    return expert / (expert + attn)


def _ffn_param_fraction(cfg) -> float:
    d = cfg.d_model
    if cfg.d_ff == 0:
        return 0.0
    ffn = 3 * d * cfg.d_ff
    attn = (d * cfg.n_heads * cfg.head_dim
            + 2 * d * cfg.n_kv_heads * cfg.head_dim
            + cfg.n_heads * cfg.head_dim * d)
    return ffn / (ffn + attn)
