"""STUN — the paper's primary contribution (see DESIGN.md §1)."""
from repro.core.clustering import (  # noqa: F401
    agglomerative_threshold,
    agglomerative_to_count,
    cluster_experts,
    dsatur_to_count,
)
from repro.core.combinatorial import (  # noqa: F401
    combinatorial_prune,
    combinatorial_prune_layer,
    n_combinations,
)
from repro.core.expert_prune import (  # noqa: F401
    expert_prune_moe,
    greedy_prune_sequence,
    layer_reconstruction_loss,
    representatives,
)
from repro.core.robustness import kurtosis, model_kurtosis  # noqa: F401
from repro.core.similarity import (  # noqa: F401
    behavioral_distance,
    coactivation_counts,
    router_distance,
)
from repro.core.structured_nonmoe import structured_prune_ffn  # noqa: F401
from repro.core.stun import stun_prune, unstructured_only  # noqa: F401
from repro.core.unstructured import (  # noqa: F401
    mask_per_output,
    nm_rounding,
    owl_layer_sparsities,
    sparsify_model,
    wanda_scores,
)
