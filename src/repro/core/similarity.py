"""Behavioral similarity between experts (paper §4.3, Eq. 8 / Eq. 10).

Eq. 8 presents b_ij = -||W_i - W_j||_F as a *similarity* (higher = more
similar); Algorithm 1 consumes it as a *distance* visited in increasing
order with a complete-linkage threshold.  We keep the distance convention
internally: d_ij = λ1·||W_i - W_j||_F - λ2·a_ij  (so d = -b).

Coactivation statistics a_ij count how often experts i, j appear together in
the same token's top-k set over calibration data, normalized by the layer's
total coactivations (paper footnote 4).
"""
from __future__ import annotations

import numpy as np


def router_distance(router_w: np.ndarray) -> np.ndarray:
    """Pairwise ||W_i - W_j||_F over router rows. router_w [E, D] -> [E, E]."""
    W = np.asarray(router_w, np.float64)
    sq = np.sum(W * W, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (W @ W.T)
    d = np.sqrt(np.maximum(d2, 0.0))
    np.fill_diagonal(d, 0.0)  # exact zeros (quadratic form rounds off)
    return d


def coactivation_counts(top_idx: np.ndarray, n_experts: int) -> np.ndarray:
    """top_idx [T, k] token-wise selected experts -> raw counts a_ij [E, E].

    a_ij = #tokens whose top-k contains both i and j (i != j).
    """
    T, k = top_idx.shape
    onehot = np.zeros((T, n_experts), np.float64)
    np.put_along_axis(onehot, top_idx, 1.0, axis=1)
    a = onehot.T @ onehot
    np.fill_diagonal(a, 0.0)
    return a


def normalize_coactivation(a: np.ndarray) -> np.ndarray:
    """Divide by total coactivations in the layer (footnote 4)."""
    tot = a.sum()
    return a / tot if tot > 0 else a


def behavioral_distance(router_w, coact=None, lam1: float = 1.0,
                        lam2: float = 0.0) -> np.ndarray:
    """Distance matrix d_ij = λ1·||W_i-W_j||_F - λ2·a_ij  (= -b_ij, Eq. 10)."""
    d = lam1 * router_distance(router_w)
    if lam2 != 0.0 and coact is not None:
        d = d - lam2 * normalize_coactivation(np.asarray(coact, np.float64))
    np.fill_diagonal(d, 0.0)
    return d


def expert_flat_weights(layer_moe_params, layer_idx=None) -> np.ndarray:
    """Concatenate each expert's weights into one flat vector. -> [E, P].

    Accepts the `moe` param subtree ({router, we_gate, we_up, we_down}); when
    the tree is scan-stacked [L, E, ...], pass layer_idx.
    """
    mats = []
    for key in ("we_gate", "we_up", "we_down"):
        w = np.asarray(layer_moe_params[key], np.float32)
        if layer_idx is not None:
            w = w[layer_idx]
        mats.append(w.reshape(w.shape[0], -1))
    return np.concatenate(mats, axis=1)
