"""Structured stage for non-MoE architectures (paper §6.2.5, RQ5).

The paper generalizes STUN to non-MoEs by running a light structured pruning
(LLM-Surgeon, ~5%) before unstructured pruning.  Our TPU-friendly analogue
prunes whole d_ff *columns* (gate/up columns + matching down rows) ranked by
a first-order saliency ||w_col|| · ||x_in|| — the same Taylor logic the
paper applies to experts, at row/column granularity.  The result is a
physically smaller, still-dense model (structure preserved for the MXU).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


def ffn_column_saliency(w_gate, w_up, w_down, xnorm) -> np.ndarray:
    """Saliency per d_ff column: combined first-order score. -> [F]."""
    g = np.asarray(w_gate, np.float32)
    u = np.asarray(w_up, np.float32)
    d = np.asarray(w_down, np.float32)
    xn = np.asarray(xnorm, np.float32)[:, None]
    s_in = np.linalg.norm(g * xn, axis=0) * np.linalg.norm(u * xn, axis=0)
    s_out = np.linalg.norm(d, axis=1)
    return s_in * s_out


def structured_prune_ffn(params, cfg, norms: Dict, ratio: float = 0.05):
    """Drop the lowest-saliency `ratio` of d_ff columns in every MLP.

    Returns (new_params, new_cfg, kept_idx per layer). Only dense-family
    MLPs (incl. hybrid/audio/vlm blocks) are touched.
    """
    assert cfg.family != "moe", "MoE uses expert pruning (stage 1) instead"
    F = cfg.d_ff
    if F == 0:
        return params, cfg, {}
    n_keep = max(8, int(round(F * (1.0 - ratio))))
    # keep MXU-aligned sizes
    n_keep -= n_keep % 8

    kept: Dict[int, np.ndarray] = {}
    pat = cfg.effective_pattern()
    new_params = {**params, "layers": dict(params["layers"])
                  if cfg.family == "hybrid" or not cfg.scan_layers
                  else dict(params["layers"])}

    def prune_one(ltree, l):
        mlp = ltree["mlp"]
        wg = np.asarray(mlp["w_gate"], np.float32)
        wu = np.asarray(mlp["w_up"], np.float32)
        wd = np.asarray(mlp["w_down"], np.float32)
        if wg.ndim == 3:  # stacked [L, D, F]
            wg, wu, wd = wg[l], wu[l], wd[l]
        xn = norms.get((l, "mlp_in"), np.ones(wg.shape[0], np.float32))
        sal = ffn_column_saliency(wg, wu, wd, xn)
        idx = np.sort(np.argsort(-sal)[:n_keep])
        kept[l] = idx
        return (wg[:, idx], wu[:, idx], wd[idx, :])

    import jax.numpy as jnp
    if cfg.family == "hybrid" or not cfg.scan_layers:
        for l, kind in enumerate(pat):
            lt = new_params["layers"][str(l)]
            if "mlp" not in lt:
                continue
            wg, wu, wd = prune_one(lt, l)
            new_params["layers"][str(l)] = {
                **lt, "mlp": {"w_gate": jnp.asarray(wg), "w_up": jnp.asarray(wu),
                              "w_down": jnp.asarray(wd)}}
    else:
        lt = new_params["layers"]
        if "mlp" in lt:
            outs = [prune_one(lt, l) for l in range(cfg.n_layers)]
            new_params["layers"] = {
                **lt,
                "mlp": {"w_gate": jnp.asarray(np.stack([o[0] for o in outs])),
                        "w_up": jnp.asarray(np.stack([o[1] for o in outs])),
                        "w_down": jnp.asarray(np.stack([o[2] for o in outs]))}}
    new_cfg = dataclasses.replace(cfg, d_ff=n_keep)
    return new_params, new_cfg, kept
