"""Unstructured pruning: Wanda, OWL, magnitude (paper stage 2).

Wanda (Sun et al. 2024): score S = |W| · ||X_in||_2, pruned per *output*
comparison group at uniform layer sparsity.
OWL  (Yin et al. 2024): same scores, but per-layer sparsity reallocated by
outlier ratio — layers with more outliers (score > M × layer-mean) keep
more weights; ratios bounded to [S-λ, S+λ] with mean S (M=5, λ=0.08).
Magnitude: |W| per-output groups, no activations.

All masks are returned alongside the sparsified params so downstream
consumers (kurtosis probe, block-sparse kernel, N:M re-rounding) can reuse
them.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

# weight path -> (stat tap name, input axis, per_expert?)
FAMILY_PRUNABLE = {
    "attn": {
        ("attn", "wq"): ("attn_in", 0, False),
        ("attn", "wk"): ("attn_in", 0, False),
        ("attn", "wv"): ("attn_in", 0, False),
        ("attn", "wo"): ("attn_out", (0, 1), False),
    },
    "mlp": {
        ("mlp", "w_gate"): ("mlp_in", 0, False),
        ("mlp", "w_up"): ("mlp_in", 0, False),
        ("mlp", "w_down"): ("mlp_mid", 0, False),
    },
    "moe": {
        ("moe", "we_gate"): ("moe_expert_in", 1, True),
        ("moe", "we_up"): ("moe_expert_in", 1, True),
        ("moe", "we_down"): ("moe_expert_mid", 1, True),
    },
    "ssm": {
        ("ssm", "w_in"): ("ssm_in", 0, False),
        ("ssm", "w_x"): ("ssm_x", 0, False),
        ("ssm", "w_dt"): ("ssm_dt", 0, False),
        ("ssm", "w_out"): ("ssm_out", 0, False),
    },
    "rec": {
        ("rec", "w_gate"): ("rec_in", 0, False),
        ("rec", "w_in"): ("rec_in", 0, False),
        ("rec", "w_a"): ("rec_gates", 0, False),
        ("rec", "w_i"): ("rec_gates", 0, False),
        ("rec", "w_out"): ("rec_out", 0, False),
    },
}


def prunable_for(cfg, kind: str) -> Dict:
    out = {}
    if kind == "attn":
        out.update(FAMILY_PRUNABLE["attn"])
        out.update(FAMILY_PRUNABLE["moe" if cfg.family == "moe" else "mlp"])
    elif kind == "ssm":
        out.update(FAMILY_PRUNABLE["ssm"])
    elif kind == "rec":
        out.update(FAMILY_PRUNABLE["rec"])
        out.update(FAMILY_PRUNABLE["mlp"])
    elif kind == "local_attn":
        out.update(FAMILY_PRUNABLE["attn"])
        out.update(FAMILY_PRUNABLE["mlp"])
    return out


# ---------------------------------------------------------------------------
# Scores & masks
# ---------------------------------------------------------------------------


def wanda_scores(W: np.ndarray, xnorm: np.ndarray, in_axis) -> np.ndarray:
    """|W| · ||X||, xnorm broadcast over the input axis/axes."""
    s = np.abs(np.asarray(W, np.float32))
    if isinstance(in_axis, tuple):
        shape = [1] * s.ndim
        for ax in in_axis:
            shape[ax] = s.shape[ax]
        s = s * xnorm.reshape(shape)
    else:
        shape = [1] * s.ndim
        shape[in_axis] = s.shape[in_axis]
        s = s * xnorm.reshape(shape)
    return s


def mask_per_output(scores: np.ndarray, sparsity: float, in_axis
                    ) -> np.ndarray:
    """Prune the lowest `sparsity` fraction within each output group."""
    axes = in_axis if isinstance(in_axis, tuple) else (in_axis,)
    # move input axes to the front, flatten into one comparison axis
    perm = list(axes) + [i for i in range(scores.ndim) if i not in axes]
    s = np.transpose(scores, perm)
    n_in = int(np.prod(s.shape[: len(axes)]))
    flat = s.reshape(n_in, -1)
    n_prune = int(np.floor(sparsity * n_in))
    mask_flat = np.ones_like(flat, bool)
    if n_prune > 0:
        idx = np.argpartition(flat, n_prune - 1, axis=0)[:n_prune]
        np.put_along_axis(mask_flat, idx, False, axis=0)
    mask = mask_flat.reshape(s.shape)
    inv = np.argsort(perm)
    return np.transpose(mask, inv)


def nm_rounding(scores: np.ndarray, in_axis, n: int = 2, m: int = 4
                ) -> np.ndarray:
    """N:M re-rounding of a score tensor (TPU/accelerator-friendly pattern):
    keep the top-n of every m consecutive weights along the input axis.

    Exactly n survive per group even under score ties (deterministic:
    stable ascending argsort takes the last n, so among equal scores the
    higher-indexed weights survive) — a threshold comparison would keep
    every tied weight and break the hardware pattern's <= n guarantee.
    """
    ax = in_axis if not isinstance(in_axis, tuple) else in_axis[0]
    s = np.moveaxis(np.asarray(scores, np.float32), ax, -1)
    orig = s.shape[-1]
    pad = (-orig) % m
    if pad:
        s = np.concatenate([s, np.full(s.shape[:-1] + (pad,), -np.inf,
                                       s.dtype)], axis=-1)
    grp = s.reshape(s.shape[:-1] + (s.shape[-1] // m, m))
    order = np.argsort(grp, axis=-1, kind="stable")
    mask_g = np.zeros(grp.shape, bool)
    np.put_along_axis(mask_g, order[..., m - n:], True, axis=-1)
    mask = mask_g.reshape(s.shape)[..., :orig]
    return np.moveaxis(mask, -1, ax)


def outlier_ratio(scores: np.ndarray, M: float = 5.0) -> float:
    mean = scores.mean()
    return float((scores > M * mean).mean())


def owl_layer_sparsities(ratios: List[float], target: float,
                         lam: float = 0.08) -> np.ndarray:
    """OWL: sparsity_i ∈ [S-λ, S+λ], decreasing in outlier ratio, mean S."""
    r = np.asarray(ratios, np.float64)
    if r.max() - r.min() < 1e-12:
        return np.full(len(r), target)
    dev = r - r.mean()
    dev = dev / np.max(np.abs(dev))                 # [-1, 1], zero mean-ish
    s = target - lam * dev                          # more outliers -> keep more
    s = s + (target - s.mean())                     # exact budget
    return np.clip(s, 0.0, 0.99)


# ---------------------------------------------------------------------------
# Whole-model sparsification
# ---------------------------------------------------------------------------


def _get_path(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _set_path(tree, path, val):
    out = dict(tree)
    if len(path) == 1:
        out[path[0]] = val
        return out
    out[path[0]] = _set_path(tree[path[0]], path[1:], val)
    return out


def _iter_layers(params, cfg):
    """Yields (layer_idx, kind, layer_param_tree, stacked?)."""
    pat = cfg.effective_pattern()
    for l, kind in enumerate(pat):
        if cfg.family == "hybrid" or not cfg.scan_layers:
            yield l, kind, params["layers"][str(l)], False
        else:
            yield l, kind, params["layers"], True


def sparsify_model(params, cfg, norms: Dict, sparsity: float,
                   method: str = "wanda", owl_M: float = 5.0,
                   owl_lam: float = 0.08, nm: Optional[Tuple[int, int]] = None):
    """Apply Wanda/OWL/magnitude masks to every prunable weight.

    norms: {(layer, tap) -> xnorm} from calibration (unused for magnitude).
    Returns (new_params, masks {(layer, path) -> bool ndarray}, report).
    """
    import jax.numpy as jnp

    # pass 1: scores (+ per-layer outlier ratios for OWL)
    entries = []  # (l, path, stacked, in_axis, scores)
    ratios_by_layer: Dict[int, List[float]] = {}
    for l, kind, ltree, stacked in _iter_layers(params, cfg):
        for path, (tap, in_axis, per_expert) in prunable_for(cfg, kind).items():
            W = np.asarray(_get_path(ltree, path), np.float32)
            if stacked:
                W = W[l]
            if method == "magnitude":
                sc = np.abs(W)
            else:
                xn = norms[(l, tap)]
                if per_expert:
                    # xn [E, Din]; W [E, ..., ...] with in_axis counted
                    # relative to the full tensor
                    sc = np.abs(W) * np.expand_dims(
                        xn, axis=tuple(i for i in range(1, W.ndim)
                                       if i != in_axis))
                else:
                    sc = wanda_scores(W, xn, in_axis)
            entries.append((l, path, stacked, in_axis, per_expert, sc))
            ratios_by_layer.setdefault(l, []).append(outlier_ratio(sc, owl_M))

    layer_ids = sorted(ratios_by_layer)
    if method == "owl":
        per_layer = owl_layer_sparsities(
            [float(np.mean(ratios_by_layer[l])) for l in layer_ids],
            sparsity, owl_lam)
        sp_of = dict(zip(layer_ids, per_layer))
    else:
        sp_of = {l: sparsity for l in layer_ids}

    # pass 2: masks + apply
    new_params = params
    masks = {}
    total, kept = 0, 0
    for l, path, stacked, in_axis, per_expert, sc in entries:
        if per_expert:
            # comparison group per (expert, output): treat expert axis as
            # batch — compute per expert slice
            mask = np.stack([mask_per_output(sc[e], sp_of[l],
                                             in_axis - 1 if isinstance(in_axis, int) else in_axis)
                             for e in range(sc.shape[0])])
        else:
            mask = mask_per_output(sc, sp_of[l], in_axis)
        if nm is not None:
            mask &= nm_rounding(sc, (in_axis if not per_expert else in_axis),
                                *nm)
        masks[(l, path)] = mask
        total += mask.size
        kept += int(mask.sum())
        W = _get_path(new_params["layers"] if stacked
                      else new_params["layers"][str(l)], path)
        Wn = np.asarray(W, np.float32)
        if stacked:
            Wl = Wn[l] * mask
            Wn = Wn.copy()
            Wn[l] = Wl
        else:
            Wn = Wn * mask
        sub = new_params["layers"] if stacked else new_params["layers"][str(l)]
        sub = _set_path(sub, path, jnp.asarray(Wn, dtype=_get_path(
            params["layers"] if stacked else params["layers"][str(l)],
            path).dtype))
        if stacked:
            new_params = {**new_params, "layers": sub}
        else:
            new_params = {**new_params,
                          "layers": {**new_params["layers"], str(l): sub}}
    report = {
        "method": method,
        "target_sparsity": sparsity,
        "achieved_sparsity": 1.0 - kept / max(total, 1),
        "per_layer_sparsity": {l: float(sp_of[l]) for l in layer_ids},
    }
    return new_params, masks, report
