"""O(1) expert pruning with selective reconstruction (paper §4.3–4.4, Alg 2).

Per MoE layer:
  1. distance matrix from router rows (+ optional coactivation), Eq. 8/10;
  2. cluster to the target count (Alg. 1);
  3. within each cluster keep the expert closest to the cluster parameter
     mean θ̄ (1st-order Taylor argument, Eq. 11–12);
  4. *selective reconstruction* (Alg. 2): if the layer has fewer than κ
     clusters, overwrite the kept expert with θ̄ (minimizes Σℰ_i); otherwise
     keep the original weights (minimizes the distribution-shift error ℰ_d).
     The representative's router row is reconstructed the same way.

Outputs either a *mask* view (full-size params + alive-mask, for cheap
evaluation via router masking) or a *compact* view (physically smaller
arrays, for serving).  The greedy Eq. 5–7 selection is provided explicitly
for validation; its fixed point is exactly keep-one-representative-per-
cluster.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.clustering import cluster_experts
from repro.core.similarity import behavioral_distance, expert_flat_weights


# ---------------------------------------------------------------------------
# Per-cluster representative selection (Taylor ranking + reconstruction)
# ---------------------------------------------------------------------------


def representatives(flat_w: np.ndarray, labels: np.ndarray, kappa: int
                    ) -> Tuple[np.ndarray, bool, Dict[int, np.ndarray]]:
    """Pick per-cluster representatives.

    Returns (rep_idx [n_clusters], reconstruct?, {cluster -> θ̄ flat}).
    reconstruct is True iff n_clusters < κ (Alg. 2 branch).
    """
    n_clusters = int(labels.max()) + 1
    reconstruct = n_clusters < kappa
    reps = np.zeros(n_clusters, np.int64)
    means: Dict[int, np.ndarray] = {}
    for c in range(n_clusters):
        members = np.where(labels == c)[0]
        mean = flat_w[members].mean(axis=0)
        dist = np.linalg.norm(flat_w[members] - mean[None], axis=1)
        reps[c] = members[int(np.argmin(dist))]
        means[c] = mean
    return reps, reconstruct, means


def greedy_prune_sequence(labels: np.ndarray, rep_idx: np.ndarray,
                          L: float = 10.0, p: float = 1.0) -> List[int]:
    """Explicit greedy optimization of Eq. 6 with the Eq. 7 scoring.

    P(E_i) = L if i is its cluster's representative (high reconstruction loss
    if removed) else 0; pruning-probability score = -ℰ rank; lowered by p
    once the rest of the cluster is already pruned.  Returns the prune order;
    its result set equals {non-representatives}.
    """
    E = len(labels)
    reps = set(int(r) for r in rep_idx)
    pruned: List[int] = []
    pruned_set = set()
    target = E - (int(labels.max()) + 1)
    for _ in range(target):
        best, best_score = None, -np.inf
        for i in range(E):
            if i in pruned_set:
                continue
            score = -L if i in reps else 0.0   # prune-prob ~ -ℰ_i
            others = [j for j in np.where(labels == labels[i])[0] if j != i]
            if all(j in pruned_set for j in others):
                score -= p                     # c(E_i) ⊆ S_k guard (Eq. 7)
            if score > best_score:
                best, best_score = i, score
        pruned.append(best)
        pruned_set.add(best)
    return pruned


# ---------------------------------------------------------------------------
# Whole-model expert pruning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExpertPruneReport:
    n_keep: int
    labels: List[np.ndarray]          # per layer [E]
    rep_idx: List[np.ndarray]         # per layer [n_keep]
    reconstructed: List[bool]         # per layer
    router_forward_passes: int = 0    # O(1) claim: stays 0 for λ2 == 0


def _layer_distance(router_layer, coact_layer, lam1, lam2):
    return behavioral_distance(router_layer, coact_layer, lam1, lam2)


def expert_prune_moe(params, cfg, ratio: float, *, kappa: int = 3,
                     lam1: float = 1.0, lam2: float = 0.0,
                     coact: Optional[np.ndarray] = None,
                     method: str = "agglomerative",
                     mode: str = "compact"):
    """Prune a fraction ``ratio`` of experts from every MoE layer.

    params: model param tree with scan-stacked layers (["layers"]["moe"]).
    coact: [L, E, E] coactivation counts (λ2 path) or None.
    mode: "compact" -> physically smaller arrays + updated cfg;
          "mask"    -> full-size arrays (reps possibly reconstructed) +
                       alive-mask [L, E] for router-mask evaluation.

    Returns (new_params, new_cfg, ExpertPruneReport).
    """
    assert cfg.family == "moe", cfg.family
    moe = params["layers"]["moe"]
    router = np.asarray(moe["router"], np.float32)      # [L, E, D]
    Lc, E, D = router.shape
    n_keep = max(1, int(round(E * (1.0 - ratio))))

    report = ExpertPruneReport(n_keep=n_keep, labels=[], rep_idx=[],
                               reconstructed=[])
    if lam2 != 0.0 and coact is not None:
        report.router_forward_passes = 1  # one calibration sweep total

    new_moe = {k: np.array(v, np.float32) if mode == "mask" else None
               for k, v in moe.items()}
    keep_mask = np.zeros((Lc, E), np.float32)
    compact = {k: [] for k in ("router", "we_gate", "we_up", "we_down")}

    for l in range(Lc):
        dist = _layer_distance(router[l], None if coact is None else coact[l],
                               lam1, lam2)
        labels = cluster_experts(dist, n_keep, method)
        flat = expert_flat_weights(moe, l)
        reps, reconstruct, means = representatives(flat, labels, kappa)
        report.labels.append(labels)
        report.rep_idx.append(reps)
        report.reconstructed.append(reconstruct)
        keep_mask[l, reps] = 1.0

        # gather representative weights (optionally cluster-mean reconstructed)
        sel = {}
        for key in ("we_gate", "we_up", "we_down"):
            w = np.asarray(moe[key][l], np.float32)      # [E, ...]
            out = w[reps].copy()
            if reconstruct:
                for c in range(len(reps)):
                    out[c] = w[labels == c].mean(axis=0)
            sel[key] = out
        r = router[l][reps].copy()
        if reconstruct:
            for c in range(len(reps)):
                r[c] = router[l][labels == c].mean(axis=0)
        sel["router"] = r

        if mode == "mask":
            for key in ("we_gate", "we_up", "we_down", "router"):
                tgt = new_moe[key]
                tgt[l, reps] = sel[key]
        else:
            for key in compact:
                compact[key].append(sel[key])

    if mode == "mask":
        new_params = _replace_moe(params, {k: v for k, v in new_moe.items()})
        return new_params, cfg, keep_mask, report

    new_params = _replace_moe(params, {k: np.stack(v) for k, v in
                                       compact.items()})
    new_cfg = dataclasses.replace(cfg, n_experts=n_keep,
                                  top_k=min(cfg.top_k, n_keep))
    return new_params, new_cfg, keep_mask, report


def _replace_moe(params, new_moe):
    out = dict(params)
    out["layers"] = dict(params["layers"])
    out["layers"]["moe"] = {**params["layers"]["moe"], **new_moe}
    return out


# ---------------------------------------------------------------------------
# Reconstruction loss (Eq. 4) — shared with the combinatorial baseline
# ---------------------------------------------------------------------------


def layer_reconstruction_loss(x, layer_moe_params, cfg, keep_mask,
                              replacement=None):
    """ℰ_S = ||M(x;θ) - M(x;θ-θ_S)||_F on a batch x [B,S,D] (Eq. 4).

    keep_mask [E] 1=alive.  ``replacement`` optionally swaps in
    reconstructed expert weights before masking.
    """
    import jax.numpy as jnp
    from repro.models.moe import moe_apply

    p = layer_moe_params if replacement is None else {**layer_moe_params,
                                                      **replacement}
    full = moe_apply(x, layer_moe_params, cfg)
    pruned = moe_apply(x, p, cfg, expert_mask=jnp.asarray(keep_mask))
    return float(jnp.linalg.norm((full - pruned).astype(jnp.float32)))
