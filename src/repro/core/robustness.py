"""Pruning-robustness probes (paper §5).

Kurtosis K(θ) = E[((θ-μ)/σ)^4] (Eq. 14) estimates how much further
unstructured pruning a network tolerates (Mason-Williams & Dahlqvist 2024).
The paper's claim, which `benchmarks/bench_kurtosis.py` and a property test
verify empirically on our models:
  * expert (structured) pruning  ≈ preserves kurtosis;
  * unstructured pruning         lowers kurtosis (pushes the weight
    distribution toward bimodal, the kurtosis minimum).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def kurtosis(w: np.ndarray, exclude_zeros: bool = False) -> float:
    x = np.asarray(w, np.float64).reshape(-1)
    if exclude_zeros:
        x = x[x != 0.0]
    if x.size < 4:
        return float("nan")
    mu, sigma = x.mean(), x.std()
    if sigma == 0:
        return float("nan")
    return float(np.mean(((x - mu) / sigma) ** 4))


def model_kurtosis(params, paths=("we_gate", "we_up", "we_down", "w_gate",
                                  "w_up", "w_down", "wq", "wk", "wv", "wo"),
                   exclude_zeros: bool = True) -> Dict[str, float]:
    """Kurtosis per prunable weight family, plus the aggregate.

    ``exclude_zeros`` measures the *surviving* weight distribution (the
    quantity §5's bimodality argument is about) so masked-out weights do not
    masquerade as a spike at zero.
    """
    out: Dict[str, float] = {}
    chunks = []

    def walk(tree, prefix=()):
        if hasattr(tree, "shape"):
            if prefix[-1] in paths:
                arr = np.asarray(tree, np.float32)
                out["/".join(map(str, prefix))] = kurtosis(
                    arr, exclude_zeros=exclude_zeros)
                chunks.append(arr.reshape(-1))
            return
        for k in tree:
            walk(tree[k], prefix + (k,))

    walk(params)
    if chunks:
        flat = np.concatenate(chunks)
        out["__all__"] = kurtosis(flat, exclude_zeros=exclude_zeros)
    return out
