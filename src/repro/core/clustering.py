"""Expert clustering (paper Alg. 1 + appendix DSatur alternative).

Agglomerative (complete linkage, faithful to Alg. 1): visit pairs in
increasing distance order while the closest unvisited pair is within the
threshold t; merge two clusters only if *every* cross-pair distance is
within t (the m_d / m_e check).  The threshold is tuned — here by binary
search — to hit the cluster count implied by the desired pruning ratio.

DSatur (appendix Eq. 15): clique partitioning — color the *complement*
graph (edges between DISsimilar pairs); color classes are cliques of
mutually-similar experts = clusters.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


def agglomerative_threshold(dist: np.ndarray, t: float) -> np.ndarray:
    """Alg. 1 body for a fixed threshold. dist [E,E] -> labels [E]."""
    E = dist.shape[0]
    d = dist.copy().astype(np.float64)
    iu = np.triu_indices(E, k=1)
    labels = np.arange(E)

    # visit pairs in increasing-distance order (argmin + mark-visited loop)
    order = np.argsort(d[iu], kind="stable")
    for idx in order:
        i, j = iu[0][idx], iu[1][idx]
        if d[i, j] >= t:
            break  # "while min b < t" termination
        ci, cj = labels[i], labels[j]
        if ci == cj:
            continue
        mi = np.max(dist[i, labels == cj])           # m_d: worst cross-dist
        mj = np.max(dist[np.ix_(labels == ci, [j])]) # m_e
        if max(mi, mj) < t:
            # complete-linkage safety: all pairs across both clusters
            cross = dist[np.ix_(labels == ci, labels == cj)]
            if cross.max() < t:
                labels[labels == cj] = ci
    # relabel to 0..n_clusters-1
    _, labels = np.unique(labels, return_inverse=True)
    return labels


def agglomerative_to_count(dist: np.ndarray, n_keep: int,
                           iters: int = 40) -> np.ndarray:
    """Binary-search the Alg. 1 threshold for a target cluster count.

    Merges are discrete, so an exact hit may be impossible; we return the
    labeling with count closest to (and never below) n_keep, then force down
    to exactly n_keep by merging the globally closest cluster pairs.
    """
    E = dist.shape[0]
    n_keep = int(min(max(n_keep, 1), E))
    lo, hi = 0.0, float(dist.max()) + 1e-9
    best = np.arange(E)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        labels = agglomerative_threshold(dist, mid)
        k = labels.max() + 1
        if k > n_keep:
            lo = mid        # too many clusters: raise threshold
            best = labels
        else:
            hi = mid
            if k == n_keep:
                return labels
    labels = best
    # force remaining merges by smallest complete-linkage distance
    while labels.max() + 1 > n_keep:
        k = labels.max() + 1
        bd, bp = np.inf, None
        for a in range(k):
            for b in range(a + 1, k):
                cross = dist[np.ix_(labels == a, labels == b)].max()
                if cross < bd:
                    bd, bp = cross, (a, b)
        a, b = bp
        labels[labels == b] = a
        _, labels = np.unique(labels, return_inverse=True)
    return labels


def dsatur_threshold(dist: np.ndarray, t: float) -> np.ndarray:
    """DSatur clique partitioning: color complement graph (dissimilar edges)."""
    import networkx as nx

    E = dist.shape[0]
    g = nx.Graph()
    g.add_nodes_from(range(E))
    for i in range(E):
        for j in range(i + 1, E):
            if dist[i, j] >= t:      # NOT similar enough -> complement edge
                g.add_edge(i, j)
    coloring = nx.coloring.greedy_color(g, strategy="DSATUR")
    labels = np.array([coloring[i] for i in range(E)])
    _, labels = np.unique(labels, return_inverse=True)
    return labels


def dsatur_to_count(dist: np.ndarray, n_keep: int, iters: int = 40) -> np.ndarray:
    E = dist.shape[0]
    n_keep = int(min(max(n_keep, 1), E))
    lo, hi = 0.0, float(dist.max()) + 1e-9
    best = np.arange(E)
    best_k = E
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        labels = dsatur_threshold(dist, mid)
        k = labels.max() + 1
        if k >= n_keep:
            lo = mid
            if k < best_k:
                best, best_k = labels, k
        else:
            hi = mid
    if best_k > n_keep:
        # greedy merge of smallest-max-cross-distance pairs to reach count
        labels = best
        while labels.max() + 1 > n_keep:
            k = labels.max() + 1
            bd, bp = np.inf, None
            for a in range(k):
                for b in range(a + 1, k):
                    cross = dist[np.ix_(labels == a, labels == b)].max()
                    if cross < bd:
                        bd, bp = cross, (a, b)
            a, b = bp
            labels[labels == b] = a
            _, labels = np.unique(labels, return_inverse=True)
        return labels
    return best


def cluster_experts(dist: np.ndarray, n_keep: int,
                    method: str = "agglomerative") -> np.ndarray:
    if method == "agglomerative":
        return agglomerative_to_count(dist, n_keep)
    if method == "dsatur":
        return dsatur_to_count(dist, n_keep)
    raise ValueError(method)
