"""Calibration pass: activation statistics, coactivations, layer inputs.

One instrumented (unrolled, per-layer) forward pass over calibration batches
collects everything the pruning stack consumes:
  * per-weight input-feature L2 norms  -> Wanda / OWL scores,
  * per-layer expert coactivation counts -> Eq. 10 (λ2 path),
  * per-layer MoE block inputs           -> Lu et al. combinatorial baseline.

Runs on small/reduced models (the paper's calibration uses 128–1000 C4
samples); the production-scale path only ever needs router weights (λ2=0,
the O(1) no-forward-pass mode).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (apply_rope, attention, rmsnorm, rope_tables,
                                 swiglu)
from repro.models.recurrent import recurrent_block
from repro.models.ssm import mamba_mixer


class CalibStats:
    """Accumulates sum-of-squares activation stats + coactivation counts."""

    def __init__(self):
        self.sumsq: Dict[Tuple[int, str], np.ndarray] = {}
        self.coact: Dict[int, np.ndarray] = {}
        self.layer_inputs: Dict[int, List[np.ndarray]] = {}
        self.tokens_seen = 0

    def tap(self, layer: int, name: str, x):
        ss = np.asarray(jnp.sum(x.astype(jnp.float32) ** 2,
                                axis=tuple(range(x.ndim - 1))))
        key = (layer, name)
        self.sumsq[key] = self.sumsq.get(key, 0.0) + ss

    def tap_expert(self, layer: int, name: str, x_flat, sel_onehot):
        """Per-expert stats: x [T, D], sel [T, E] 0/1."""
        ss = np.asarray(jnp.einsum("te,td->ed", sel_onehot,
                                   x_flat.astype(jnp.float32) ** 2))
        key = (layer, name)
        self.sumsq[key] = self.sumsq.get(key, 0.0) + ss

    def tap_coact(self, layer: int, top_idx, n_experts: int):
        from repro.core.similarity import coactivation_counts
        a = coactivation_counts(np.asarray(top_idx).reshape(-1,
                                                            top_idx.shape[-1]),
                                n_experts)
        self.coact[layer] = self.coact.get(layer, 0.0) + a

    def tap_input(self, layer: int, x):
        self.layer_inputs.setdefault(layer, []).append(np.asarray(x))

    def norms(self) -> Dict[Tuple[int, str], np.ndarray]:
        return {k: np.sqrt(v) for k, v in self.sumsq.items()}


def _attn_tapped(x, p, cfg, sin, cos, pos, stats, l, window=None):
    stats.tap(l, "attn_in", x)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    o = attention(q, k, v, pos, pos, impl="naive", window=window,
                  softcap=cfg.attn_logit_softcap, chunk=cfg.attn_chunk)
    stats.tap(l, "attn_out", o.reshape(o.shape[0], o.shape[1], -1))
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _mlp_tapped(x, p, stats, l, prefix="mlp"):
    stats.tap(l, f"{prefix}_in", x)
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    stats.tap(l, f"{prefix}_mid", h)
    return h @ p["w_down"]


def _moe_tapped(x, p, cfg, stats, l, collect_inputs=False):
    B, S, D = x.shape
    if collect_inputs:
        stats.tap_input(l, x)
    stats.tap(l, "moe_in", x)
    x_flat = x.reshape(-1, D)
    logits = jnp.einsum("td,ed->te", x_flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    stats.tap_coact(l, top_i, cfg.n_experts)
    sel = jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts, dtype=jnp.float32),
                  axis=1)                                      # [T,E]
    stats.tap_expert(l, "moe_expert_in", x_flat, sel)
    # dense-expert compute (calibration models are tiny)
    g = jnp.einsum("td,edf->tef", x_flat, p["we_gate"])
    u = jnp.einsum("td,edf->tef", x_flat, p["we_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u  # [T,E,Fe]
    stats.sumsq[(l, "moe_expert_mid")] = stats.sumsq.get(
        (l, "moe_expert_mid"), 0.0) + np.asarray(
        jnp.einsum("te,tef->ef", sel, h.astype(jnp.float32) ** 2))
    y = jnp.einsum("tef,efd->ted", h, p["we_down"])
    gate = jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts, dtype=jnp.float32)
                   * top_p[..., None], axis=1)                 # [T,E]
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), gate)
    out = out.astype(x.dtype).reshape(B, S, D)
    if cfg.shared_expert:
        out = out + swiglu(x, p["shared_gate"], p["shared_up"],
                           p["shared_down"])
    return out


def _ssm_tapped(x, p, cfg, stats, l):
    stats.tap(l, "ssm_in", x)
    # re-run pieces for intermediate taps
    di = cfg.d_inner
    xz = x @ p["w_in"]
    xs = xz[..., :di]
    from repro.models.ssm import causal_conv1d
    xs_c, _ = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xs_act = jax.nn.silu(xs_c.astype(jnp.float32)).astype(x.dtype)
    stats.tap(l, "ssm_x", xs_act)
    R = cfg.dt_rank_actual
    dt = (xs_act @ p["w_x"])[..., :R]
    stats.tap(l, "ssm_dt", dt)
    y, _ = mamba_mixer(x, p, cfg)
    # w_out input ~ gated y before projection; approximate with xs_act scale
    stats.tap(l, "ssm_out", xs_act)
    return y


def _rec_tapped(x, p, cfg, stats, l):
    stats.tap(l, "rec_in", x)
    from repro.models.ssm import causal_conv1d
    u = x @ p["w_in"]
    u_c, _ = causal_conv1d(u, p["conv_w"], p["conv_b"])
    stats.tap(l, "rec_gates", u_c)
    from repro.models.recurrent import rg_lru
    h, _ = rg_lru(u_c, p, cfg)
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    stats.tap(l, "rec_out", h * gate)
    return (h * gate) @ p["w_out"]


def _layer_params(params, cfg, l: int):
    layers = params["layers"]
    if cfg.family == "hybrid" or not cfg.scan_layers:
        return layers[str(l)]
    return jax.tree.map(lambda w: w[l], layers)


def instrumented_forward(params, cfg, batch, stats: CalibStats,
                         collect_inputs: bool = False):
    """Unrolled forward collecting calibration statistics; returns logits."""
    if "embeds" in batch:
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        h = params["embed"][batch["tokens"]]
    B, S, D = h.shape
    pos = jnp.arange(S)
    sin, cos = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    stats.tokens_seen += B * S
    pat = cfg.effective_pattern()
    for l, kind in enumerate(pat):
        p = _layer_params(params, cfg, l)
        xn = rmsnorm(h, p["ln1"], cfg.norm_eps)
        if kind == "ssm":
            h = h + _ssm_tapped(xn, p["ssm"], cfg, stats, l)
            continue
        if kind == "rec":
            h = h + _rec_tapped(xn, p["rec"], cfg, stats, l)
        else:  # attn
            window = cfg.local_window if cfg.family == "hybrid" else None
            h = h + _attn_tapped(xn, p["attn"], cfg, sin, cos, pos, stats, l,
                                 window=window)
        x2 = rmsnorm(h, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            h = h + _moe_tapped(x2, p["moe"], cfg, stats, l,
                                collect_inputs=collect_inputs)
        else:
            h = h + _mlp_tapped(x2, p["mlp"], stats, l)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", h, head)


def run_calibration(params, cfg, batches, collect_inputs: bool = False
                    ) -> CalibStats:
    stats = CalibStats()
    for batch in batches:
        instrumented_forward(params, cfg, batch, stats,
                             collect_inputs=collect_inputs)
    return stats


def coactivation_tensor(stats: CalibStats, cfg) -> Optional[np.ndarray]:
    if not stats.coact:
        return None
    L = cfg.n_layers
    return np.stack([stats.coact[l] for l in range(L)])


def moe_layer_inputs(stats: CalibStats, cfg) -> np.ndarray:
    """[L, B*, S, D] concatenated MoE-block inputs for the combinatorial
    baseline."""
    L = cfg.n_layers
    return np.stack([np.concatenate(stats.layer_inputs[l], axis=0)
                     for l in range(L)])
