"""Lu et al. (2024) combinatorial expert pruning — the O(k^n/√n) baseline.

Per layer, enumerate every C(n, n_prune) expert subset, evaluate the
reconstruction loss ℰ_S (Eq. 4) with router renormalization over survivors,
keep the argmin.  Each subset evaluation is one forward pass of the layer on
the calibration batch — we count them to substantiate the paper's cost
comparison (Table 2 "cost" column).
"""
from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np


def combinatorial_prune_layer(x, layer_moe_params, cfg, n_prune: int
                              ) -> Tuple[np.ndarray, float, int]:
    """Returns (keep_mask [E], best ℰ_S, forward_pass_count)."""
    import jax
    import jax.numpy as jnp
    from repro.models.moe import moe_apply

    E = cfg.n_experts
    full = moe_apply(x, layer_moe_params, cfg)

    @jax.jit
    def recon(mask):
        pruned = moe_apply(x, layer_moe_params, cfg, expert_mask=mask)
        return jnp.linalg.norm((full - pruned).astype(jnp.float32))

    best_loss, best_mask = np.inf, None
    n_calls = 0
    for S in itertools.combinations(range(E), n_prune):
        mask = np.ones(E, np.float32)
        mask[list(S)] = 0.0
        loss = float(recon(jnp.asarray(mask)))
        n_calls += 1
        if loss < best_loss:
            best_loss, best_mask = loss, mask
    return best_mask, best_loss, n_calls


def combinatorial_prune(params, cfg, x_per_layer, ratio: float):
    """Whole-model variant: independent per-layer exhaustive search.

    x_per_layer: [L, B, S, D] layer inputs captured from a calibration
    forward pass.  Returns (keep_mask [L, E], total_forward_passes).
    """
    E = cfg.n_experts
    n_prune = E - max(1, int(round(E * (1.0 - ratio))))
    L = cfg.n_layers
    masks, total = [], 0
    for l in range(L):
        import jax
        lp = jax.tree.map(lambda w: w[l], params["layers"]["moe"])
        m, _, c = combinatorial_prune_layer(x_per_layer[l], lp, cfg, n_prune)
        masks.append(m)
        total += c
    return np.stack(masks), total


def n_combinations(n: int, phi: float) -> float:
    """The paper's O(k^n/√n) count: C(n, φn) forward passes per layer."""
    from math import comb
    return comb(n, int(round(phi * n)))
