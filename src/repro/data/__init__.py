from repro.data.synthetic import (  # noqa: F401
    SyntheticLM,
    calibration_batches,
    make_batch,
)
