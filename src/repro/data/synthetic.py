"""Deterministic synthetic LM data pipeline (C4 stand-in).

Design goals (DESIGN.md §8):
  * *stateless-resumable*: ``batch = f(seed, step)`` is a pure function, so
    restart/elastic-rescale needs no data-state checkpoint and stragglers
    cannot skew the stream;
  * *learnable*: tokens follow a fixed random order-1 Markov chain mixed
    with a Zipf unigram — a tiny model trained on it visibly separates good
    from bad pruning (the benchmarks' GSM8K/C4 analogue);
  * matches the paper's calibration protocol shape-wise (128–1000 samples,
    2048–4096 seq len) at reduced scale.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """A fixed random Markov language over `vocab` tokens."""
    vocab: int
    seed: int = 0
    branching: int = 8          # successors per token
    zipf_a: float = 1.2
    mix: float = 0.85           # P(markov) vs P(unigram noise)

    def __post_init__(self):
        rs = np.random.RandomState(self.seed)
        self.successors = rs.randint(0, self.vocab,
                                     size=(self.vocab, self.branching))
        probs = rs.dirichlet(np.ones(self.branching) * 0.5,
                             size=self.vocab)
        self.succ_probs = probs.astype(np.float64)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        z = ranks ** (-self.zipf_a)
        self.unigram = z / z.sum()

    def entropy_floor(self) -> float:
        """Approximate per-token entropy of the Markov component (nats)."""
        h = -np.sum(self.succ_probs * np.log(self.succ_probs + 1e-12),
                    axis=1)
        return float(self.mix * h.mean()
                     - (1 - self.mix) * np.log(1.0 / self.vocab))

    def sample(self, batch: int, seq: int, step: int) -> np.ndarray:
        rs = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        out = np.empty((batch, seq + 1), np.int64)
        cur = rs.choice(self.vocab, size=batch, p=self.unigram)
        out[:, 0] = cur
        for t in range(1, seq + 1):
            use_markov = rs.rand(batch) < self.mix
            # markov step: pick successor by per-token distribution
            u = rs.rand(batch)
            cdf = np.cumsum(self.succ_probs[cur], axis=1)
            idx = (u[:, None] > cdf).sum(axis=1).clip(0, self.branching - 1)
            nxt_markov = self.successors[cur, idx]
            nxt_noise = rs.choice(self.vocab, size=batch, p=self.unigram)
            cur = np.where(use_markov, nxt_markov, nxt_noise)
            out[:, t] = cur
        return out


def make_batch(lm: SyntheticLM, batch: int, seq: int, step: int,
               d_model: int = 0, frontend_stub: bool = False) -> dict:
    """(seed, step) -> batch dict. Pure & deterministic."""
    toks = lm.sample(batch, seq, step)
    inputs = jnp.asarray(toks[:, :-1], jnp.int32)
    labels = jnp.asarray(toks[:, 1:], jnp.int32)
    if frontend_stub:
        # modality frontend stub: deterministic pseudo-embeddings per token
        key = jax.random.fold_in(jax.random.PRNGKey(lm.seed), step)
        table = jax.random.normal(key, (lm.vocab, d_model), jnp.bfloat16) * 0.1
        return {"embeds": table[inputs], "labels": labels}
    return {"tokens": inputs, "labels": labels}


def calibration_batches(cfg, n_batches: int = 4, batch: int = 2,
                        seq: int = 64, seed: int = 1234) -> List[dict]:
    """Calibration set for Wanda/OWL/coactivation (paper: C4 samples)."""
    lm = SyntheticLM(vocab=cfg.vocab, seed=seed)
    return [make_batch(lm, batch, seq, step=i, d_model=cfg.d_model,
                       frontend_stub=cfg.frontend_stub)
            for i in range(n_batches)]


def batch_iterator(cfg, batch: int, seq: int, seed: int = 0,
                   start_step: int = 0) -> Iterator[dict]:
    lm = SyntheticLM(vocab=cfg.vocab, seed=seed)
    step = start_step
    while True:
        yield make_batch(lm, batch, seq, step, d_model=cfg.d_model,
                         frontend_stub=cfg.frontend_stub)
        step += 1
