"""Dispatch-safety static analysis for the serving stack.

The same bug class bit this repo twice — PR 1's ``SlotKVCache.seq_lens``
zero-copy race and PR 4's alignment-dependent numpy<->jax aliasing in
device views — each found late, by a randomized stress oracle, after
shipping.  This package turns that bug class (and its neighbours) into
lint-time findings and deterministic test failures:

  * :mod:`repro.analysis.core` — the shared AST visitor / reporting
    core: :class:`Finding`, :class:`Checker`, per-line
    ``# repro-lint: disable=<check> -- <why>`` suppressions, and the
    ``analyze_source`` / ``analyze_file`` drivers.  Pure stdlib ``ast``;
    importing this package pulls in no jax/numpy.
  * :mod:`repro.analysis.aliasing` — **aliasing-hazard**: mutable
    ``np.ndarray`` attributes aliased into device arrays (or handed to
    jitted callables) without a ``.copy()`` snapshot — the exact
    PR-1/PR-4 pattern.
  * :mod:`repro.analysis.jit` — **jit-discipline**: bad
    ``static_argnums``/``static_argnames`` (unknown names, out-of-range
    nums, unhashable defaults), Python-side mutation of captured state
    inside jitted bodies, shape-dependent Python branches that retrace.
  * :mod:`repro.analysis.pallas` — **pallas-invariants**: BlockSpec
    index-map arity vs grid + scalar-prefetch count, index maps that
    read anything but prefetched scalars, literal grid/BlockSpec
    divisibility, version-skew Pallas symbols used outside
    ``kernels/compat.py`` (the shim registry the checker consumes via
    ``compat.capabilities()``).
  * :mod:`repro.analysis.dtype` — **dtype-discipline**: sub-fp32
    (f8/bf16/f16) boundary crossings into accumulating ops without an
    explicit cast site in ``serving/`` and ``sparse/``.
  * :mod:`repro.analysis.timing` — **timing-discipline**: ``time.time()``
    in serving/bench/launch code (wall clocks are not monotonic), and
    latency windows whose closing stamp spans a device dispatch with no
    host fence — async dispatch makes such windows measure enqueue
    overhead, not device time.
  * :mod:`repro.analysis.sanitizer` — the runtime half: version-stamped
    buffer guards (``REPRO_SANITIZE=1``) that turn a mutate-while-
    aliased race from an alignment-dependent coin flip into a
    deterministic :class:`DispatchRaceError`.  Imported lazily (needs
    numpy) — ``from repro.analysis import sanitizer``.

``tools/lint_repro.py`` is the CLI; ``make lint`` runs it over ``src/``
in strict mode.  See docs/analysis.md for the checker catalog and how to
add a checker.
"""
from repro.analysis.core import (Checker, Finding, SourceFile,
                                 all_checkers, analyze_file,
                                 analyze_source, checkers_for)

__all__ = ["Checker", "Finding", "SourceFile", "all_checkers",
           "analyze_file", "analyze_source", "checkers_for"]
