"""Dispatch-race sanitizer: version-stamped guards on host cache state.

The PR-1/PR-4 bug class at runtime: ``jnp.asarray`` of an aligned numpy
buffer can be **zero-copy** on CPU, so an async dispatch reads whatever
the host buffer holds when the dispatch *executes* — and the serving
loop mutates ``seq_lens`` / ``page_table`` right after submitting.  The
failure is an alignment-/timing-dependent coin flip: wrong tokens in
~half of runs, clean in the rest.

With ``REPRO_SANITIZE=1`` the caches wrap their mutable host buffers in
a version-stamped guard (:func:`guard`) and every dispatch-bound host
array goes through :func:`device_view`.  The rule is the conservative
worst case and therefore **deterministic**:

  * ``device_view(x)`` of a *live guarded buffer* (not a ``.copy()``
    snapshot) records a zero-copy alias against the buffer's guard —
    whether or not jax actually aliased it on this run.
  * any later in-place mutation of that buffer
    (``x[i] = ...``, ``x.fill(...)``) raises :class:`DispatchRaceError`
    naming the owning array: the dispatch submitted with the alias may
    read the post-mutation bytes.

Correct code always hands jax a private ``.copy()`` snapshot
(``__array_finalize__`` strips the guard from copies, keeps it on
views), so a healthy tree never registers an alias and the sanitizer is
pure bookkeeping.  Removing a ``.copy()`` — the exact PR-4 regression —
turns the first post-dispatch mutation into a hard failure on every
run, instead of a stress-oracle coin flip.  The static half of this
defense is the ``aliasing-hazard`` lint checker; the sanitizer catches
what syntax can't see (helpers, indirection, new call sites).

Zero overhead when disabled: :func:`guard` returns the array unchanged
and :func:`device_view` is ``jnp.asarray``.
"""
from __future__ import annotations

import os
from typing import List, Optional

import numpy as np

# jnp import is deferred so pure-host tooling (and the lint CLI's
# import of repro.analysis) never pays for jax
_jnp = None


class DispatchRaceError(RuntimeError):
    """A guarded host buffer was mutated while a device view built from
    its live (un-snapshotted) memory may still be read by a dispatch."""


_FORCED: Optional[bool] = None     # enable()/disable() override for tests


def enabled() -> bool:
    """Sanitizer switch: ``REPRO_SANITIZE=1`` (or a test override)."""
    if _FORCED is not None:
        return _FORCED
    return os.environ.get("REPRO_SANITIZE", "") == "1"


def enable(on: bool = True):
    """Force the sanitizer on/off for this process (tests)."""
    global _FORCED
    _FORCED = on


def clear_override():
    global _FORCED
    _FORCED = None


class BufferGuard:
    """Version stamp + live-alias registry for one host buffer.

    ``version`` counts in-place mutations; ``aliases`` records the
    versions at which the buffer was handed zero-copy to a device view.
    The records live on the guard (not a global), so they are reclaimed
    with the buffer.
    """

    __slots__ = ("name", "version", "aliases")

    def __init__(self, name: str):
        self.name = name
        self.version = 0
        self.aliases: List[int] = []

    def on_alias(self):
        self.aliases.append(self.version)

    def on_mutate(self):
        self.version += 1
        if self.aliases:
            raise DispatchRaceError(
                f"host buffer '{self.name}' mutated (version "
                f"{self.version}) while {len(self.aliases)} zero-copy "
                f"device view(s) of its live memory exist (first taken at "
                f"version {self.aliases[0]}) — a dispatch submitted with "
                f"that view may read the post-mutation bytes.  Hand jax a "
                f"private .copy() snapshot instead of the live buffer "
                f"(see docs/analysis.md, aliasing-hazard).")


class GuardedArray(np.ndarray):
    """ndarray subclass whose in-place writes notify a
    :class:`BufferGuard`.

    Views (slices, reshapes — memory-sharing) inherit the parent's
    guard; copies (``.copy()``, fancy indexing — fresh memory) drop it.
    Only ``__setitem__`` and ``fill`` are intercepted: that is how the
    serving stack mutates its bookkeeping arrays, and the documented
    contract for guarded buffers.
    """

    _guard: Optional[BufferGuard]

    def __array_finalize__(self, obj):
        # fresh memory (base None) -> no guard; memory-sharing view ->
        # inherit the parent's guard so mutation through any view trips
        self._guard = (getattr(obj, "_guard", None)
                       if self.base is not None else None)

    def __setitem__(self, key, value):
        g = self._guard
        if g is not None:
            g.on_mutate()
        super().__setitem__(key, value)

    def fill(self, value):
        g = self._guard
        if g is not None:
            g.on_mutate()
        super().fill(value)


def guard(arr: np.ndarray, name: str) -> np.ndarray:
    """Wrap ``arr`` in a version-stamped guard when sanitizing.

    Returns ``arr`` unchanged when the sanitizer is off — callers keep
    one code path and pay nothing in production.
    """
    if not enabled():
        return arr
    g = np.asarray(arr).view(GuardedArray)
    g._guard = BufferGuard(name)
    return g


def guard_of(arr) -> Optional[BufferGuard]:
    return getattr(arr, "_guard", None)


def device_view(arr):
    """``jnp.asarray`` that tracks zero-copy aliases of guarded buffers.

    A ``.copy()`` snapshot (guard stripped by ``__array_finalize__``)
    passes straight through; a live guarded buffer registers an alias so
    any later mutation raises deterministically.  The conversion itself
    is unchanged — the sanitizer observes, it does not fix: the failure
    points at the call site that should have snapshotted.
    """
    global _jnp
    if _jnp is None:
        import jax.numpy as jnp
        _jnp = jnp
    g = guard_of(arr)
    if g is not None:
        g.on_alias()
    return _jnp.asarray(arr)


def release(arr):
    """Drop alias records for ``arr``'s guard — for callers that have
    *proven* every dispatch holding a view has completed (e.g. after a
    blocking materialization of all step outputs).  The serving stack
    never needs this (it snapshots instead); provided for harnesses."""
    g = guard_of(arr)
    if g is not None:
        g.aliases.clear()
