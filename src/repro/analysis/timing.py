"""timing-discipline: wall-clock hygiene in serving, bench and launch code.

Two families of findings, both born from real latency-accounting bugs:

  * **wrong clock** — any ``time.time()`` call site.  Wall time is not
    monotonic (NTP slews it, VMs step it), so latency windows computed
    from it can go negative or jump by seconds.  Every serving/bench
    timestamp must come from ``time.monotonic()`` (cross-request
    timelines) or ``time.perf_counter()`` (micro-benchmarks).
  * **timing window over an un-fenced dispatch** — a
    ``monotonic()``/``perf_counter()`` stamp, then a device dispatch,
    then a second stamp with **no host synchronization between the
    dispatch and the closing stamp**.  JAX dispatch is asynchronous: the
    call returns as soon as the work is enqueued, so the window measures
    dispatch overhead, not device time — the classic
    "my decode step takes 40us" lie.  A fence is anything that forces
    the result to host: ``np.asarray(...)``, ``jax.block_until_ready``,
    ``jax.device_get``, ``.block_until_ready()``, or a scalar coercion
    (``int(...)`` / ``float(...)``).

Dispatches are recognized structurally: calls through the engine's
jitted attribute slots (``self._decode(...)``, ``self._prefill(...)``,
``self._draft``/``_verify``/``_sample``/``_fork_fn``, ...) and calls of
local names bound from ``jax.jit(...)``.  High-level engine entry points
(``.generate()``, ``.step()``) are deliberately *not* dispatches — they
fence internally (tokens are materialized before they return), so timing
them is exactly what an SLO bench should do.

Events are collected in **post-order** (children before parents), which
matches evaluation order for nested calls — in
``jax.block_until_ready(fn(x))`` the dispatch is seen before the fence,
and in ``sched.on_token(rid, int(tok), time.monotonic())`` the scalar
coercion fences before the stamp is taken.  Control flow is linearized
(a loop body is scanned once), which errs toward silence — lint-level
precision, no false positives from cross-iteration windows.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Checker, Finding, SourceFile, call_name

STAMP_NAMES = {
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "monotonic", "monotonic_ns", "perf_counter", "perf_counter_ns",
}
FENCE_NAMES = {
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "jax.block_until_ready", "block_until_ready",
    "jax.device_get", "device_get",
    "int", "float",
}
# jitted attribute slots assigned in ServeEngine/__init__ paths — calls
# through these enqueue device work and return immediately
DISPATCH_ATTRS = {
    "_decode", "_prefill", "_draft", "_verify", "_sample",
    "_decode_uniform", "_fork_fn",
}


def _jit_locals(tree: ast.AST) -> Set[str]:
    """Names bound (anywhere in the file) from a ``jax.jit(...)`` call —
    calling one is a dispatch."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and call_name(node.value) in ("jax.jit", "jit"):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


class TimingDisciplineChecker(Checker):
    name = "timing-discipline"
    severity = "error"
    paths = ("serving/", "benchmarks/", "launch/")

    def check(self, src: SourceFile) -> Iterator[Finding]:
        jit_locals = _jit_locals(src.tree)
        # wrong clock: anywhere in the file, including nested scopes
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) and call_name(node) == "time.time":
                yield self.finding(
                    src, node, "time.time() is not monotonic — NTP slews "
                    "and VM clock steps corrupt latency windows; use "
                    "time.monotonic() (timelines) or time.perf_counter() "
                    "(micro-benchmarks)")
        # un-fenced windows: one linear scan per function scope
        for fn in ast.walk(src.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_scope(src, fn.body, jit_locals)
        if isinstance(src.tree, ast.Module):
            yield from self._check_scope(src, src.tree.body, jit_locals)

    # -- event collection --------------------------------------------------
    def _classify(self, node: ast.Call,
                  jit_locals: Set[str]) -> Optional[str]:
        name = call_name(node)
        if name in STAMP_NAMES:
            return "stamp"
        if name in FENCE_NAMES:
            return "fence"
        if isinstance(node.func, ast.Attribute):
            if node.func.attr == "block_until_ready":
                return "fence"
            if node.func.attr in DISPATCH_ATTRS:
                return "dispatch"
        if isinstance(node.func, ast.Name) and node.func.id in jit_locals:
            return "dispatch"
        return None

    def _events(self, body, jit_locals: Set[str]
                ) -> List[Tuple[str, ast.Call]]:
        events: List[Tuple[str, ast.Call]] = []

        def visit(node):
            # nested scopes are scanned as their own windows
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                return
            for child in ast.iter_child_nodes(node):
                visit(child)
            if isinstance(node, ast.Call):
                kind = self._classify(node, jit_locals)
                if kind is not None:
                    events.append((kind, node))

        for stmt in body:
            visit(stmt)
        return events

    # -- window scan -------------------------------------------------------
    def _check_scope(self, src: SourceFile, body,
                     jit_locals: Set[str]) -> Iterator[Finding]:
        seen_stamp = False
        pending: Optional[ast.Call] = None
        for kind, node in self._events(body, jit_locals):
            if kind == "stamp":
                if seen_stamp and pending is not None:
                    yield self.finding(
                        src, pending,
                        f"timing window (closed by the stamp at line "
                        f"{node.lineno}) spans this dispatch with no fence "
                        f"— async dispatch returns before the device "
                        f"finishes, so the window measures enqueue "
                        f"overhead; materialize the result "
                        f"(np.asarray / block_until_ready / int(...)) "
                        f"before the closing stamp, or record it via a "
                        f"telemetry Span with fence_rate > 0")
                seen_stamp = True
                pending = None
            elif kind == "dispatch":
                if seen_stamp and pending is None:
                    pending = node
            else:  # fence
                pending = None
