"""Shared visitor/reporting core for the repro-lint checkers.

Everything here is stdlib-only (``ast`` + ``re``): the lint CLI must run
in a bare CI job and must never need the heavyweight runtime deps of the
code it checks.

A checker is a small class over this core: it names itself, declares a
default severity and (optionally) the path fragments it applies to, and
implements ``check(src)`` yielding :class:`Finding`s.  The driver
(:func:`analyze_source` / :func:`analyze_file`) parses once, runs every
applicable checker, and applies the suppression comments.

Suppression syntax (one per line, checked by CI for a justification)::

    hazardous_line()  # repro-lint: disable=aliasing-hazard -- why it's safe

    # repro-lint: disable=jit-discipline,dtype-discipline -- spans next line
    hazardous_line()

A trailing comment suppresses findings on its own line; a comment alone
on a line also covers the following line.  A disable comment *without*
the ``-- <justification>`` tail is itself reported as an
``unexplained-suppression`` error (which cannot be suppressed), so the
tree ships with zero unexplained suppressions by construction.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

SEVERITIES = ("error", "warning")

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,-]+)(?:\s*--\s*(\S.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit: a file/line, the checker that fired, and why."""
    check: str
    severity: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}: [{self.severity}] "
                f"{self.check}: {self.message}")


class SourceFile:
    """One parsed python source: text, line table, AST, suppressions."""

    def __init__(self, path: str, text: str):
        self.path = path.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[Finding] = None
        try:
            self.tree = ast.parse(text, filename=path)
        except SyntaxError as e:
            self.parse_error = Finding(
                "parse-error", "error", self.path, e.lineno or 1,
                f"file does not parse: {e.msg}")
        # line -> suppressed check names; a comment-only line also covers
        # the next line (the statement it annotates)
        self._suppress: Dict[int, Set[str]] = {}
        self._unexplained: List[Finding] = []
        for i, raw in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(raw)
            if not m:
                continue
            checks = {c.strip() for c in m.group(1).split(",") if c.strip()}
            self._suppress.setdefault(i, set()).update(checks)
            if raw.lstrip().startswith("#"):
                self._suppress.setdefault(i + 1, set()).update(checks)
            if not m.group(2):
                self._unexplained.append(Finding(
                    "unexplained-suppression", "error", self.path, i,
                    "suppression without a justification: append "
                    "'-- <why this is safe>'"))

    def suppressed(self, check: str, line: int) -> bool:
        return check in self._suppress.get(line, ())

    def unexplained_suppressions(self) -> List[Finding]:
        return list(self._unexplained)


class Checker:
    """Base class: subclasses set ``name``/``severity``/``paths`` and
    implement :meth:`check`.

    ``paths`` is a tuple of path fragments (e.g. ``("kernels/",)``): the
    checker only runs on files whose path contains one of them; empty
    means every file.
    """
    name: str = "checker"
    severity: str = "error"
    paths: Sequence[str] = ()

    @classmethod
    def applies_to(cls, path: str) -> bool:
        path = path.replace("\\", "/")
        return not cls.paths or any(p in path for p in cls.paths)

    def check(self, src: SourceFile) -> Iterator[Finding]:
        raise NotImplementedError
        yield  # pragma: no cover

    # -- reporting helper -------------------------------------------------
    def finding(self, src: SourceFile, node, message: str,
                severity: Optional[str] = None) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 1)
        sev = severity or self.severity
        assert sev in SEVERITIES, sev
        return Finding(self.name, sev, src.path, line, message)


def all_checkers() -> List[type]:
    """Every registered checker class (imported lazily to keep
    ``repro.analysis`` import-light and cycle-free)."""
    from repro.analysis.aliasing import AliasingHazardChecker
    from repro.analysis.dtype import DtypeDisciplineChecker
    from repro.analysis.jit import JitDisciplineChecker
    from repro.analysis.pallas import PallasInvariantsChecker
    from repro.analysis.timing import TimingDisciplineChecker
    return [AliasingHazardChecker, JitDisciplineChecker,
            PallasInvariantsChecker, DtypeDisciplineChecker,
            TimingDisciplineChecker]


def checkers_for(path: str,
                 checkers: Optional[Iterable[type]] = None) -> List[Checker]:
    return [cls() for cls in (checkers or all_checkers())
            if cls.applies_to(path)]


def analyze_source(text: str, path: str = "<string>",
                   checkers: Optional[Iterable] = None) -> List[Finding]:
    """Run checkers over one source string; returns surviving findings.

    ``checkers`` may be classes or instances; defaults to every
    registered checker applicable to ``path``.  Suppressed findings are
    dropped; unexplained suppression comments are appended as findings.
    """
    src = SourceFile(path, text)
    if src.parse_error is not None:
        return [src.parse_error]
    insts: List[Checker] = []
    for c in (checkers if checkers is not None else all_checkers()):
        inst = c() if isinstance(c, type) else c
        if type(inst).applies_to(path):
            insts.append(inst)
    out: List[Finding] = []
    for inst in insts:
        for f in inst.check(src):
            if not src.suppressed(f.check, f.line):
                out.append(f)
    out.extend(src.unexplained_suppressions())
    out.sort(key=lambda f: (f.path, f.line, f.check))
    return out


def analyze_file(path, checkers: Optional[Iterable] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    return analyze_source(text, str(path), checkers)


# ---------------------------------------------------------------------------
# small AST utilities shared by the checkers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c" for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def int_literal(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    return None


def tuple_elts(node: ast.AST) -> Optional[List[ast.AST]]:
    """Elements of a tuple/list literal, else None (symbolic)."""
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return None


def jit_decorations(fn: ast.AST) -> List[ast.Call]:
    """``jax.jit`` decorator call sites on a function def.

    Matches ``@jax.jit``, ``@jit``, ``@functools.partial(jax.jit, ...)``
    and ``@partial(jax.jit, ...)``; returns the Call nodes carrying the
    static_argnums/static_argnames keywords (bare ``@jax.jit`` yields a
    synthetic empty-call marker is NOT needed — callers test truthiness
    of the list and read keywords off each call).
    """
    out: List[ast.Call] = []
    for dec in getattr(fn, "decorator_list", []):
        if dotted_name(dec) in ("jax.jit", "jit"):
            out.append(ast.Call(func=dec, args=[], keywords=[]))
            continue
        if isinstance(dec, ast.Call):
            name = call_name(dec)
            if name in ("jax.jit", "jit"):
                out.append(dec)
            elif name in ("functools.partial", "partial") and dec.args \
                    and dotted_name(dec.args[0]) in ("jax.jit", "jit"):
                out.append(dec)
    return out


def lambda_or_def_params(fn) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args]
    if a.vararg:
        names.append(a.vararg.arg)
    names += [p.arg for p in a.kwonlyargs]
    return names
