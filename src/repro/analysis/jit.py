"""jit-discipline: static-argument hygiene and trace-time side effects.

Three families of findings on ``@jax.jit`` /
``@functools.partial(jax.jit, ...)`` functions (and ``jax.jit(f, ...)``
call sites that can be resolved statically):

  * **bad static arguments** — ``static_argnames`` naming a parameter
    the wrapped signature does not have (a typo silently traces the
    argument instead of specializing on it), ``static_argnums`` out of
    the positional range or negative, and static parameters whose
    *default* is unhashable / array-valued (lists, dicts, sets,
    ``np.array(...)``) — jit raises on these only at call time, or
    worse, retraces per call.
  * **trace-time mutation** — Python-side writes to captured state
    inside a jitted body (``self.x = ...``, ``captured[k] = ...``,
    ``captured.append(...)``, ``global``/``nonlocal``): they run once at
    trace time, then silently never again.
  * **shape-dependent branches** (warning) — ``if``/``while`` tests
    reading ``<traced-param>.shape``: legal, but every new shape
    silently retraces the whole function; hoist to a static argument if
    the branch is intentional.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from repro.analysis.core import (Checker, Finding, SourceFile, call_name,
                                 int_literal, jit_decorations, keyword_arg,
                                 lambda_or_def_params, tuple_elts)

MUTATORS = {"append", "extend", "insert", "update", "setdefault", "pop",
            "popitem", "clear", "remove", "add", "discard", "sort",
            "reverse", "fill"}
UNHASHABLE_DEFAULTS = (ast.List, ast.Dict, ast.Set)
ARRAY_CTOR_HEADS = ("np.", "numpy.", "jnp.", "jax.numpy.")


def _str_items(node: ast.AST) -> Optional[List[str]]:
    """String elements of a str/tuple-of-str literal, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    elts = tuple_elts(node)
    if elts is None:
        return None
    out = []
    for e in elts:
        if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
            return None
        out.append(e.value)
    return out


def _int_items(node: ast.AST) -> Optional[List[int]]:
    lit = int_literal(node)
    if lit is not None:
        return [lit]
    elts = tuple_elts(node)
    if elts is None:
        return None
    out = []
    for e in elts:
        lit = int_literal(e)
        if lit is None:
            return None
        out.append(lit)
    return out


def _local_names(fn) -> Set[str]:
    """Names bound inside ``fn``: params, plain assignments, loop and
    comprehension targets, with-aliases.  Anything else a statement
    mutates is captured (closure / global / attribute) state."""
    names = set(lambda_or_def_params(fn))

    def add_target(t):
        if isinstance(t, ast.Name):
            names.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                add_target(e)
        elif isinstance(t, ast.Starred):
            add_target(t.value)

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                add_target(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            add_target(node.target)
        elif isinstance(node, ast.For):
            add_target(node.target)
        elif isinstance(node, ast.comprehension):
            add_target(node.target)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            add_target(node.optional_vars)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            names.add(node.name)
    return names


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


class JitDisciplineChecker(Checker):
    name = "jit-discipline"
    severity = "error"

    def check(self, src: SourceFile) -> Iterator[Finding]:
        defs = {n.name: n for n in ast.walk(src.tree)
                if isinstance(n, ast.FunctionDef)}
        # decorated defs
        for fn in defs.values():
            for dec in jit_decorations(fn):
                yield from self._check_static_args(src, dec, fn)
            if jit_decorations(fn):
                yield from self._check_body(src, fn)
        # jax.jit(<fn>, ...) call sites resolvable to a local def/lambda
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call) and
                    call_name(node) in ("jax.jit", "jit") and node.args):
                continue
            target = node.args[0]
            fn = None
            if isinstance(target, ast.Name) and target.id in defs:
                fn = defs[target.id]
            elif isinstance(target, ast.Lambda):
                fn = target
            yield from self._check_static_args(src, node, fn)

    # -- static_argnums / static_argnames ---------------------------------
    def _check_static_args(self, src: SourceFile, call: ast.Call,
                           fn) -> Iterator[Finding]:
        params = lambda_or_def_params(fn) if fn is not None else None
        has_var = fn is not None and fn.args.vararg is not None
        static_names: List[str] = []
        names_kw = keyword_arg(call, "static_argnames")
        if names_kw is not None:
            items = _str_items(names_kw)
            if items is None:
                if isinstance(names_kw, ast.Call):
                    yield self.finding(
                        src, names_kw, "static_argnames must be a literal "
                        "str/tuple of str, not a computed value")
            else:
                static_names += items
                if params is not None:
                    for nm in items:
                        if nm not in params:
                            yield self.finding(
                                src, names_kw,
                                f"static_argnames names {nm!r} which is not "
                                f"a parameter of the wrapped function "
                                f"({', '.join(params) or 'no params'}) — "
                                f"the argument will be traced, not "
                                f"specialized")
        nums_kw = keyword_arg(call, "static_argnums")
        if nums_kw is not None:
            items = _int_items(nums_kw)
            if items is None:
                yield self.finding(
                    src, nums_kw, "static_argnums must be a literal "
                    "int/tuple of int (hashable, array-free)")
            else:
                for i in items:
                    if i < 0:
                        yield self.finding(
                            src, nums_kw,
                            f"negative static_argnums entry {i}")
                    elif params is not None and not has_var and \
                            i >= len(params):
                        yield self.finding(
                            src, nums_kw,
                            f"static_argnums entry {i} is out of range for "
                            f"a {len(params)}-parameter function")
                    elif params is not None and i < len(params):
                        static_names.append(params[i])
        # unhashable / array-valued defaults on static parameters
        if fn is not None and static_names:
            args = fn.args
            pos = args.posonlyargs + args.args
            defaults = dict(zip([p.arg for p in pos[len(pos)
                                                    - len(args.defaults):]],
                                args.defaults))
            defaults.update({p.arg: d for p, d in
                             zip(args.kwonlyargs, args.kw_defaults)
                             if d is not None})
            for nm in static_names:
                d = defaults.get(nm)
                if d is None:
                    continue
                bad = isinstance(d, UNHASHABLE_DEFAULTS) or (
                    isinstance(d, ast.Call) and
                    (call_name(d) or "").startswith(ARRAY_CTOR_HEADS))
                if bad:
                    yield self.finding(
                        src, d, f"static parameter {nm!r} has an "
                        f"unhashable/array-valued default — jit hashes "
                        f"static arguments; this raises (or retraces) at "
                        f"call time")
        # remember static names for the body checks
        if fn is not None:
            existing = getattr(fn, "_repro_static", set())
            fn._repro_static = existing | set(static_names)

    # -- trace-time mutation + shape branches ------------------------------
    def _check_body(self, src: SourceFile,
                    fn: ast.FunctionDef) -> Iterator[Finding]:
        local = _local_names(fn)
        static = getattr(fn, "_repro_static", set())
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                yield self.finding(
                    src, node, f"{type(node).__name__.lower()} declaration "
                    f"inside a jitted body — writes run once at trace "
                    f"time, then never again")
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    yield from self._check_mutation_target(src, t, local)
            elif isinstance(node, ast.AugAssign):
                if not isinstance(node.target, ast.Name):
                    yield from self._check_mutation_target(
                        src, node.target, local)
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in MUTATORS:
                root = _root_name(node.func.value)
                if root is not None and root not in local:
                    yield self.finding(
                        src, node, f"'.{node.func.attr}()' mutates captured "
                        f"'{root}' inside a jitted body — runs once at "
                        f"trace time, then never again")
            elif isinstance(node, (ast.If, ast.While)):
                yield from self._check_shape_branch(src, node, fn, static)

    def _check_mutation_target(self, src: SourceFile, t: ast.AST,
                               local: Set[str]) -> Iterator[Finding]:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                yield from self._check_mutation_target(src, e, local)
            return
        if isinstance(t, ast.Attribute):
            root = _root_name(t)
            if root == "self" or (root is not None and root not in local):
                yield self.finding(
                    src, t, f"attribute write to captured "
                    f"'{root}.{t.attr}' inside a jitted body — a "
                    f"trace-time side effect, not a per-call update")
        elif isinstance(t, ast.Subscript):
            root = _root_name(t)
            if root is not None and root not in local:
                yield self.finding(
                    src, t, f"subscript write to captured '{root}' inside "
                    f"a jitted body — a trace-time side effect, not a "
                    f"per-call update")

    def _check_shape_branch(self, src: SourceFile, node, fn,
                            static: Set[str]) -> Iterator[Finding]:
        params = set(lambda_or_def_params(fn)) - static - {"self"}
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.Attribute) and sub.attr == "shape" and \
                    isinstance(sub.value, ast.Name) and \
                    sub.value.id in params:
                yield self.finding(
                    src, node, f"Python branch on {sub.value.id}.shape "
                    f"inside a jitted body — every new shape silently "
                    f"retraces; make it a static argument if intended",
                    severity="warning")
                return
