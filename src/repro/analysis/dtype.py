"""dtype-discipline: explicit casts at fp32 / sub-fp32 boundaries.

The serving and sparse runtimes accumulate in fp32 by policy (docstring
contracts in ``sparse/execute.py`` and the kernels).  As f8/bf16 weight
pools and KV caches land (ROADMAP: quantized block pools), the dangerous
pattern is an accumulating op whose operands silently inherit a sub-fp32
dtype — the matmul then accumulates in low precision with no visible
cast site to review.

Rule (``serving/`` and ``sparse/`` only): inside any function that
*touches* a sub-fp32 dtype (``float8_e4m3fn``, ``float8_e5m2``,
``bfloat16``, ``float16`` — as an attribute or a string literal), every
accumulating op — ``jnp.einsum`` / ``jnp.matmul`` / ``jnp.dot`` /
``jnp.tensordot`` / ``lax.dot_general`` / ``lax.dot`` / the ``@``
operator — must carry an explicit cast site: either a
``preferred_element_type=`` keyword or ``.astype(...)`` on every array
operand.  Functions that never touch a sub-fp32 dtype are exempt — pure
fp32 code keeps its idiomatic, cast-free einsums.
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from repro.analysis.core import (Checker, Finding, SourceFile, call_name,
                                 keyword_arg)

SUB_FP32 = ("float8_e4m3fn", "float8_e5m2", "float8", "bfloat16", "float16")
ACCUMULATORS = {"jnp.einsum", "jnp.matmul", "jnp.dot", "jnp.tensordot",
                "jax.numpy.einsum", "jax.numpy.matmul", "jax.numpy.dot",
                "jax.numpy.tensordot", "lax.dot_general", "lax.dot",
                "jax.lax.dot_general", "jax.lax.dot"}


def _touches_sub_fp32(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr in SUB_FP32:
            return True
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and any(t in node.value for t in SUB_FP32):
            return True
    return False


def _is_cast(node: ast.AST) -> bool:
    """Operand carries its own explicit cast (``x.astype(...)``)."""
    return isinstance(node, ast.Call) and \
        isinstance(node.func, ast.Attribute) and node.func.attr == "astype"


def _array_operands(call: ast.Call, name: str) -> List[ast.AST]:
    args = list(call.args)
    if name.endswith("einsum") and args and \
            isinstance(args[0], ast.Constant) and \
            isinstance(args[0].value, str):
        args = args[1:]                       # spec string is not an array
    if name.endswith(("dot_general", "dot")) and len(args) > 2:
        args = args[:2]                       # dimension_numbers et al.
    return args


class DtypeDisciplineChecker(Checker):
    name = "dtype-discipline"
    severity = "warning"
    paths = ("serving/", "sparse/")

    def check(self, src: SourceFile) -> Iterator[Finding]:
        for fn in ast.walk(src.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _touches_sub_fp32(fn):
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    name = call_name(node) or ""
                    if name not in ACCUMULATORS:
                        continue
                    if keyword_arg(node, "preferred_element_type") is not None:
                        continue
                    ops = _array_operands(node, name)
                    if ops and all(_is_cast(a) for a in ops):
                        continue
                    yield self.finding(
                        src, node, f"{name} in a function touching a "
                        f"sub-fp32 dtype has no explicit cast site — add "
                        f".astype(...) on the operands or "
                        f"preferred_element_type= so the accumulation "
                        f"dtype is reviewable")
                elif isinstance(node, ast.BinOp) and \
                        isinstance(node.op, ast.MatMult):
                    if _is_cast(node.left) and _is_cast(node.right):
                        continue
                    yield self.finding(
                        src, node, "'@' matmul in a function touching a "
                        "sub-fp32 dtype has no explicit cast site — cast "
                        "both operands so the accumulation dtype is "
                        "reviewable")
