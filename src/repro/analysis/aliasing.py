"""aliasing-hazard: mutable numpy state aliased into device arrays.

The PR-1/PR-4 bug class: a class keeps mutable host bookkeeping as
``np.ndarray`` attributes (``seq_lens``, ``page_table``), hands them to
jax (``jnp.asarray`` zero-copy aliases aligned numpy buffers on CPU),
and mutates them while an async dispatch may still read the shared
memory — producing alignment-/timing-dependent wrong tokens.  The fix is
always the same: hand jax a private ``.copy()`` snapshot.

This checker flags, per class:

  * a mutable numpy attribute (assigned ``self.X = np.zeros(...)`` etc.,
    possibly wrapped in ``sanitizer.guard(...)``) converted to a device
    array — ``jnp.asarray`` / ``jnp.array`` / ``sanitizer.device_view``
    — without a ``.copy()`` anywhere in the converted expression;
  * the same attribute returned bare (or via ``np.asarray``) from a
    ``*_device`` view method — the caller will alias it;
  * the same attribute passed raw into a jitted dispatch callable
    (an attribute assigned ``self._f = jax.jit(...)``);
  * an element of a mutable **container** attribute (``self.X = {}`` /
    ``[]`` / ``dict(...)`` / ``list(...)``) — e.g. a per-lane page list
    or a trie-held page-id list — handed to a device converter or a
    jitted dispatch without a ``.copy()``: the container's elements
    outlive the call and later bookkeeping (``release``, eviction,
    COW forks) mutates them while a dispatch may still read the alias.

The heuristic is syntactic: an expression that *derives* a fresh array
from the attribute (e.g. ``np.maximum(self.x, 0)``) may be flagged —
suppress with ``# repro-lint: disable=aliasing-hazard -- <why>``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from repro.analysis.core import Checker, Finding, SourceFile, call_name

# numpy constructors that produce a fresh mutable buffer
NP_CTORS = {"zeros", "ones", "empty", "full", "arange", "array", "asarray",
            "zeros_like", "ones_like", "empty_like", "full_like"}
# constructors of mutable containers whose elements may hold host
# buffers / page-id lists that later bookkeeping mutates in place
CONTAINER_CTORS = {"dict", "list", "collections.OrderedDict",
                   "collections.defaultdict", "defaultdict", "OrderedDict"}
# converters that hand a host buffer to jax (potentially zero-copy)
DEVICE_CONVERTERS = {"jnp.asarray", "jnp.array", "jax.numpy.asarray",
                     "jax.numpy.array"}
DEVICE_CONVERTER_SUFFIXES = (".device_view",)


def _is_np_ctor(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    if name is None:
        return False
    head, _, tail = name.rpartition(".")
    return head in ("np", "numpy") and tail in NP_CTORS


def _unwrap_guard(node: ast.AST) -> ast.AST:
    """``sanitizer.guard(np.zeros(...), name)`` -> the inner ctor."""
    if isinstance(node, ast.Call) and node.args:
        name = call_name(node) or ""
        if name.endswith("guard"):
            return node.args[0]
    return node


def _has_copy(expr: ast.AST) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "copy":
            return True
    return False


def _aliased_attr(expr: ast.AST, mutable: Set[str]) -> Optional[str]:
    """Name of a mutable ``self.X`` aliased by ``expr`` sans snapshot."""
    if _has_copy(expr):
        return None
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and node.attr in mutable:
            return node.attr
    return None


def _is_container_ctor(node: ast.AST) -> bool:
    if isinstance(node, (ast.Dict, ast.List, ast.ListComp, ast.DictComp)):
        return True
    if isinstance(node, ast.Call):
        return call_name(node) in CONTAINER_CTORS
    return False


def _aliased_container(expr: ast.AST, containers: Set[str]) -> Optional[str]:
    """Container attr whose *element* ``expr`` aliases sans snapshot —
    a ``self.X[...]`` subscript or the bare ``self.X``."""
    if _has_copy(expr):
        return None
    for node in ast.walk(expr):
        attr = node.value if isinstance(node, ast.Subscript) else node
        if isinstance(attr, ast.Attribute) and \
                isinstance(attr.value, ast.Name) and \
                attr.value.id == "self" and attr.attr in containers:
            return attr.attr
    return None


def _is_device_converter(call: ast.Call) -> bool:
    name = call_name(call)
    if name is None:
        return False
    return name in DEVICE_CONVERTERS or \
        any(name.endswith(s) for s in DEVICE_CONVERTER_SUFFIXES)


class AliasingHazardChecker(Checker):
    name = "aliasing-hazard"
    severity = "error"

    def check(self, src: SourceFile) -> Iterator[Finding]:
        for cls in ast.walk(src.tree):
            if isinstance(cls, ast.ClassDef):
                yield from self._check_class(src, cls)

    # -- per-class analysis ----------------------------------------------
    def _collect(self, cls: ast.ClassDef):
        """Mutable numpy attrs, container attrs + jitted dispatch attrs
        of one class (``self.X = ...`` and annotated
        ``self.X: T = ...`` assignments both count)."""
        mutable: Set[str] = set()
        containers: Set[str] = set()
        dispatchers: Set[str] = set()
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            for tgt in targets:
                if not (isinstance(tgt, ast.Attribute) and
                        isinstance(tgt.value, ast.Name) and
                        tgt.value.id == "self"):
                    continue
                value = _unwrap_guard(value)
                if _is_np_ctor(value):
                    mutable.add(tgt.attr)
                if _is_container_ctor(value):
                    containers.add(tgt.attr)
                if isinstance(value, ast.Call) and \
                        call_name(value) in ("jax.jit", "jit"):
                    dispatchers.add(tgt.attr)
        return mutable, containers, dispatchers

    def _check_class(self, src: SourceFile,
                     cls: ast.ClassDef) -> Iterator[Finding]:
        mutable, containers, dispatchers = self._collect(cls)
        if not mutable and not containers:
            return
        seen = set()

        def emit(node, attr, why, kind="numpy"):
            key = (node.lineno, attr)
            if key not in seen:
                seen.add(key)
                if kind == "numpy":
                    msg = (f"mutable numpy attribute self.{attr} {why} "
                           f"without a .copy() snapshot — an async "
                           f"dispatch may read the live buffer after a "
                           f"later mutation (PR-1/PR-4 bug class)")
                else:
                    msg = (f"element of mutable container attribute "
                           f"self.{attr} {why} without a .copy() "
                           f"snapshot — container-held buffers (per-lane "
                           f"page lists, trie-held page ids) are mutated "
                           f"by later bookkeeping while a dispatch may "
                           f"still read the alias")
                yield self.finding(src, node, msg)

        def emit_any(node, arg, why):
            attr = _aliased_attr(arg, mutable)
            if attr:
                yield from emit(node, attr, why)
                return
            attr = _aliased_container(arg, containers)
            if attr:
                yield from emit(node, attr, why, kind="container")

        for fn in [n for n in ast.walk(cls)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and _is_device_converter(node):
                    for arg in node.args:
                        yield from emit_any(node, arg,
                                            "aliased into a device array")
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        isinstance(node.func.value, ast.Name) and \
                        node.func.value.id == "self" and \
                        node.func.attr in dispatchers:
                    for arg in list(node.args) + \
                            [kw.value for kw in node.keywords]:
                        yield from emit_any(
                            node, arg,
                            f"passed into jitted dispatch "
                            f"self.{node.func.attr}")
                elif isinstance(node, ast.Return) and \
                        fn.name.endswith("_device") and \
                        node.value is not None and \
                        not isinstance(node.value, ast.Call):
                    attr = _aliased_attr(node.value, mutable)
                    if attr:
                        yield from emit(node, attr,
                                        f"returned from device view "
                                        f"{fn.name}()")
                elif isinstance(node, ast.Return) and \
                        fn.name.endswith("_device") and \
                        isinstance(node.value, ast.Call) and \
                        (call_name(node.value) or "").startswith(
                            ("np.", "numpy.")):
                    attr = _aliased_attr(node.value, mutable)
                    if attr:
                        yield from emit(node, attr,
                                        f"returned from device view "
                                        f"{fn.name}() as a host alias")
