"""aliasing-hazard: mutable numpy state aliased into device arrays.

The PR-1/PR-4 bug class: a class keeps mutable host bookkeeping as
``np.ndarray`` attributes (``seq_lens``, ``page_table``), hands them to
jax (``jnp.asarray`` zero-copy aliases aligned numpy buffers on CPU),
and mutates them while an async dispatch may still read the shared
memory — producing alignment-/timing-dependent wrong tokens.  The fix is
always the same: hand jax a private ``.copy()`` snapshot.

This checker flags, per class:

  * a mutable numpy attribute (assigned ``self.X = np.zeros(...)`` etc.,
    possibly wrapped in ``sanitizer.guard(...)``) converted to a device
    array — ``jnp.asarray`` / ``jnp.array`` / ``sanitizer.device_view``
    — without a ``.copy()`` anywhere in the converted expression;
  * the same attribute returned bare (or via ``np.asarray``) from a
    ``*_device`` view method — the caller will alias it;
  * the same attribute passed raw into a jitted dispatch callable
    (an attribute assigned ``self._f = jax.jit(...)``).

The heuristic is syntactic: an expression that *derives* a fresh array
from the attribute (e.g. ``np.maximum(self.x, 0)``) may be flagged —
suppress with ``# repro-lint: disable=aliasing-hazard -- <why>``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from repro.analysis.core import Checker, Finding, SourceFile, call_name

# numpy constructors that produce a fresh mutable buffer
NP_CTORS = {"zeros", "ones", "empty", "full", "arange", "array", "asarray",
            "zeros_like", "ones_like", "empty_like", "full_like"}
# converters that hand a host buffer to jax (potentially zero-copy)
DEVICE_CONVERTERS = {"jnp.asarray", "jnp.array", "jax.numpy.asarray",
                     "jax.numpy.array"}
DEVICE_CONVERTER_SUFFIXES = (".device_view",)


def _is_np_ctor(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    if name is None:
        return False
    head, _, tail = name.rpartition(".")
    return head in ("np", "numpy") and tail in NP_CTORS


def _unwrap_guard(node: ast.AST) -> ast.AST:
    """``sanitizer.guard(np.zeros(...), name)`` -> the inner ctor."""
    if isinstance(node, ast.Call) and node.args:
        name = call_name(node) or ""
        if name.endswith("guard"):
            return node.args[0]
    return node


def _has_copy(expr: ast.AST) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "copy":
            return True
    return False


def _aliased_attr(expr: ast.AST, mutable: Set[str]) -> Optional[str]:
    """Name of a mutable ``self.X`` aliased by ``expr`` sans snapshot."""
    if _has_copy(expr):
        return None
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and node.attr in mutable:
            return node.attr
    return None


def _is_device_converter(call: ast.Call) -> bool:
    name = call_name(call)
    if name is None:
        return False
    return name in DEVICE_CONVERTERS or \
        any(name.endswith(s) for s in DEVICE_CONVERTER_SUFFIXES)


class AliasingHazardChecker(Checker):
    name = "aliasing-hazard"
    severity = "error"

    def check(self, src: SourceFile) -> Iterator[Finding]:
        for cls in ast.walk(src.tree):
            if isinstance(cls, ast.ClassDef):
                yield from self._check_class(src, cls)

    # -- per-class analysis ----------------------------------------------
    def _collect(self, cls: ast.ClassDef):
        """Mutable numpy attrs + jitted dispatch attrs of one class."""
        mutable: Set[str] = set()
        dispatchers: Set[str] = set()
        for node in ast.walk(cls):
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                if not (isinstance(tgt, ast.Attribute) and
                        isinstance(tgt.value, ast.Name) and
                        tgt.value.id == "self"):
                    continue
                value = _unwrap_guard(node.value)
                if _is_np_ctor(value):
                    mutable.add(tgt.attr)
                if isinstance(value, ast.Call) and \
                        call_name(value) in ("jax.jit", "jit"):
                    dispatchers.add(tgt.attr)
        return mutable, dispatchers

    def _check_class(self, src: SourceFile,
                     cls: ast.ClassDef) -> Iterator[Finding]:
        mutable, dispatchers = self._collect(cls)
        if not mutable:
            return
        seen = set()

        def emit(node, attr, why):
            key = (node.lineno, attr)
            if key not in seen:
                seen.add(key)
                yield self.finding(
                    src, node,
                    f"mutable numpy attribute self.{attr} {why} without a "
                    f".copy() snapshot — an async dispatch may read the "
                    f"live buffer after a later mutation (PR-1/PR-4 bug "
                    f"class)")

        for fn in [n for n in ast.walk(cls)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and _is_device_converter(node):
                    for arg in node.args:
                        attr = _aliased_attr(arg, mutable)
                        if attr:
                            yield from emit(node, attr,
                                            "aliased into a device array")
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        isinstance(node.func.value, ast.Name) and \
                        node.func.value.id == "self" and \
                        node.func.attr in dispatchers:
                    for arg in list(node.args) + \
                            [kw.value for kw in node.keywords]:
                        attr = _aliased_attr(arg, mutable)
                        if attr:
                            yield from emit(
                                node, attr,
                                f"passed into jitted dispatch "
                                f"self.{node.func.attr}")
                elif isinstance(node, ast.Return) and \
                        fn.name.endswith("_device") and \
                        node.value is not None and \
                        not isinstance(node.value, ast.Call):
                    attr = _aliased_attr(node.value, mutable)
                    if attr:
                        yield from emit(node, attr,
                                        f"returned from device view "
                                        f"{fn.name}()")
                elif isinstance(node, ast.Return) and \
                        fn.name.endswith("_device") and \
                        isinstance(node.value, ast.Call) and \
                        (call_name(node.value) or "").startswith(
                            ("np.", "numpy.")):
                    attr = _aliased_attr(node.value, mutable)
                    if attr:
                        yield from emit(node, attr,
                                        f"returned from device view "
                                        f"{fn.name}() as a host alias")
