"""pallas-invariants: static checks on every ``pl.pallas_call`` site.

Pallas failures are notoriously late (compile on a real TPU, or a wrong
DMA under interpret mode); these invariants are checkable from the AST:

  * **index-map arity** — every BlockSpec index map must take exactly
    ``len(grid) + num_scalar_prefetch`` parameters; a missing scalar-ref
    parameter shifts the whole prefetch argument order one left and
    Pallas reports an opaque arity error (or silently mis-tiles).
  * **scalar-read discipline** — index maps may subscript *only* the
    prefetched scalar refs (the trailing ``num_scalar_prefetch``
    parameters).  Subscripting a grid index or a closed-over array is
    not available in SMEM at index-map time.
  * **operand ordering/count** — when the ``pl.pallas_call(...)``
    result is invoked inline, the operand count must equal
    ``num_scalar_prefetch + len(in_specs)`` (scalars first).
  * **divisibility** (literal shapes only) — where the grid, the
    BlockSpec block shape and the ``out_shape`` are all integer
    literals and the index map is a plain permutation of grid indices,
    each block dim must divide the array dim and the mapped grid axis
    must cover it exactly.  Symbolic shapes (the production kernels) are
    skipped — their divisibility asserts stay runtime checks.
  * **version-skew shims** — Pallas symbols that
    ``repro.kernels.compat`` shims (declared by its ``capabilities()``
    registry) must be imported from compat, never referenced as
    ``pltpu.<symbol>`` / ``pltpu.TPU<symbol>`` directly: version-skew
    workarounds live in exactly one place the linter can see.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.core import (Checker, Finding, SourceFile, call_name,
                                 int_literal, keyword_arg,
                                 lambda_or_def_params, tuple_elts)

# fallback when jax (and therefore kernels/compat) is not importable in
# the lint environment; compat.capabilities()["shimmed"] is authoritative
_FALLBACK_SHIMMED = ("CompilerParams",)


def _shimmed_symbols() -> Tuple[str, ...]:
    try:
        from repro.kernels.compat import capabilities
        return tuple(capabilities()["shimmed"])
    except Exception:
        return _FALLBACK_SHIMMED


class _Spec:
    """Statically-extracted view of one grid spec + its BlockSpecs."""

    def __init__(self):
        self.n_prefetch = 0
        self.grid_len: Optional[int] = None
        self.grid_elts: Optional[List[ast.AST]] = None
        self.in_specs: List[ast.Call] = []
        self.out_specs: List[ast.Call] = []


def _blockspec_calls(node: Optional[ast.AST]) -> List[ast.Call]:
    if node is None:
        return []
    out = []
    elts = tuple_elts(node)
    for e in (elts if elts is not None else [node]):
        if isinstance(e, ast.Call) and \
                (call_name(e) or "").endswith("BlockSpec"):
            out.append(e)
    return out


def _extract_spec(call: ast.Call, env: Dict[str, ast.AST]) -> \
        Optional[_Spec]:
    """Pull grid/in_specs/out_specs/num_scalar_prefetch out of a
    ``pl.pallas_call`` site, resolving a ``grid_spec=`` name through the
    enclosing function's single-assignment environment."""
    spec = _Spec()
    holder: ast.Call = call
    gs = keyword_arg(call, "grid_spec")
    if gs is not None:
        if isinstance(gs, ast.Name):
            gs = env.get(gs.id)
        if not isinstance(gs, ast.Call):
            return None
        holder = gs
        n = keyword_arg(gs, "num_scalar_prefetch")
        if n is not None:
            lit = int_literal(n)
            if lit is None:
                return None
            spec.n_prefetch = lit
    grid = keyword_arg(holder, "grid")
    if grid is not None:
        elts = tuple_elts(grid)
        if elts is not None:
            spec.grid_len = len(elts)
            spec.grid_elts = elts
        else:
            spec.grid_len = 1 if int_literal(grid) is not None else None
    spec.in_specs = _blockspec_calls(keyword_arg(holder, "in_specs"))
    spec.out_specs = _blockspec_calls(keyword_arg(holder, "out_specs"))
    return spec


def _index_map(bs: ast.Call) -> Optional[ast.Lambda]:
    im = bs.args[1] if len(bs.args) > 1 else keyword_arg(bs, "index_map")
    return im if isinstance(im, ast.Lambda) else None


def _block_shape(bs: ast.Call) -> Optional[List[Optional[int]]]:
    shape = bs.args[0] if bs.args else keyword_arg(bs, "block_shape")
    elts = tuple_elts(shape) if shape is not None else None
    if elts is None:
        return None
    return [int_literal(e) for e in elts]


class PallasInvariantsChecker(Checker):
    name = "pallas-invariants"
    severity = "error"
    paths = ("kernels/",)

    def __init__(self):
        self.shimmed = _shimmed_symbols()

    def check(self, src: SourceFile) -> Iterator[Finding]:
        yield from self._check_compat_discipline(src)
        # flat single-assignment environment: grid_spec names are
        # function-local in practice, and a later shadowing assignment
        # simply wins (same as execution order for these straight-line
        # kernel wrappers)
        env: Dict[str, ast.AST] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                env[node.targets[0].id] = node.value
        # visit each pallas_call exactly once: inline-invoked sites get
        # the operand-count check (which recurses into the spec checks),
        # bare sites get the spec checks directly
        inline_inner = set()
        calls: List[ast.Call] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Call) and \
                        (call_name(node.func) or "").endswith("pallas_call"):
                    inline_inner.add(id(node.func))
                    calls.append(node)
                elif (call_name(node) or "").endswith("pallas_call"):
                    calls.append(node)
        for node in calls:
            if isinstance(node.func, ast.Call):
                yield from self._check_operands(src, node, env)
            elif id(node) not in inline_inner:
                yield from self._check_specs(src, node, env)

    # -- compat shim discipline -------------------------------------------
    def _check_compat_discipline(self, src: SourceFile) -> Iterator[Finding]:
        if src.path.endswith("kernels/compat.py"):
            return
        banned = set()
        for s in self.shimmed:
            banned.add(s)
            banned.add("TPU" + s)
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Attribute) and node.attr in banned and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id in ("pltpu", "tpu"):
                yield self.finding(
                    src, node, f"direct use of pltpu.{node.attr} — import "
                    f"{node.attr.removeprefix('TPU') or node.attr} from "
                    f"repro.kernels.compat so version-skew workarounds "
                    f"stay declared in one place (compat.capabilities())")

    # -- BlockSpec invariants ---------------------------------------------
    def _check_specs(self, src: SourceFile, call: ast.Call,
                     env: Dict[str, ast.AST]) -> Iterator[Finding]:
        spec = _extract_spec(call, env)
        if spec is None or spec.grid_len is None:
            return
        expected = spec.grid_len + spec.n_prefetch
        out_shape = self._out_shape(call)
        for which, bspecs in (("in_specs", spec.in_specs),
                              ("out_specs", spec.out_specs)):
            for bs in bspecs:
                im = _index_map(bs)
                if im is None:
                    continue
                params = lambda_or_def_params(im)
                if len(params) != expected:
                    yield self.finding(
                        src, bs, f"{which} BlockSpec index map takes "
                        f"{len(params)} args but the grid has "
                        f"{spec.grid_len} axes + {spec.n_prefetch} "
                        f"scalar-prefetch refs = {expected} — prefetch "
                        f"ordering is silently shifted")
                    continue
                scalar_params = set(params[spec.grid_len:]) \
                    if spec.n_prefetch else set()
                grid_params = params[:spec.grid_len]
                for sub in ast.walk(im.body):
                    if not isinstance(sub, ast.Subscript):
                        continue
                    root = sub.value
                    while isinstance(root, (ast.Subscript, ast.Attribute)):
                        root = root.value
                    if not isinstance(root, ast.Name):
                        continue
                    if root.id in scalar_params:
                        continue
                    where = ("grid index" if root.id in grid_params
                             else "closed-over state")
                    yield self.finding(
                        src, bs, f"{which} BlockSpec index map subscripts "
                        f"{where} '{root.id}' — index maps may only read "
                        f"the scalar-prefetch refs (the trailing "
                        f"{spec.n_prefetch} parameters)")
                if which == "out_specs" and out_shape is not None:
                    yield from self._check_divisibility(
                        src, bs, im, spec, out_shape)

    def _out_shape(self, call: ast.Call) -> Optional[List[int]]:
        node = keyword_arg(call, "out_shape")
        if not (isinstance(node, ast.Call) and
                (call_name(node) or "").endswith("ShapeDtypeStruct") and
                node.args):
            return None
        elts = tuple_elts(node.args[0])
        if elts is None:
            return None
        lits = [int_literal(e) for e in elts]
        return None if any(v is None for v in lits) else lits

    def _check_divisibility(self, src: SourceFile, bs: ast.Call,
                            im: ast.Lambda, spec: _Spec,
                            shape: List[int]) -> Iterator[Finding]:
        block = _block_shape(bs)
        if block is None or any(b is None for b in block) or \
                len(block) != len(shape):
            return
        grid = [int_literal(e) for e in (spec.grid_elts or [])]
        if any(g is None for g in grid):
            return
        body = im.body
        if not isinstance(body, ast.Tuple) or len(body.elts) != len(shape):
            return
        params = lambda_or_def_params(im)[:spec.grid_len]
        for d, (dim, blk, idx) in enumerate(zip(shape, block, body.elts)):
            if dim % blk != 0:
                yield self.finding(
                    src, bs, f"out_shape dim {d} ({dim}) is not divisible "
                    f"by its BlockSpec block size ({blk}) — the final "
                    f"partial block reads/writes out of bounds")
                continue
            if isinstance(idx, ast.Name) and idx.id in params:
                steps = grid[params.index(idx.id)]
                if steps * blk != dim:
                    yield self.finding(
                        src, bs, f"grid axis '{idx.id}' runs {steps} steps "
                        f"of block {blk} over out_shape dim {d} ({dim}) — "
                        f"covers {steps * blk} rows, not {dim}")

    # -- inline-call operand count ----------------------------------------
    def _check_operands(self, src: SourceFile, outer: ast.Call,
                        env: Dict[str, ast.AST]) -> Iterator[Finding]:
        inner = outer.func
        assert isinstance(inner, ast.Call)
        spec = _extract_spec(inner, env)
        if spec is not None and spec.in_specs and \
                not any(isinstance(a, ast.Starred) for a in outer.args):
            n_ops = len(outer.args)
            want = spec.n_prefetch + len(spec.in_specs)
            if n_ops != want:
                yield self.finding(
                    src, outer, f"pallas_call invoked with {n_ops} "
                    f"operands but the spec declares {spec.n_prefetch} "
                    f"scalar-prefetch refs + {len(spec.in_specs)} "
                    f"in_specs = {want} — scalars must come first, one "
                    f"operand per spec")
        # the inner call's own BlockSpec invariants apply either way
        yield from self._check_specs(src, inner, env)
