"""Three-term roofline from compiled dry-run artifacts (DESIGN.md §7).

  compute    = HLO_FLOPs / (chips × peak)       [cost_analysis "flops"]
  memory     = HLO_bytes / (chips × HBM_bw)     [cost_analysis "bytes accessed"]
  collective = coll_bytes / (chips × links × bw)[parsed from HLO text]

cost_analysis on a post-SPMD module reports *per-device* numbers on the CPU
backend; we detect which convention the backend used by comparing against
an analytic bound and normalize to per-chip.

Collective bytes: sum of result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
per-device compiled module.  All-reduce counts 2× (ring = reduce-scatter +
all-gather).  ICI: 4 usable links/chip on the 2-D torus.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    ici_links: int = 4               # usable links/chip (2-D torus)
    vmem_bytes: float = 16e6 * 8     # ~128 MB v5e... (not used in terms)


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "f32[16,128]{1,0}" or "bf16[2,4,8]"  or tuple pieces
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes from a compiled (post-SPMD) module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears left of " = <shape> <op-name>(" in HLO text
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (.+?) (\w[\w\-]*)\(", s)
        if not m:
            continue
        result_type, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        out[kind] += _shape_bytes(result_type)
        out["count"] += 1
    return out


def roofline_terms(cost: dict, coll: Dict[str, int], chips: int,
                   hw: HW = HW(), mem_analysis: Optional[dict] = None
                   ) -> dict:
    """Three roofline terms.

    compute:    probe-extrapolated HLO FLOPs (per chip) / peak.
    memory:     per-chip HBM-resident traffic from the REAL compiled
                executable's memory_analysis (arguments + outputs + temps —
                each resident byte streams >= once per step).  The
                fusion-less cost_analysis "bytes accessed" is reported as
                `t_memory_upper_s` (every op's operands from HBM).
    collective: per-chip collective payload (all-reduce 2× for RS+AG
                phases) / (links × link_bw).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll_bytes = (2 * coll.get("all-reduce", 0)
                  + coll.get("all-gather", 0)
                  + coll.get("reduce-scatter", 0)
                  + coll.get("all-to-all", 0)
                  + coll.get("collective-permute", 0))
    if mem_analysis:
        mem_bytes = sum(mem_analysis.get(k) or 0 for k in
                        ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes"))
        mem_bytes -= mem_analysis.get("alias_size_in_bytes") or 0  # donated
    else:
        mem_bytes = bytes_accessed
    t_compute = flops / hw.peak_flops
    t_memory = mem_bytes / hw.hbm_bw
    t_coll = coll_bytes / (hw.ici_links * hw.ici_bw)
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_upper_s": bytes_accessed / hw.hbm_bw,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_step_time_s": total,
        "per_chip_flops": flops,
        "per_chip_mem_bytes": mem_bytes,
        "per_chip_bytes_accessed": bytes_accessed,
        "per_chip_collective_bytes": coll_bytes,
        "collective_counts": {k: v for k, v in coll.items()},
    }


def model_flops(cfg, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (fwd-only), N = active params."""
    n = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
