"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the cached
dry-run JSONs.

    PYTHONPATH=src python -m repro.roofline.report > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "dryrun")


def load(mesh_kind: str):
    out = {}
    for f in sorted(glob.glob(os.path.join(DRYRUN, mesh_kind, "*.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/2**30:.2f}"


def mfu(rec) -> float | None:
    """model-FLOPs utilisation at the roofline bound: what fraction of the
    chips' peak the USEFUL (6·N·D) flops would occupy if the step ran at
    the bound time."""
    rl = rec.get("roofline")
    if not rl or not rec.get("model_flops_total"):
        return None
    bound = rl["bound_step_time_s"]
    chips = rec["n_chips"]
    if bound <= 0:
        return None
    return rec["model_flops_total"] / (chips * 197e12 * bound)


def dryrun_table(mesh_kind: str) -> str:
    rows = ["| arch | shape | status | HBM/chip args+temps (GiB) | "
            "compile (s) | collectives (per-chip GiB) |",
            "|---|---|---|---|---|---|"]
    for (arch, shape), r in load(mesh_kind).items():
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | SKIP (documented) | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | **ERROR** | — | — | — |")
            continue
        m = r["memory_analysis"]
        resident = (m["argument_size_in_bytes"] or 0) + \
                   (m["temp_size_in_bytes"] or 0)
        coll = r.get("extrapolated", r.get("raw_cost", {})).get("coll", {})
        cb = sum(v for k, v in coll.items() if k != "count")
        rows.append(
            f"| {arch} | {shape} | ok | {fmt_bytes(resident)} | "
            f"{r.get('compile_s', 0):.0f} | {cb/2**30:.2f} |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
            "dominant | bound (s) | MODEL/HLO flops | MFU@bound |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in load("pod").items():
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        u = r.get("useful_flops_ratio")
        m = mfu(r)
        rows.append(
            f"| {arch} | {shape} | {rl['t_compute_s']:.4f} | "
            f"{rl['t_memory_s']:.4f} | {rl['t_collective_s']:.4f} | "
            f"**{rl['dominant']}** | {rl['bound_step_time_s']:.4f} | "
            f"{u:.3f} | {m*100:.1f}% |" if u is not None else
            f"| {arch} | {shape} | — |")
    return "\n".join(rows)


def variants_table() -> str:
    """Baseline vs optimized (-opt) vs STUN-pruned (-stun) bound times."""
    base = load("pod")
    opt = load("pod-opt")
    stun = load("pod-stun")
    rows = ["| arch | shape | baseline bound (s) | opt bound (s) | "
            "stun bound (s) | best speedup |", "|---|---|---|---|---|---|"]
    for key, b in base.items():
        if b["status"] != "ok":
            continue
        cands = {}
        for name, d in (("opt", opt), ("stun", stun)):
            r = d.get(key)
            if r and r.get("status") == "ok":
                cands[name] = r["roofline"]["bound_step_time_s"]
        if not cands:
            continue
        b0 = b["roofline"]["bound_step_time_s"]
        best = min(cands.values())
        rows.append(
            f"| {key[0]} | {key[1]} | {b0:.4f} | "
            f"{cands.get('opt', float('nan')):.4f} | "
            + (f"{cands['stun']:.4f} | " if "stun" in cands else "— | ")
            + (f"**{b0/best:.2f}×** |" if best > 0 else "— |"))
    return "\n".join(rows)


def main():
    print("## §Dry-run — single pod (16×16 = 256 chips)\n")
    print(dryrun_table("pod"))
    print("\n## §Dry-run — multi-pod (2×16×16 = 512 chips)\n")
    print(dryrun_table("multipod"))
    print("\n## §Roofline (single-pod)\n")
    print(roofline_table())
    vt = variants_table()
    if vt.count("\n") > 1:
        print("\n## §Roofline — optimized variants (measured cells)\n")
        print(vt)


if __name__ == "__main__":
    main()
