from repro.serving.engine import (  # noqa: F401
    ServeEngine,
    apply_weight_masks,
    greedy_generate,
)
from repro.serving.frontend import AsyncFrontend, TokenStream  # noqa: F401
from repro.serving.kv_cache import PagedKVCache, SlotKVCache  # noqa: F401
from repro.serving.prefix_cache import PrefixCache  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    Request,
    RequestState,
    Scheduler,
    SchedulerError,
)
from repro.serving.speculative import (  # noqa: F401
    SpecStats,
    SpeculativeDecoder,
    accept_block,
    draft_block_paged,
    request_key,
    tree_layout,
)
from repro.serving.telemetry import (  # noqa: F401
    METRICS_SCHEMA,
    NULL_TRACER,
    MetricsSchemaError,
    NullTracer,
    Tracer,
    load_workload,
    stage_timeline,
    validate_metrics,
)
