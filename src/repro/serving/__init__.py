from repro.serving.engine import (  # noqa: F401
    Request,
    ServeEngine,
    greedy_generate,
)
