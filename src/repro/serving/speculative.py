"""Self-speculative decoding: the STUN-pruned model drafts, the dense
model verifies — on one shared paged KV cache.

STUN's core claim is that expert-pruned-then-weight-pruned models stay
faithful to their dense parent.  That makes the pruning artifact an ideal
*drafter* for speculative decoding against its own dense model: instead
of only shrinking the serving footprint, the pruned model buys decode
parallelism.  Per engine round:

  1. **draft** — ``draft_block_paged`` proposes a token *tree*: from the
     anchor it opens ``n_branches`` alternatives at the first draft
     position (``spec_tree``; chain decoding is the 1-branch tree) and
     extends each branch ``spec_k`` tokens deep with chained
     ``decode_step_paged`` calls, all fused into ONE jitted dispatch.
     Branches write their scratch K/V through the lanes' page tables at
     rows ``[n+1, n+k)``, each branch overwriting the last — draft writes
     are scratch the verifier replaces.  Drafter *logits* at every tree
     node ride along so the verifier knows each proposal distribution.
  2. **verify** — ``models.verify_step_paged`` teacher-forces the whole
     tree block ``[anchor, b0_1..b0_k, ..., bN_1..bN_k]`` through the
     dense params in one batched dispatch, with depth-based RoPE
     positions and a tree mask (sibling branches share absolute
     positions, so positional causality alone cannot separate them).
  3. **accept** — ``accept_block`` runs in the same dispatch.  Greedy
     lanes (``temperature == 0``) accept the longest branch prefix that
     matches the dense argmax — bit-for-bit today's behaviour.  Sampled
     lanes run **rejection sampling** (Leviathan et al.): a proposal
     ``x ~ q`` is accepted with probability ``min(1, p(x)/q(x))``
     against the dense distribution ``p``; on rejection the correction
     is drawn from the normalized residual ``norm(max(p - q, 0))``.
     Branch roots use SpecInfer-style multi-round verification: after
     rejecting one root the residual shrinks by ``q_root`` and the next
     root gets its turn, so the emitted distribution is *exactly* the
     dense model's at any temperature.  All randomness comes from
     per-request key chains (``request_key``), so token streams are
     invariant to batch composition and schedule.
  4. **bookkeeping** — each lane emits the winner branch's accepted
     prefix plus one correction/bonus token (≥ 1 token per round, so
     progress matches plain decode), the winner's K/V rows are compacted
     to the canonical contiguous rows in-dispatch, the scheduler's
     ``on_tokens`` fires EOS / ``max_new_tokens`` mid-block, and
     ``PagedKVCache.rollback`` drops the rejected suffix by shrinking
     ``seq_len`` — no page frees: the lane's reservation (which includes
     ``n_branches * spec_k - 1`` overdraft rows) keeps every block write
     in lane-owned pages, and rolled-back rows are rewritten before they
     can be attended.

Greedy verification makes the output **token-identical to dense-only
decode** for any drafter whatsoever, and rejection sampling makes it
**distribution-identical** at temperature > 0 (tests pin both: token
oracles for greedy, a χ² equivalence oracle for sampling).  The draft
only decides how many dense-verified tokens each 2-dispatch round emits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizer
from repro.models import decode_step_paged, verify_step_paged

# Per-request PRNG roles: every random draw in the serving stack comes
# from ``fold_in(request_key(base, rid, m), ROLE)`` where ``m`` is the
# 0-based index of the token being decided.  ROLE_TARGET is shared by
# plain sampling, spec bonus draws, and branch-0 draft proposals — that
# is what makes an identity drafter's spec stream equal the plain stream.
ROLE_TARGET = 0     # sample from the served model's distribution
ROLE_ACCEPT = 1     # accept/reject uniforms (folded again with the round)
ROLE_RESIDUAL = 2   # residual-distribution corrections
ROLE_BRANCH = 3     # extra tree-branch proposals (folded again with i>=1)

_EPS = 1e-20


def request_key(base_key, rid, m):
    """Key chain for request ``rid``'s ``m``-th generated token.

    Derived purely from ``(seed, rid, m)``, so sampled token streams are
    invariant to batch composition, admission order, and schedule — the
    property the statistical equivalence oracles rely on.
    """
    return jax.random.fold_in(jax.random.fold_in(base_key, rid), m)


def _lane_keys(base_key, rids, ms):
    """[B] per-lane request keys for token indices ``ms``."""
    return jax.vmap(lambda r, m: request_key(base_key, r, m))(rids, ms)


def _role_gumbel(keys, role, V, fold=None):
    """[B, V] gumbel noise from per-lane keys folded with ``role``."""
    def one(kk):
        kk = jax.random.fold_in(kk, role)
        if fold is not None:
            kk = jax.random.fold_in(kk, fold)
        return jax.random.gumbel(kk, (V,), jnp.float32)
    return jax.vmap(one)(keys)


def _role_uniform(keys, role, fold):
    """[B] uniforms from per-lane keys folded with ``(role, fold)``."""
    def one(kk):
        return jax.random.uniform(
            jax.random.fold_in(jax.random.fold_in(kk, role), fold))
    return jax.vmap(one)(keys)


def tree_layout(n_branches: int, k: int):
    """Static draft-tree layout for a ``[anchor, b0_1..b0_k, ...]`` block.

    Returns ``(depth [W], allow [W, W])`` numpy arrays, ``W = 1 + N*k``:
    ``depth[r]`` is row ``r``'s depth below the anchor (anchor 0, branch
    tokens 1..k) and ``allow[r, s]`` is True iff block row ``s`` is an
    ancestor-or-self of row ``r`` (the anchor is everyone's ancestor).
    """
    W = 1 + n_branches * k
    depth = np.zeros(W, np.int32)
    branch = np.zeros(W, np.int32)
    for r in range(1, W):
        branch[r] = (r - 1) // k
        depth[r] = (r - 1) % k + 1
    allow = np.zeros((W, W), bool)
    for r in range(W):
        for s in range(W):
            allow[r, s] = s == 0 or (branch[s] == branch[r]
                                     and depth[s] <= depth[r])
    return depth, allow


@dataclasses.dataclass
class SpecStats:
    """Speculative-decode counters, merged into ``latency_stats()``.

    ``accepted`` counts draft tokens actually *delivered* to requests
    (verifier-accepted AND not truncated by EOS / ``max_new_tokens``),
    so ``emitted == accepted + corrections`` and ``accepted <= drafted``
    hold as hard invariants.  ``drafted`` counts one root-to-leaf path
    (``spec_k``) per lane-round — the tokens a round could deliver —
    while ``drafted_nodes`` counts every proposed tree node
    (``n_branches * spec_k`` per lane-round).
    """
    rounds: int = 0             # draft+verify rounds
    drafted: int = 0            # per-lane path tokens proposed (rounds*k)
    drafted_nodes: int = 0      # all tree nodes proposed (rounds*N*k)
    accepted: int = 0           # draft tokens delivered to requests
    corrections: int = 0        # correction/bonus tokens delivered
    emitted: int = 0            # tokens actually delivered to requests
    draft_dispatches: int = 0   # fused draft-tree dispatches
    verify_dispatches: int = 0  # dense verify dispatches

    def as_dict(self) -> Dict[str, float]:
        d: Dict[str, float] = {
            "spec_rounds": float(self.rounds),
            "spec_drafted": float(self.drafted),
            "spec_drafted_nodes": float(self.drafted_nodes),
            "spec_accepted": float(self.accepted),
            "spec_corrections": float(self.corrections),
            "spec_emitted": float(self.emitted),
        }
        d["spec_accept_rate"] = (self.accepted / self.drafted
                                 if self.drafted else 0.0)
        d["spec_tokens_per_verify"] = (self.emitted / self.verify_dispatches
                                       if self.verify_dispatches else 0.0)
        # accepted DRAFT tokens per verify dispatch (excludes the free
        # bonus/correction token): the draft-shape figure of merit —
        # trees beat chains here or they are not paying for their width
        d["spec_accepted_per_verify"] = (self.accepted
                                         / self.verify_dispatches
                                         if self.verify_dispatches else 0.0)
        return d

    def reset(self):
        self.rounds = self.drafted = self.drafted_nodes = 0
        self.accepted = self.corrections = self.emitted = 0
        self.draft_dispatches = self.verify_dispatches = 0


def draft_block_paged(params, cfg, cache, tokens, seq_lens, page_tables,
                      k: int, *, n_branches: int = 1, mesh=None,
                      expert_mask=None, base_key=None, temps=None,
                      rids=None, counts=None):
    """Draft a ``n_branches`` x ``k`` token tree per lane in one dispatch.

    tokens [B, 1] int32 — each lane's last emitted token; seq_lens [B] —
    valid rows per lane (the anchor is written at row ``seq_lens[b]``);
    page_tables [B, max_pages].  The anchor step runs once; each branch
    then chains ``k-1`` ``decode_step_paged`` steps (static python loops,
    so jit fuses the whole tree into a single dispatch), writing scratch
    K/V at rows ``[n+1, n+k)`` — later branches overwrite earlier ones,
    which is safe because the verifier rewrites every attended row.

    Branch roots: greedy lanes take the drafter's top-``n_branches``
    tokens (distinct, so at most one root can match the dense argmax);
    sampled lanes draw each root independently from the drafter's
    root distribution at the lane temperature.  Branch 0's proposal
    noise is the ROLE_TARGET stream at the proposed token's index
    (``counts + depth - 1``) — identical to what plain sampling would
    draw — and branches ``i >= 1`` use the ROLE_BRANCH stream, keeping
    all proposals mutually independent.  With ``base_key=None`` (or
    ``temps=None``) drafting is purely greedy, as in greedy-only spec.

    Returns ``(draft [B, N, k] int32, draft_logits [B, N, k, vocab]
    float32, new_cache)`` — ``draft_logits[:, i, j]`` is the drafter's
    logits *predicting* branch ``i``'s depth ``j+1`` token (row ``j=0``
    is the shared root prediction), the ``q`` of the accept ratio.
    """
    B = tokens.shape[0]
    V = cfg.vocab
    N = n_branches
    sampled = base_key is not None and temps is not None
    logits0, cache = decode_step_paged(
        params, cfg, cache, tokens, seq_lens, page_tables,
        mesh=mesh, expert_mask=expert_mask)
    lg0 = logits0[:, :V].astype(jnp.float32)
    if N == 1:
        top_roots = jnp.argmax(lg0, axis=-1).astype(jnp.int32)[:, None]
    else:
        top_roots = jax.lax.top_k(lg0, N)[1].astype(jnp.int32)   # [B,N]
    if sampled:
        tclip = jnp.maximum(temps, 1e-6)[:, None]
    draft_tokens, draft_logits = [], []
    for i in range(N):
        if sampled:
            keys = _lane_keys(base_key, rids, counts)
            g = (_role_gumbel(keys, ROLE_TARGET, V) if i == 0
                 else _role_gumbel(keys, ROLE_BRANCH, V, fold=i))
            samp = jnp.argmax(lg0 / tclip + g, axis=-1)
            root = jnp.where(temps > 0, samp,
                             top_roots[:, i]).astype(jnp.int32)
        else:
            root = top_roots[:, i]
        toks, lgs = [root], [lg0]
        tok = root[:, None]
        for j in range(1, k):
            lg_j, cache = decode_step_paged(
                params, cfg, cache, tok, seq_lens + j, page_tables,
                mesh=mesh, expert_mask=expert_mask)
            lg_j = lg_j[:, :V].astype(jnp.float32)
            greedy_j = jnp.argmax(lg_j, axis=-1).astype(jnp.int32)
            if sampled:
                keys = _lane_keys(base_key, rids, counts + j)
                g = (_role_gumbel(keys, ROLE_TARGET, V) if i == 0
                     else _role_gumbel(keys, ROLE_BRANCH, V, fold=i))
                samp = jnp.argmax(lg_j / tclip + g, axis=-1)
                nxt = jnp.where(temps > 0, samp, greedy_j).astype(jnp.int32)
            else:
                nxt = greedy_j
            toks.append(nxt)
            lgs.append(lg_j)
            tok = nxt[:, None]
        draft_tokens.append(jnp.stack(toks, axis=1))
        draft_logits.append(jnp.stack(lgs, axis=1))
    return (jnp.stack(draft_tokens, axis=1), jnp.stack(draft_logits, axis=1),
            cache)


def _row(x, rows):
    """Gather x[b, rows[b]] for x [B, W, V], rows [B] -> [B, V]."""
    B, _, V = x.shape
    idx = jnp.broadcast_to(rows[:, None, None], (B, 1, V))
    return jnp.take_along_axis(x, idx, axis=1)[:, 0]


def _col(x, cols):
    """Gather x[b, cols[b]] for x [B, W], cols [B] -> [B]."""
    return jnp.take_along_axis(x, cols[:, None], axis=1)[:, 0]


def _probs(lg, temps):
    """[B, V] logits -> temperature softmax (stable at temp -> 0)."""
    return jax.nn.softmax(lg / jnp.maximum(temps, 1e-6)[:, None], axis=-1)


def _residual(r, q):
    """Normalized rejection residual ``norm(max(r - q, 0))``."""
    res = jnp.maximum(r - q, 0.0)
    return res / jnp.maximum(res.sum(axis=-1, keepdims=True), _EPS)


def _categorical(r, keys, role, fold):
    """Exact sample from distribution rows ``r`` [B, V] via gumbel-max."""
    g = _role_gumbel(keys, role, r.shape[-1]) if fold is None else \
        _role_gumbel(keys, role, r.shape[-1], fold=fold)
    return jnp.argmax(jnp.log(jnp.maximum(r, _EPS)) + g,
                      axis=-1).astype(jnp.int32)


def accept_block(logits, block, draft_logits, temps, base_key, rids, counts,
                 n_branches: int, k: int, vocab: int):
    """In-dispatch accept/resample decision for one verified spec block.

    logits [B, W, Vp] — dense verifier logits over the tree block;
    block [B, W] — the block tokens (anchor + branch tokens);
    draft_logits [B, N, k, V] — drafter logits at every tree node;
    temps / rids / counts [B] — per-lane temperature, request id, and
    generated-token count at round start (the anchor is token
    ``counts-1``, so branch depth ``d`` proposes token ``counts+d-1``).

    Greedy lanes (``temps == 0``): the winner is the branch with the
    longest prefix matching the dense argmax (roots are distinct, so at
    most one branch accepts its root) and the correction/bonus is the
    dense argmax after the accepted prefix — for ``n_branches == 1``
    this is bit-for-bit the classic greedy chain acceptance.

    Sampled lanes run exact speculative sampling:

    * **roots** (SpecInfer multi-round): residual starts at the dense
      ``p``; root ``i`` (a sample from the drafter's ``q_root``) is
      accepted with prob ``min(1, r_i(x)/q_root(x))``, else
      ``r_{i+1} = norm(max(r_i - q_root, 0))``; if every root is
      rejected the correction is drawn from the final residual.
    * **winner chain** (Leviathan): depth-``d`` token ``x ~ q_d`` is
      accepted with prob ``min(1, p_d(x)/q_d(x))``; the first rejection
      draws the correction from ``norm(max(p_d - q_d, 0))``; a fully
      accepted branch draws the bonus from the dense distribution with
      the ROLE_TARGET noise plain sampling would have used for that
      token index — which is why a perfect drafter's spec stream equals
      the plain sampled stream per ``(seed, rid)``.

    Returns ``(winner [B], accept [B] in 0..k, next_token [B])``.
    """
    V = vocab
    N = n_branches
    B = block.shape[0]
    lg = logits[..., :V].astype(jnp.float32)

    # --- greedy path (temps == 0): longest argmax-matching branch ------
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)          # [B, W]
    acc_by_branch = []
    for i in range(N):
        pred_rows = [0] + [1 + i * k + j for j in range(k - 1)]
        preds = jnp.stack([greedy[:, r] for r in pred_rows], axis=1)
        toks = block[:, 1 + i * k: 1 + i * k + k]
        match = (preds == toks).astype(jnp.int32)
        acc_by_branch.append(jnp.cumprod(match, axis=1).sum(axis=1))
    acc_g = jnp.stack(acc_by_branch, axis=1)                    # [B, N]
    win_g = jnp.argmax(acc_g, axis=1).astype(jnp.int32)
    a_g = jnp.max(acc_g, axis=1).astype(jnp.int32)
    nrow_g = jnp.where(a_g == 0, 0, 1 + win_g * k + a_g - 1)
    next_g = _col(greedy, nrow_g)

    # --- sampled path: rejection sampling with residual resampling -----
    keys0 = _lane_keys(base_key, rids, counts)
    p_anchor = _probs(lg[:, 0], temps)
    q_root = _probs(draft_logits[:, 0, 0], temps)
    r_cur = p_anchor
    root_ok = jnp.zeros((B,), bool)
    win_s = jnp.zeros((B,), jnp.int32)
    for i in range(N):
        x = block[:, 1 + i * k]
        u = _role_uniform(keys0, ROLE_ACCEPT, i)
        ratio = _col(r_cur, x) / jnp.maximum(_col(q_root, x), _EPS)
        ok = u < jnp.minimum(1.0, ratio)
        newly = ok & ~root_ok
        win_s = jnp.where(newly, i, win_s)
        # rejected rounds shrink the residual by this root's proposal q
        r_cur = jnp.where((root_ok | ok)[:, None], r_cur,
                          _residual(r_cur, q_root))
        root_ok = root_ok | ok
    next_s = _categorical(r_cur, keys0, ROLE_RESIDUAL, None)
    acc_s = root_ok.astype(jnp.int32)
    # winner-branch drafter logits [B, k, V]
    dlg_w = jnp.take_along_axis(
        draft_logits,
        jnp.broadcast_to(win_s[:, None, None, None], (B, 1, k, V)),
        axis=1)[:, 0]
    alive = root_ok
    for d in range(2, k + 1):
        keys_d = _lane_keys(base_key, rids, counts + d - 1)
        p_d = _probs(_row(lg, 1 + win_s * k + (d - 2)), temps)
        q_d = _probs(dlg_w[:, d - 1], temps)
        x = _col(block, 1 + win_s * k + (d - 1))
        u = _role_uniform(keys_d, ROLE_ACCEPT, 0)
        ratio = _col(p_d, x) / jnp.maximum(_col(q_d, x), _EPS)
        ok = u < jnp.minimum(1.0, ratio)
        corr_d = _categorical(_residual(p_d, q_d), keys_d, ROLE_RESIDUAL,
                              None)
        next_s = jnp.where(alive & ~ok, corr_d, next_s)
        acc_s = acc_s + (alive & ok).astype(jnp.int32)
        alive = alive & ok
    # fully accepted branch: bonus token from the dense distribution with
    # the exact ROLE_TARGET noise plain sampling uses for token counts+k
    keys_b = _lane_keys(base_key, rids, counts + k)
    lg_b = _row(lg, 1 + win_s * k + k - 1)
    g = _role_gumbel(keys_b, ROLE_TARGET, V)
    bonus = jnp.argmax(lg_b / jnp.maximum(temps, 1e-6)[:, None] + g,
                       axis=-1).astype(jnp.int32)
    next_s = jnp.where(alive, bonus, next_s)

    sampled = temps > 0
    winner = jnp.where(sampled, win_s, win_g)
    accept = jnp.where(sampled, acc_s, a_g)
    next_tok = jnp.where(sampled, next_s, next_g)
    return winner, accept, next_tok


def _compact_winner(cache, page_tables, seq_lens, winner, k: int):
    """Copy the winner branch's K/V rows onto the canonical chain rows.

    After verify, branch ``w``'s depth-``j`` K/V sits at cache row
    ``n + 1 + w*k + (j-1)``; the lane's history must instead be the
    contiguous rows ``n+1 .. n+k``.  Gather/scatter the ``k`` winner
    rows per lane inside the dispatch (a no-op when ``w == 0``).  Rows
    past the accepted prefix are rolled back and rewritten before they
    can be attended, so copying all ``k`` rows unconditionally is safe.
    """
    B = seq_lens.shape[0]
    kc, vc = cache["k"], cache["v"]
    L, n_pages, ps = kc.shape[0], kc.shape[1], kc.shape[2]
    j = jnp.arange(k)
    src = seq_lens[:, None] + 1 + winner[:, None] * k + j[None]   # [B,k]
    dst = seq_lens[:, None] + 1 + j[None]
    b_idx = jnp.arange(B)[:, None]
    sflat = page_tables[b_idx, src // ps] * ps + src % ps
    dflat = page_tables[b_idx, dst // ps] * ps + dst % ps
    kf = kc.reshape(L, n_pages * ps, *kc.shape[3:])
    vf = vc.reshape(L, n_pages * ps, *vc.shape[3:])
    kf = kf.at[:, dflat].set(kf[:, sflat])
    vf = vf.at[:, dflat].set(vf[:, sflat])
    return {"k": kf.reshape(kc.shape), "v": vf.reshape(vc.shape)}


def _verify_and_accept(params, cfg, cache, block, seq_lens, page_tables,
                       draft_logits, temps, rids, counts, base_key,
                       n_branches: int, k: int, *, mesh=None,
                       depth=None, allow_block=None):
    """One fused dispatch: dense verify + accept/resample + compaction.

    ``accept_block`` is looked up as a module global at trace time so
    tests can monkeypatch a deliberately-biased accept rule and prove
    the statistical oracle catches it.
    """
    _, _, logits, cache = verify_step_paged(
        params, cfg, cache, block, seq_lens, page_tables, mesh=mesh,
        depth=depth, allow_block=allow_block)
    winner, accept, next_tok = accept_block(
        logits, block, draft_logits, temps, base_key, rids, counts,
        n_branches, k, cfg.vocab)
    if n_branches > 1:
        cache = _compact_winner(cache, page_tables, seq_lens, winner, k)
    return winner, accept, next_tok, cache


class SpeculativeDecoder:
    """Owns the jitted draft/verify callables + stats for one engine.

    Built by ``ServeEngine(spec_decode="pruned")``; ``decode_round``
    replaces the engine's plain batched decode step.  The engine keeps
    two param sets: ``engine.draft_params`` (pruned — ``weight_masks``
    applied, ``expert_mask`` threaded into draft dispatches only) and
    ``engine.params`` (dense, used by prefill and verify).

    ``n_branches`` (the engine's ``spec_tree``) widens the chain draft
    to a token tree branching at the first draft position; ``seed``
    must match the engine's so spec and plain sampling share one
    per-request key-chain universe.
    """

    def __init__(self, cfg, k: int, mesh=None, draft_expert_mask=None,
                 donate=(), n_branches: int = 1, seed: int = 0):
        self.cfg = cfg
        self.k = k
        self.n_branches = n_branches
        self.stats = SpecStats()
        self.base_key = jax.random.PRNGKey(seed)
        em = draft_expert_mask
        base = self.base_key
        if n_branches == 1:
            depth_dev = allow_dev = None          # chain: positions == rows
        else:
            depth_np, allow_np = tree_layout(n_branches, k)
            depth_dev = jnp.asarray(depth_np)
            allow_dev = jnp.asarray(allow_np)
        self._draft = jax.jit(
            lambda p, c, t, sl, tbl, temps, rids, ms: draft_block_paged(
                p, cfg, c, t, sl, tbl, k, n_branches=n_branches, mesh=mesh,
                expert_mask=em, base_key=base, temps=temps, rids=rids,
                counts=ms),
            donate_argnums=donate)
        self._verify = jax.jit(
            lambda p, c, blk, sl, tbl, dlg, temps, rids, ms:
            _verify_and_accept(
                p, cfg, c, blk, sl, tbl, dlg, temps, rids, ms, base,
                n_branches, k, mesh=mesh, depth=depth_dev,
                allow_block=allow_dev),
            donate_argnums=donate)

    def decode_round(self, engine):
        """One speculative round for every active lane: fused draft-tree
        dispatch, one dense verify+accept dispatch, then per-lane
        delivery, termination, and rollback bookkeeping."""
        sched, cache = engine.scheduler, engine.cache
        active = list(sched.active.values())
        k, N = self.k, self.n_branches
        B = cache.n_slots
        last = np.zeros((B, 1), np.int32)
        temps = np.zeros(B, np.float32)
        rids = np.zeros(B, np.int32)
        ms = np.zeros(B, np.int32)
        for st in active:
            # a fully-cached (prefix-cache) admission has no tokens yet:
            # replay its last prompt token as the block anchor
            last[st.slot, 0] = (st.tokens[-1] if st.tokens
                                else st.replay_token)
            temps[st.slot] = st.req.temperature
            rids[st.slot] = st.rid
            ms[st.slot] = len(st.tokens)
        last_dev = sanitizer.device_view(last)
        seq = cache.seq_lens_device()
        tbl = cache.page_table_device()
        temps_d = jnp.asarray(temps)
        rids_d = jnp.asarray(rids)
        ms_d = jnp.asarray(ms)
        tracer = engine.tracer
        with tracer.span("spec_draft", lanes=len(active), k=k,
                         branches=N) as sp:
            draft, dlg, cache.tree = self._draft(
                engine.draft_params, cache.tree, last_dev, seq, tbl,
                temps_d, rids_d, ms_d)
            sp.fence(draft)
        block = jnp.concatenate([last_dev, draft.reshape(B, N * k)], axis=1)
        with tracer.span("spec_verify", lanes=len(active)) as sp:
            winner, accept, next_tok, cache.tree = self._verify(
                engine.params, cache.tree, block, seq, tbl, dlg,
                temps_d, rids_d, ms_d)
            # materialize inside the span: the host transfer is where the
            # verify dispatch's device time surfaces, and the accept
            # counts become span args for the Perfetto view
            draft_np = np.asarray(draft)
            w_np = np.asarray(winner)
            a_np = np.asarray(accept)
            n_np = np.asarray(next_tok)
            sp.set(accepted=int(a_np[[st.slot for st in active]].sum())
                   if active else 0,
                   drafted=k * len(active))
        engine.decode_dispatches += 2          # 1 fused draft + 1 verify
        self.stats.rounds += 1
        self.stats.draft_dispatches += 1
        self.stats.verify_dispatches += 1
        now = time.monotonic()
        for st in active:
            b = st.slot
            a = int(a_np[b])
            w = int(w_np[b])
            emit = [int(t) for t in draft_np[b, w, :a]] + [int(n_np[b])]
            self.stats.drafted += k
            self.stats.drafted_nodes += N * k
            n0 = int(cache.seq_lens[b])
            # verify wrote rows [n0, n0+N*k] and compaction put the
            # winner branch at rows [n0+1, n0+k]; advance over the whole
            # block, then roll the rejected suffix back (`emit` beyond
            # the request's own termination is dropped by on_tokens)
            cache.advance(b, 1 + N * k)
            consumed, finished = sched.on_tokens(st.rid, emit, now)
            delivered = min(consumed, a)
            self.stats.accepted += delivered
            self.stats.corrections += consumed - delivered
            self.stats.emitted += consumed
            if finished:
                cache.release(b)
            else:
                cache.rollback(b, n0 + consumed)
