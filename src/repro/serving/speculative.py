"""Self-speculative decoding: the STUN-pruned model drafts, the dense
model verifies — on one shared paged KV cache.

STUN's core claim is that expert-pruned-then-weight-pruned models stay
faithful to their dense parent.  That makes the pruning artifact an ideal
*drafter* for speculative decoding against its own dense model: instead
of only shrinking the serving footprint, the pruned model buys decode
parallelism.  Per engine round:

  1. **draft** — ``draft_block_paged`` runs ``spec_k`` greedy decode
     steps with the pruned params (runtime ``expert_mask`` and/or stage-2
     weight masks) fused into ONE jitted dispatch, writing draft K/V
     through the lanes' page tables at rows ``[n, n+k)``.
  2. **verify** — ``models.verify_step_paged`` teacher-forces the block
     ``[last, d_1..d_k]`` through the dense params in one batched
     dispatch.  It overwrites rows ``[n, n+k]`` with dense K/V (the draft
     writes are scratch — every row that can ever be attended again holds
     verifier K/V), and returns per-lane accept lengths plus the
     verifier's correction/bonus token.
  3. **accept** — each lane emits ``draft[:accept] + [correction]``
     (≥ 1 token per round, so progress matches plain decode), the
     scheduler's ``on_tokens`` fires EOS / ``max_new_tokens`` mid-block,
     and ``PagedKVCache.rollback`` drops the rejected suffix by shrinking
     ``seq_len`` — no page frees: the lane's reservation (which includes
     ``spec_k - 1`` overdraft rows) keeps every block write in lane-owned
     pages, and rolled-back rows are rewritten before they can be
     attended.

Greedy verification makes the output **token-identical to dense-only
decode** for any drafter whatsoever (tests pin this oracle): the draft
only decides how many dense-verified tokens each 2-dispatch round emits.
Dispatches per emitted token drop from 1 to ``2 / (accept_len + 1)``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizer
from repro.models import decode_step_paged, verify_step_paged


@dataclasses.dataclass
class SpecStats:
    """Speculative-decode counters, merged into ``latency_stats()``."""
    rounds: int = 0             # draft+verify rounds
    drafted: int = 0            # draft tokens proposed (rounds * k * lanes)
    accepted: int = 0           # draft tokens the verifier accepted
    emitted: int = 0            # tokens actually delivered to requests
    draft_dispatches: int = 0   # fused k-step draft dispatches
    verify_dispatches: int = 0  # dense verify dispatches

    def as_dict(self) -> Dict[str, float]:
        d: Dict[str, float] = {
            "spec_rounds": float(self.rounds),
            "spec_drafted": float(self.drafted),
            "spec_accepted": float(self.accepted),
            "spec_emitted": float(self.emitted),
        }
        d["spec_accept_rate"] = (self.accepted / self.drafted
                                 if self.drafted else 0.0)
        d["spec_tokens_per_verify"] = (self.emitted / self.verify_dispatches
                                       if self.verify_dispatches else 0.0)
        return d

    def reset(self):
        self.rounds = self.drafted = self.accepted = self.emitted = 0
        self.draft_dispatches = self.verify_dispatches = 0


def draft_block_paged(params, cfg, cache, tokens, seq_lens, page_tables,
                      k: int, *, mesh=None, expert_mask=None):
    """Draft ``k`` greedy tokens per lane in one dispatch.

    tokens [B, 1] int32 — each lane's last emitted token; seq_lens [B] —
    valid rows per lane (token 0 is written at row ``seq_lens[b]``);
    page_tables [B, max_pages].  Runs ``k`` chained ``decode_step_paged``
    steps (``k`` is a static python int, so jit unrolls the chain into a
    single dispatch), each writing the drafter's K/V at the next row —
    scratch writes the verifier overwrites.

    Returns ``(draft [B, k] int32, new_cache)``.  Drafting is always
    greedy: spec mode serves greedy requests only (the engine rejects
    ``temperature > 0`` at submit), so draft sampling needs no RNG.
    """
    draft = []
    tok = tokens
    for j in range(k):
        logits, cache = decode_step_paged(
            params, cfg, cache, tok, seq_lens + j, page_tables,
            mesh=mesh, expert_mask=expert_mask)
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1
                         ).astype(jnp.int32)[:, None]
        draft.append(tok[:, 0])
    return jnp.stack(draft, axis=1), cache


class SpeculativeDecoder:
    """Owns the jitted draft/verify callables + stats for one engine.

    Built by ``ServeEngine(spec_decode="pruned")``; ``decode_round``
    replaces the engine's plain batched decode step.  The engine keeps
    two param sets: ``engine.draft_params`` (pruned — ``weight_masks``
    applied, ``expert_mask`` threaded into draft dispatches only) and
    ``engine.params`` (dense, used by prefill and verify).
    """

    def __init__(self, cfg, k: int, mesh=None, draft_expert_mask=None,
                 donate=()):
        self.cfg = cfg
        self.k = k
        self.stats = SpecStats()
        em = draft_expert_mask
        self._draft = jax.jit(
            lambda p, c, t, sl, tbl: draft_block_paged(
                p, cfg, c, t, sl, tbl, k, mesh=mesh, expert_mask=em),
            donate_argnums=donate)
        self._verify = jax.jit(
            lambda p, c, t, sl, tbl: verify_step_paged(
                p, cfg, c, t, sl, tbl, mesh=mesh),
            donate_argnums=donate)

    def decode_round(self, engine):
        """One speculative round for every active lane: fused k-token
        draft dispatch, one dense verify dispatch, then per-lane
        acceptance, termination, and rollback bookkeeping."""
        sched, cache = engine.scheduler, engine.cache
        active = list(sched.active.values())
        k = self.k
        B = cache.n_slots
        last = np.zeros((B, 1), np.int32)
        for st in active:
            # a fully-cached (prefix-cache) admission has no tokens yet:
            # replay its last prompt token as the block anchor
            last[st.slot, 0] = (st.tokens[-1] if st.tokens
                                else st.replay_token)
        last_dev = sanitizer.device_view(last)
        seq = cache.seq_lens_device()
        tbl = cache.page_table_device()
        draft, cache.tree = self._draft(engine.draft_params, cache.tree,
                                        last_dev, seq, tbl)
        block = jnp.concatenate([last_dev, draft], axis=1)    # [B, k+1]
        accept_len, next_tok, _, cache.tree = self._verify(
            engine.params, cache.tree, block, seq, tbl)
        engine.decode_dispatches += 2          # 1 fused draft + 1 verify
        self.stats.rounds += 1
        self.stats.draft_dispatches += 1
        self.stats.verify_dispatches += 1
        draft_np = np.asarray(draft)
        a_np = np.asarray(accept_len)
        n_np = np.asarray(next_tok)
        now = time.monotonic()
        for st in active:
            b = st.slot
            a = int(a_np[b])
            emit = [int(t) for t in draft_np[b, :a]] + [int(n_np[b])]
            self.stats.drafted += k
            self.stats.accepted += a
            n0 = int(cache.seq_lens[b])
            # verify wrote rows [n0, n0+k]; advance over the whole block,
            # then roll the rejected suffix back (`emit` beyond the
            # request's own termination is dropped by on_tokens)
            cache.advance(b, k + 1)
            consumed, finished = sched.on_tokens(st.rid, emit, now)
            self.stats.emitted += consumed
            if finished:
                cache.release(b)
            else:
                cache.rollback(b, n0 + consumed)
