"""Asyncio streaming frontend over ``ServeEngine.submit/step``.

The engine is a synchronous step machine: ``submit`` queues, ``step``
advances every in-flight request by (at most) one schedule round, and
tokens land in ``Scheduler`` state.  ``AsyncFrontend`` turns that into
the thing you can point traffic at — per-request **async token
streams** — without threads and without touching the engine's
dispatch path:

  * **one step loop** (``run()``): a single task calls ``engine.step()``
    whenever any request is in flight, yielding to the event loop
    between steps so arrivals submitted "while the engine runs"
    interleave exactly like an open-loop client.  When the engine is
    idle the loop parks on an :class:`asyncio.Event` instead of
    spinning — a new ``submit`` wakes it.
  * **per-request streams**: ``submit()`` returns a :class:`TokenStream`
    whose ``async for`` yields tokens in generation order as steps
    produce them.  The stream is push-fed from the step loop (an
    ``asyncio.Queue`` per request), so a slow consumer never stalls the
    engine — tokens buffer in the (bounded-by-``max_new_tokens``) queue.
  * **backpressure**: ``submit(wait=True)`` holds the caller while the
    engine has no admission headroom (``can_admit_now`` — free lane +
    lifetime page reservation), waking on every request completion.
    The cap on *queued* requests is therefore the caller count, not an
    unbounded deque: an open-loop generator that outruns the engine
    accumulates waiting coroutines, exactly the visible queue a load
    bench wants to measure.
  * **cancellation**: breaking out of the ``async for`` (client
    disconnect) cancels the request in the engine — its lane, page
    reservation, COW forks, and prefix-cache claims are released on the
    next loop tick instead of decoding to ``max_new_tokens`` as a
    zombie.  ``TokenStream.cancel()`` does the same explicitly.

Everything runs on one event loop in one thread: the engine's
numpy/cache bookkeeping needs no locking, and "cancel mid-spec-block"
simply means the cancel lands between two decode rounds — the engine
releases the lane before the next round rebuilds its lane list.
"""
from __future__ import annotations

import asyncio
from typing import AsyncIterator, Optional

from repro.serving.scheduler import Request
from repro.serving.telemetry import stage_timeline

_DONE = object()          # queue sentinel: stream complete
_CANCELED = object()      # queue sentinel: request canceled engine-side


class TokenStream:
    """One request's async token stream (returned by
    ``AsyncFrontend.submit``).

    ``async for tok in stream`` yields ints in generation order and ends
    when the request finishes (EOS or ``max_new_tokens``).  Leaving the
    loop early — ``break``, an exception, a dropped client — cancels the
    request engine-side via the generator's ``finally``; iterating a
    canceled stream stops cleanly at whatever was already queued."""

    def __init__(self, frontend: "AsyncFrontend", rid: int):
        self.frontend = frontend
        self.rid = rid
        self.queue: asyncio.Queue = asyncio.Queue()
        self.tokens: list = []         # everything yielded so far
        self.finished = False          # engine delivered the full stream
        self.canceled = False
        # per-request stage split (telemetry.stage_timeline dict:
        # queue_s / prefill_s / decode_s / total_s / ttft_s / n_tokens),
        # captured at completion before the scheduler pops the state;
        # None until finished (and for canceled streams)
        self.timeline: Optional[dict] = None

    def cancel(self) -> bool:
        """Abort this request engine-side (idempotent).  Returns True if
        live state was removed — False once finished: a completed
        stream's tokens are never destroyed."""
        if self.canceled or self.finished:
            return False
        self.canceled = True
        removed = self.frontend.engine.cancel(self.rid)
        self.queue.put_nowait(_CANCELED)
        self.frontend._wake()
        return removed

    async def __aiter__(self) -> AsyncIterator[int]:
        try:
            while True:
                tok = await self.queue.get()
                if tok is _DONE or tok is _CANCELED:
                    return
                yield tok
        finally:
            # early exit (break / client disconnect): free the lane now
            self.cancel()

    async def drain(self) -> list:
        """Collect the whole stream (convenience for non-streaming
        callers and tests)."""
        return [tok async for tok in self]


class AsyncFrontend:
    """Thin asyncio frontend over a :class:`ServeEngine` (see module
    docstring).  Construct, then either ``async with frontend:`` (runs
    the step loop for the block) or call ``start()``/``aclose()``."""

    def __init__(self, engine):
        self.engine = engine
        self._streams: dict = {}       # rid -> TokenStream, in flight
        self._work = asyncio.Event()   # engine has (or just got) work
        self._room = asyncio.Event()   # admission headroom changed
        self._task: Optional[asyncio.Task] = None
        self._closed = False

    # ---- lifecycle ------------------------------------------------------
    def start(self):
        """Spawn the step loop on the running event loop (idempotent)."""
        if self._task is None or self._task.done():
            self._task = asyncio.get_running_loop().create_task(self.run())

    async def aclose(self):
        """Stop the step loop; in-flight requests are canceled."""
        self._closed = True
        for stream in list(self._streams.values()):
            stream.cancel()
        self._wake()
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None

    async def __aenter__(self) -> "AsyncFrontend":
        self.start()
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    # ---- submission -----------------------------------------------------
    async def submit(self, request: Request, wait: bool = True
                     ) -> TokenStream:
        """Validate + queue ``request``; returns its :class:`TokenStream`.

        ``wait=True`` (default) applies backpressure: the caller is held
        until the engine has admission headroom for this request (a free
        lane + its lifetime page reservation), so the engine-side queue
        stays bounded by the callers willing to wait.  ``wait=False``
        queues unconditionally — the open-loop bench uses this, because
        open-loop arrivals by definition do not slow down when the
        server falls behind.  Unservable requests raise ValueError
        immediately in both modes (nothing is queued)."""
        self.engine._validate(request)
        if wait:
            while not self.engine.can_admit_now(request):
                self._room.clear()
                await self._room.wait()
        rid = self.engine.submit(request)
        stream = TokenStream(self, rid)
        self._streams[rid] = stream
        self._wake()
        return stream

    @property
    def in_flight(self) -> int:
        """Streams submitted and not yet finished or canceled."""
        return len(self._streams)

    # ---- step loop ------------------------------------------------------
    async def run(self):
        """Drive ``engine.step()`` while any request is in flight; park on
        the wake event when idle.  One ``await`` per step keeps the loop
        cooperative: arrivals and cancels land *between* steps, which is
        the only place the single-threaded engine can observe them."""
        while not self._closed:
            if not self.engine.busy:
                self._work.clear()
                # nothing in flight: any stream still tracked is a
                # zombie (canceled mid-prefill before its queue drained)
                await self._work.wait()
                continue
            self.engine.step()
            self._publish()
            await asyncio.sleep(0)     # let arrivals/cancels interleave

    def _publish(self):
        """Push newly generated tokens to their streams; retire finished
        and canceled requests."""
        sched = self.engine.scheduler
        for rid, stream in list(self._streams.items()):
            if stream.canceled:
                del self._streams[rid]
                self._room.set()
                continue
            st = sched.state(rid)
            if st is None:             # canceled engine-side, not via stream
                stream.canceled = True
                stream.queue.put_nowait(_CANCELED)
                del self._streams[rid]
                self._room.set()
                continue
            while len(stream.tokens) < len(st.tokens):
                tok = st.tokens[len(stream.tokens)]
                stream.tokens.append(tok)
                stream.queue.put_nowait(tok)
            if st.done:
                stream.finished = True
                # capture the stage split BEFORE result() pops the state
                stream.timeline = stage_timeline(st)
                stream.queue.put_nowait(_DONE)
                sched.result(rid)      # pop finished state; tokens are ours
                del self._streams[rid]
                self._room.set()

    def _wake(self):
        self._work.set()
        self._room.set()
