"""Structured serving telemetry: span tracing, the unified metrics
schema, per-request stage timelines, and workload-trace record/replay.

Three layers, all zero-cost when disabled (the ``REPRO_SANITIZE``
pattern — the default ``NULL_TRACER`` allocates nothing per call):

**Spans.**  :class:`Tracer` records stage-typed spans around every
engine-step phase — admission, prefix match/insert/evict, prefill
chunks (with the resumable-cursor position), decode rounds, spec
draft/verify (with accept counts), page alloc/COW-fork, cancel — on a
monotonic clock.  JAX dispatches return before the device finishes, so
a span that merely brackets a dispatch measures *enqueue* cost; call
:meth:`Span.fence` with the dispatch outputs and the tracer samples
``jax.block_until_ready`` at span close (``fence_rate``, a
deterministic accumulator — no RNG) so device time is attributed to
the dispatch that issued it without fencing every step.  Spans export
as Chrome-trace-event JSON (:meth:`Tracer.export`) loadable in
Perfetto / ``chrome://tracing``: one track per engine lane plus
scheduler / cache / queue tracks.

**Metrics schema.**  ``METRICS_SCHEMA`` is the single canonical
declaration of every key ``ServeEngine.latency_stats()`` (and the
wider ``ServeEngine.metrics()``) may emit — scheduler latency windows,
cache gauges, spec counters, prefix-cache counters, engine dispatch
counters.  ``validate_metrics`` rejects undeclared keys, and a pin
test holds the schema equal to the documented table in
``docs/serving.md``, so the three historical dict schemas can no
longer drift apart silently.

**Stage timelines & workload traces.**  :func:`stage_timeline` splits
a finished request JetStream-style (queue -> prefill -> decode) from
the scheduler's per-request stamps; the tracer also records a
replayable workload trace — ``(arrival_offset_s, prompt_len,
max_new_tokens, seed)`` per submitted request — that
``benchmarks/bench_slo.py --replay`` drives back through the open-loop
harness (see ``docs/observability.md`` for the format).
"""
from __future__ import annotations

import json
import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional

# ---------------------------------------------------------------------------
# track layout (Chrome trace: pid/tid pairs; we use one process, one
# tid per track, named via metadata events)

TRACK_SCHEDULER = "scheduler"   # admission / decode / spec / cancel
TRACK_CACHE = "cache"           # page alloc / COW fork / prefix ops
TRACK_QUEUE = "queue"           # retroactive per-request queue spans


def lane_track(slot: int) -> str:
    """Track name for a cache lane (one Perfetto row per lane)."""
    return f"lane {int(slot)}"


# ---------------------------------------------------------------------------
# null implementations — the disabled path.  ``NULL_SPAN`` is a shared
# singleton: a disabled trace point allocates NOTHING (pin-tested).

class _NullSpan:
    """Shared no-op span; every method is a constant-time no-op."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        pass

    def fence(self, payload):
        return payload


NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: the engine default.  Every hook is a no-op and
    ``span()`` returns the shared :data:`NULL_SPAN` singleton, so
    tracing-off costs one attribute lookup + one call per trace point
    and zero allocations.
    """
    enabled = False
    fence_rate = 0.0

    def span(self, name, track=TRACK_SCHEDULER, **args) -> _NullSpan:
        return NULL_SPAN

    def instant(self, name, track=TRACK_SCHEDULER, **args) -> None:
        pass

    def complete(self, name, track, t_start, t_end, **args) -> None:
        pass

    def record_request(self, rid, prompt, max_new_tokens,
                       temperature=0.0) -> None:
        pass

    def request_done(self, st) -> None:
        pass


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# live tracer

class Span:
    """One in-flight span.  Use as a context manager::

        with tracer.span("decode", n_active=4) as sp:
            logits, tree = decode(...)
            sp.fence(logits)          # sampled block_until_ready at close
            sp.set(tokens=4)          # extra args, post-hoc

    ``fence()`` registers the dispatch outputs; whether the close
    actually blocks is decided by the tracer's deterministic
    ``fence_rate`` sampler, so steady-state overhead is bounded.
    """
    __slots__ = ("_tracer", "name", "track", "args", "t_start", "_payload")

    def __init__(self, tracer: "Tracer", name: str, track: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.t_start = 0.0
        self._payload = None

    def __enter__(self) -> "Span":
        self.t_start = self._tracer._clock()
        return self

    def set(self, **args) -> None:
        self.args.update(args)

    def fence(self, payload):
        self._payload = payload
        return payload

    def __exit__(self, *exc) -> bool:
        tracer = self._tracer
        if self._payload is not None and tracer._take_fence():
            import jax  # deferred: schema/replay users never import jax
            jax.block_until_ready(self._payload)
            tracer.n_fences += 1
            self.args.setdefault("fenced", True)
        tracer.complete(self.name, self.track, self.t_start,
                        tracer._clock(), **self.args)
        return False


class Tracer:
    """Span recorder with Chrome-trace export and workload capture.

    ``fence_rate`` in [0, 1] is the fraction of *fenced* span closes
    that actually ``jax.block_until_ready`` their payload (0.0 — the
    default — never blocks; 1.0 fences every dispatch).  Sampling is a
    deterministic accumulator, not RNG, so traced runs stay replayable.

    ``clock`` defaults to ``time.monotonic``; tests inject fake clocks.
    """
    enabled = True

    def __init__(self, fence_rate: float = 0.0, clock=time.monotonic):
        if not 0.0 <= fence_rate <= 1.0:
            raise ValueError(f"fence_rate must be in [0, 1]: {fence_rate}")
        self.fence_rate = float(fence_rate)
        self._clock = clock
        self.t0 = clock()
        self.events: List[Dict[str, Any]] = []
        self.workload: List[Dict[str, Any]] = []
        self.n_spans = 0
        self.n_fences = 0
        self._fence_acc = 0.0
        self._tids: Dict[str, int] = {}
        for track in (TRACK_SCHEDULER, TRACK_CACHE, TRACK_QUEUE):
            self._tid(track)

    # -- internals --------------------------------------------------------

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids)
            self._tids[track] = tid
            self.events.append({"ph": "M", "name": "thread_name",
                                "pid": 0, "tid": tid,
                                "args": {"name": track}})
        return tid

    def _take_fence(self) -> bool:
        if self.fence_rate <= 0.0:
            return False
        self._fence_acc += self.fence_rate
        if self._fence_acc >= 1.0:
            self._fence_acc -= 1.0
            return True
        return False

    # -- span API ---------------------------------------------------------

    def span(self, name: str, track: str = TRACK_SCHEDULER,
             **args) -> Span:
        self.n_spans += 1
        return Span(self, name, track, args)

    def complete(self, name: str, track: str, t_start: float,
                 t_end: float, **args) -> None:
        """Record a finished span directly (retroactive spans use this
        with scheduler timestamps — nesting is by time containment, so
        emission order does not matter)."""
        self.events.append({
            "ph": "X", "name": name, "pid": 0, "tid": self._tid(track),
            "ts": (t_start - self.t0) * 1e6,
            "dur": max(0.0, (t_end - t_start) * 1e6),
            "args": args,
        })

    def instant(self, name: str, track: str = TRACK_SCHEDULER,
                **args) -> None:
        self.events.append({
            "ph": "i", "s": "t", "name": name, "pid": 0,
            "tid": self._tid(track),
            "ts": (self._clock() - self.t0) * 1e6, "args": args,
        })

    # -- per-request lifecycle -------------------------------------------

    def record_request(self, rid: int, prompt, max_new_tokens: int,
                       temperature: float = 0.0) -> None:
        """Append one workload-trace record at submit time."""
        self.workload.append({
            "arrival_offset_s": round(self._clock() - self.t0, 6),
            "prompt_len": int(len(prompt)),
            "max_new_tokens": int(max_new_tokens),
            "seed": prompt_seed(prompt),
            "temperature": float(temperature),
        })

    def request_done(self, st) -> None:
        """Emit the retroactive lifecycle spans for a finished request
        (wired as ``Scheduler.on_finish``): a queue span on the queue
        track, and request/prefill/decode spans on the request's lane
        track.  Args carry the same windows ``latency_stats()``
        aggregates (``ttft_s``, the per-token gap trace), so traces
        reconcile exactly with the pooled percentiles (pin-tested).
        """
        timeline = stage_timeline(st)
        if timeline is None:
            return
        lane = lane_track(st.slot)
        self.n_spans += 4
        self.complete(f"queue rid={st.rid}", TRACK_QUEUE,
                      st.t_submit, st.t_admit, rid=st.rid)
        self.complete(f"request rid={st.rid}", lane, st.t_admit,
                      st.t_done, rid=st.rid,
                      itl_gaps=[float(g) for g in st.itl], **timeline)
        self.complete("prefill", lane, st.t_admit, st.t_active,
                      rid=st.rid)
        self.complete("decode", lane, st.t_active, st.t_done,
                      rid=st.rid, n_tokens=len(st.tokens))

    # -- export -----------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        """Write Chrome-trace-event JSON (open in Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def dump_workload(self, path: str) -> None:
        """Write the recorded workload trace as JSONL for ``--replay``."""
        with open(path, "w") as f:
            for rec in self.workload:
                f.write(json.dumps(rec) + "\n")


# ---------------------------------------------------------------------------
# per-request stage timeline (JetStream-style queue/prefill/decode split)

def stage_timeline(st) -> Optional[Dict[str, Any]]:
    """Split a finished request's wall time into stages from the
    scheduler's stamps.  Duck-typed over ``RequestState`` (needs
    ``t_submit/t_admit/t_active/t_done/t_first_token/tokens``); returns
    None until the request finished with full stamps (e.g. a request
    driven through a bare Scheduler without admit/activate times, or a
    canceled one).
    """
    if (getattr(st, "t_done", None) is None
            or getattr(st, "t_admit", None) is None
            or getattr(st, "t_active", None) is None):
        return None
    return {
        "queue_s": st.t_admit - st.t_submit,
        "prefill_s": st.t_active - st.t_admit,
        "decode_s": st.t_done - st.t_active,
        "total_s": st.t_done - st.t_submit,
        "ttft_s": (None if st.t_first_token is None
                   else st.t_first_token - st.t_submit),
        "n_tokens": len(st.tokens),
    }


# ---------------------------------------------------------------------------
# workload traces (record/replay format; docs/observability.md)

WORKLOAD_FIELDS = ("arrival_offset_s", "prompt_len", "max_new_tokens",
                   "seed")


def prompt_seed(prompt) -> int:
    """Deterministic content seed for a prompt token sequence — replay
    regenerates a synthetic prompt of the same length from it, so
    traces ship no raw text."""
    return zlib.crc32(",".join(str(int(t)) for t in prompt).encode())


def load_workload(path: str) -> List[Dict[str, Any]]:
    """Parse + validate a JSONL workload trace; returns records sorted
    by arrival offset."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            for field in WORKLOAD_FIELDS:
                if field not in rec:
                    raise ValueError(
                        f"{path}:{i}: workload record missing "
                        f"{field!r} (need {WORKLOAD_FIELDS})")
            if rec["arrival_offset_s"] < 0 or rec["prompt_len"] <= 0 \
                    or rec["max_new_tokens"] <= 0:
                raise ValueError(f"{path}:{i}: non-positive field "
                                 f"in {rec}")
            records.append(rec)
    if not records:
        raise ValueError(f"{path}: empty workload trace")
    records.sort(key=lambda r: r["arrival_offset_s"])
    return records


# ---------------------------------------------------------------------------
# unified metrics schema — the one canonical key set behind
# ``latency_stats()`` / ``gauges()`` / ``SpecStats.as_dict()`` /
# ``prefix_cache.stats()`` / ``ServeEngine.metrics()``.  The pin test
# holds this equal to the documented table in docs/serving.md.

@dataclass(frozen=True)
class MetricSpec:
    kind: str   # "histogram" | "gauge" | "counter"
    doc: str


_H, _G, _C = "histogram", "gauge", "counter"

METRICS_SCHEMA: Dict[str, MetricSpec] = {
    # scheduler latency windows (bounded deques; present once data exists)
    "p50_latency_s": MetricSpec(_H, "median end-to-end request latency"),
    "p95_latency_s": MetricSpec(_H, "p95 end-to-end request latency"),
    "p50_first_token_s": MetricSpec(_H, "median TTFT (submit to first "
                                        "token)"),
    "p95_first_token_s": MetricSpec(_H, "p95 TTFT"),
    "p50_inter_token_s": MetricSpec(_H, "median inter-token gap "
                                        "(per-request trace, pooled)"),
    "p95_inter_token_s": MetricSpec(_H, "p95 inter-token gap"),
    # per-stage windows (queue -> prefill -> decode split)
    "p50_queue_s": MetricSpec(_H, "median queue wait (submit to "
                                  "admission)"),
    "p95_queue_s": MetricSpec(_H, "p95 queue wait"),
    "p50_prefill_s": MetricSpec(_H, "median prefill stage (admission "
                                    "to activation)"),
    "p95_prefill_s": MetricSpec(_H, "p95 prefill stage"),
    "p50_decode_s": MetricSpec(_H, "median decode stage (activation "
                                   "to done)"),
    "p95_decode_s": MetricSpec(_H, "p95 decode stage"),
    # paged KV cache gauges
    "pages_in_use": MetricSpec(_G, "pages currently referenced"),
    "pages_total": MetricSpec(_G, "page-pool capacity"),
    "page_utilization": MetricSpec(_G, "pages_in_use / pages_total"),
    "kv_fragmentation": MetricSpec(_G, "allocated-but-unwritten KV "
                                       "fraction"),
    "lanes_prefilling": MetricSpec(_G, "lanes mid-prefill"),
    "prefill_pages_in_use": MetricSpec(_G, "pages held by prefilling "
                                           "lanes"),
    "cache_hit_rate": MetricSpec(_G, "alloc requests served without "
                                     "eviction"),
    "shared_pages": MetricSpec(_G, "pages with refcount > 1"),
    "cow_forks": MetricSpec(_G, "copy-on-write page forks performed"),
    # slot KV cache gauges (legacy layout)
    "slots_in_use": MetricSpec(_G, "occupied cache slots"),
    "slots_total": MetricSpec(_G, "cache slot capacity"),
    "slot_utilization": MetricSpec(_G, "slots_in_use / slots_total"),
    # speculative-decode counters (SpecStats.as_dict)
    "spec_rounds": MetricSpec(_C, "draft+verify rounds"),
    "spec_drafted": MetricSpec(_C, "per-lane path tokens proposed"),
    "spec_drafted_nodes": MetricSpec(_C, "all draft-tree nodes "
                                         "proposed"),
    "spec_accepted": MetricSpec(_C, "draft tokens delivered"),
    "spec_corrections": MetricSpec(_C, "correction/bonus tokens "
                                       "delivered"),
    "spec_emitted": MetricSpec(_C, "total tokens delivered via spec"),
    "spec_accept_rate": MetricSpec(_G, "accepted / drafted"),
    "spec_tokens_per_verify": MetricSpec(_G, "emitted per verify "
                                             "dispatch"),
    "spec_accepted_per_verify": MetricSpec(_G, "accepted draft tokens "
                                               "per verify dispatch"),
    # prefix-cache counters (prefix_cache.stats)
    "prefix_lookups": MetricSpec(_C, "admission-time prefix lookups"),
    "prefix_hits": MetricSpec(_C, "lookups matching >= 1 cached page"),
    "prefix_hit_rate": MetricSpec(_G, "prefix_hits / prefix_lookups"),
    "prefix_cached_pages": MetricSpec(_G, "pages resident in the trie"),
    "prefix_claimed_tokens": MetricSpec(_C, "prompt tokens served from "
                                            "cache"),
    "prefix_token_savings": MetricSpec(_G, "claimed / offered prompt "
                                           "tokens"),
    "prefix_evicted_pages": MetricSpec(_C, "trie pages reclaimed by "
                                           "LRU eviction"),
    # engine dispatch counters (ServeEngine.metrics() only)
    "prefill_dispatches": MetricSpec(_C, "prefill-chunk dispatches"),
    "decode_dispatches": MetricSpec(_C, "decode/draft/verify "
                                        "dispatches"),
    "requests_admitted": MetricSpec(_C, "requests granted a cache "
                                        "lane"),
    "requests_canceled": MetricSpec(_C, "requests canceled mid-flight"),
    "pages_allocated": MetricSpec(_C, "lifetime pages reserved at "
                                      "admission"),
}


class MetricsSchemaError(KeyError):
    """A metrics dict emitted a key not declared in METRICS_SCHEMA."""


def validate_metrics(stats: Dict[str, Any],
                     source: str = "latency_stats") -> Dict[str, Any]:
    """Reject undeclared metric keys; returns ``stats`` unchanged.

    Every emitting surface routes through this, so adding a metric
    anywhere without declaring it in the schema (and therefore in the
    docs/serving.md table, held equal by the pin test) fails fast.
    """
    unknown = [k for k in stats if k not in METRICS_SCHEMA]
    if unknown:
        raise MetricsSchemaError(
            f"{source} emitted key(s) not in METRICS_SCHEMA: "
            f"{sorted(unknown)} — declare them in "
            f"repro.serving.telemetry.METRICS_SCHEMA and the metrics "
            f"schema table in docs/serving.md")
    return stats


def schema_table(keys: Optional[Iterable[str]] = None) -> str:
    """Render the schema as the markdown table embedded in
    docs/serving.md (between the ``metrics-schema`` markers)."""
    lines = ["| key | kind | meaning |", "|---|---|---|"]
    for key in (keys or METRICS_SCHEMA):
        spec = METRICS_SCHEMA[key]
        lines.append(f"| `{key}` | {spec.kind} | {spec.doc} |")
    return "\n".join(lines)
