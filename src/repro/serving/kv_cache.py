"""KV caches for continuous batching: page-granular (default) and the
legacy slot-granular layout.

``PagedKVCache`` stores K/V as [L, n_pages, page_size, K, hd] pools plus a
per-lane page table [n_slots, max_pages]: lane ``b``'s logical rows
[i*ps, (i+1)*ps) live in physical page ``page_table[b, i]``.  Pages — not
whole ``max_len`` slots — are the allocation unit, so many short requests
pack densely into the same pool a few long ones would use, and the pool
budget (``n_pages``) can be provisioned for the live-token working set
rather than ``n_slots * max_len`` worst case.

Paged invariants (asserted by tests/test_paged_serving.py and
tests/test_prefix_cache.py):
  * **Page 0 is a sentinel** — never allocated to a request.  Free lanes'
    table rows and table entries past a lane's reservation all point at
    it, so the batched decode step's placeholder writes for idle lanes
    and prefill's chunk-padding writes land in page 0, which is never
    attended (length masking).  Allocated pages are therefore never
    dirtied by another lane — the slot layout's "free slots are dirty,
    prefill must rewrite row 0 first" invariant is gone by construction.
  * **No *writable* page is owned by two lanes**: every page carries a
    refcount (``refcount(p) == referencing lane tables + prefix-trie
    entries``), and a page with refcount > 1 is shared *read-only* — it
    holds a cached prompt prefix whose rows no sharer ever rewrites
    (decode/draft/verify all write at rows ``>= prompt_len``, and a
    fully-cached prompt's first decode write goes to a copy-on-write
    fork of the last shared page).  Without a prefix cache every
    refcount is 1 and this reduces to the original exclusive-ownership
    invariant.  ``release`` (né ``free``) decrements; a page returns to
    the free pool only at refcount 0, so cached pages stay resident
    after their lane finishes until LRU eviction reclaims them under
    pool pressure.
  * **Reservation covers the request lifetime**: admission reserves
    ``ceil((prompt + max_new_tokens + overdraft)/ps)`` pages up front
    (cache-hit admissions point the leading table entries at shared
    cached pages instead of drawing them from the free pool), so a
    decode step can never run out of pages mid-flight (the engine has
    no preemption).  ``overdraft`` (speculative decoding:
    ``spec_tree * spec_k - 1`` — the widest draft-tree verify block)
    covers verify-block rows written past the request's own lifetime and
    then rolled back via ``rollback()`` — reserved so block writes land
    in lane-owned pages, never on the shared sentinel.  The admission
    *gate* is page availability — free pages plus what prefix-cache
    eviction could reclaim — not lane count alone.

The device arrays live in ``tree`` and are updated functionally by the
jitted prefill/decode calls; this class owns the host-side bookkeeping
(free page pool, per-lane tables and lengths).

``SlotKVCache`` keeps the PR-1 slot-granular layout ([L, B, T, K, hd],
one ``max_len`` slot per lane) — it remains the reference implementation
the paged engine is tested token-identical against, and its docstring
invariant still applies: free slots are dirty, and batched ragged decode
writes idle lanes' placeholder K/V into row 0, which is safe only because
slot prefill always rewrites from row 0 before any row is attended.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizer
from repro.models import init_cache, init_paged_cache
from repro.serving.telemetry import NULL_TRACER, TRACK_CACHE


class PagedKVCache:
    """Page-granular KV cache: fixed page pool + per-lane page tables."""

    def __init__(self, cfg, n_slots: int, max_len: int, page_size: int,
                 page_budget: Optional[int] = None, overdraft: int = 0):
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"PagedKVCache requires an attention KV cache; "
                f"family={cfg.family!r} keeps recurrent state instead")
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        # ``overdraft`` rows per lane beyond the request's own lifetime:
        # speculative decoding writes a verify block of
        # W = spec_tree * spec_k + 1 tokens starting at the last emitted
        # position, so up to W - 2 rows past ``prompt + max_new_tokens``
        # are written
        # (then rolled back, never attended).  Reserving them keeps every
        # block write inside pages the lane owns — without the overdraft
        # those writes would fall onto the shared sentinel page, where a
        # same-dispatch query of another lane could read them.
        self.overdraft = overdraft
        self.max_pages = -(-(max_len + overdraft) // page_size)  # table width
        self.max_len = self.max_pages * page_size     # lane logical capacity
        if page_budget is None:
            page_budget = n_slots * self.max_pages    # fits slot worst case
        self.page_budget = page_budget
        self.n_pages = page_budget + 1                # + sentinel page 0
        self.tree = init_paged_cache(cfg, self.n_pages, page_size)
        # under REPRO_SANITIZE=1 these carry version-stamped guards: a
        # device view built from the live buffer + a later mutation is a
        # deterministic DispatchRaceError instead of a timing coin flip
        self.seq_lens = sanitizer.guard(np.zeros(n_slots, np.int32),
                                        "PagedKVCache.seq_lens")
        self.page_table = sanitizer.guard(
            np.zeros((n_slots, self.max_pages), np.int32),
            "PagedKVCache.page_table")
        self._free_slots = list(range(n_slots - 1, -1, -1))  # pop() -> 0
        self._free_pages = list(range(self.n_pages - 1, 0, -1))  # never 0
        self._pages_of: Dict[int, List[int]] = {}
        # refcount per non-free page: referencing lane tables + prefix-
        # trie entries.  Pages with no entry are in the free pool.
        self._refs: Dict[int, int] = {}
        # per lane: leading table entries that point at SHARED cached
        # pages (read-only for this lane) — gauges + test invariants
        self._n_shared: Dict[int, int] = {}
        self._prefilling: set = set()    # lanes mid-prefill (gauges)
        self._table_dev = None           # device copy, rebuilt on mutation
        self._slot_dev: Dict[int, object] = {}   # per-slot device rows
        self._prefix = None              # attached PrefixCache (optional)
        self._fork_fn = None             # jitted COW page copy, built lazily
        self.cow_forks = 0               # copy-on-write forks (gauge)
        self.tracer = NULL_TRACER        # set by ServeEngine.set_tracer

    # ---- lifecycle ------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def pages_in_use(self) -> int:
        return self.page_budget - len(self._free_pages)

    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` rows — pure page math, no overdraft."""
        return -(-n_tokens // self.page_size)

    def lifetime_pages(self, n_tokens: int) -> int:
        """Pages ``alloc(n_tokens)`` will actually reserve: the request's
        ``n_tokens`` lifetime rows plus the cache-wide speculative
        ``overdraft`` rows."""
        return self.pages_needed(n_tokens + self.overdraft)

    def can_admit(self, n_tokens: int, n_shared: int = 0) -> bool:
        """Quick admission gate.  ``n_shared`` leading pages come from the
        prefix cache instead of the free pool; headroom counts free pages
        plus what prefix-cache eviction could reclaim.  Slightly
        optimistic under sharing (a matched page can itself be the
        eviction headroom) — ``alloc`` re-checks authoritatively and
        returns None on a genuine shortfall."""
        fresh = self.lifetime_pages(n_tokens) - n_shared
        avail = len(self._free_pages) + self.evictable_pages
        return (bool(self._free_slots) and fresh <= avail
                and n_tokens + self.overdraft <= self.max_len)

    def alloc(self, n_tokens: int, shared_pages: Sequence[int] = (),
              fork_last: bool = False) -> Optional[int]:
        """Claim a free lane plus pages for ``n_tokens`` lifetime rows.

        The lane's leading table entries point at ``shared_pages`` (a
        cached prefix from the prefix trie — refcounts bumped, rows
        read-only for this lane); the remaining
        ``lifetime_pages(n_tokens) - len(shared_pages)`` come from the
        free pool, evicting LRU cached pages if the pool runs short.
        ``fork_last`` copies the last shared page into a private one
        before installing it (copy-on-write: a fully cached prompt's
        first decode write lands at row ``prompt_len - 1``, inside that
        page).  Returns the lane index, or None when lanes or pages are
        short — never raises; admission simply waits.  The caller sets
        ``seq_lens[slot]`` to the claimed prefix length next (0 for a
        cold admission) — until then idle-lane placeholder writes would
        land at row 0, which on a cache hit is shared."""
        with self.tracer.span("page_alloc", track=TRACK_CACHE,
                              tokens=int(n_tokens),
                              shared=len(shared_pages),
                              fork=bool(fork_last)) as sp:
            slot = self._alloc(n_tokens, shared_pages, fork_last)
            sp.set(slot=-1 if slot is None else int(slot))
            return slot

    def _alloc(self, n_tokens: int, shared_pages: Sequence[int],
               fork_last: bool) -> Optional[int]:
        need = self.lifetime_pages(n_tokens)
        shared = [int(p) for p in shared_pages]
        assert len(shared) <= need and (not fork_last or shared)
        n_borrowed = len(shared) - (1 if fork_last else 0)
        if not self.can_admit(n_tokens, n_shared=n_borrowed):
            return None
        slot = self._free_slots.pop()
        # retain the claim FIRST: refcount >= 2 pages are never eviction
        # candidates, so the eviction pass below can't reclaim them
        for p in shared:
            self.retain_page(p)
        fresh_need = need - n_borrowed
        if fresh_need > len(self._free_pages) and self._prefix is not None:
            self._prefix.evict(fresh_need - len(self._free_pages))
        if fresh_need > len(self._free_pages):   # eviction came up short
            for p in shared:
                self.release_page(p)    # never frees: trie still holds 1
            self._free_slots.append(slot)
            return None
        pages = shared
        if fork_last:
            src = pages[-1]
            dst = self._free_pages.pop()
            self._refs[dst] = 1
            self._fork_page(src, dst)
            pages[-1] = dst
            self.release_page(src)      # drop our claim; trie keeps it
            self.cow_forks += 1
        while len(pages) < need:
            p = self._free_pages.pop()
            self._refs[p] = 1
            pages.append(p)
        self._pages_of[slot] = pages
        self._n_shared[slot] = n_borrowed
        self.page_table[slot] = 0                     # sentinel tail
        self.page_table[slot, :need] = pages
        self._invalidate_table(slot)
        return slot

    def release(self, slot: int):
        """Release a finished request's lane and page references.

        Resets the lane's table row to the sentinel and its ``seq_lens``
        to 0, and decrements each page's refcount — pages also held by
        the prefix trie stay resident; the rest return to the free pool.
        Asserts the lane is currently allocated (double-release is a
        bookkeeping bug, not a recoverable condition).  Freed pages are
        NOT zeroed — the sentinel-tail table row keeps them unattendable
        until re-allocated, and prefill/decode rewrite rows before any
        query can see them."""
        assert 0 <= slot < self.n_slots and slot in self._pages_of, slot
        for p in reversed(self._pages_of.pop(slot)):
            self.release_page(p)
        self._n_shared.pop(slot, None)
        self.page_table[slot] = 0
        self.seq_lens[slot] = 0
        self._prefilling.discard(slot)
        self._free_slots.append(slot)
        self._invalidate_table(slot)

    # ---- page refcounts (lane tables + prefix-trie entries) -------------
    def retain_page(self, page: int):
        """Add one reference to a non-free page (lane claim or trie
        insert)."""
        assert page != 0, "sentinel page is never referenced"
        self._refs[page] = self._refs.get(page, 0) + 1

    def release_page(self, page: int):
        """Drop one reference; at refcount 0 the page rejoins the free
        pool."""
        n = self._refs.get(page, 0)
        assert page != 0 and n > 0, (page, n)
        if n == 1:
            del self._refs[page]
            self._free_pages.append(page)
        else:
            self._refs[page] = n - 1

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def attach_prefix_cache(self, prefix_cache):
        """Wire a ``PrefixCache`` in as the eviction source: when
        ``alloc`` runs out of free pages it asks the trie to reclaim
        LRU refcount-1 pages, and admission headroom counts them."""
        self._prefix = prefix_cache

    @property
    def evictable_pages(self) -> int:
        return 0 if self._prefix is None else self._prefix.evictable_pages()

    def lane_pages(self, slot: int) -> List[int]:
        """Snapshot of a lane's page list (e.g. for trie insertion)."""
        return list(self._pages_of[slot])

    def lane_shared(self, slot: int) -> int:
        """Leading pages of ``slot`` that are shared cached-prefix pages
        (read-only for this lane)."""
        return self._n_shared.get(slot, 0)

    def _fork_page(self, src: int, dst: int):
        """Device-side copy-on-write: duplicate page ``src``'s K/V rows
        into ``dst`` across every layer pool."""
        if self._fork_fn is None:
            donate = (0,) if jax.default_backend() != "cpu" else ()
            self._fork_fn = jax.jit(
                lambda tr, s, d: jax.tree.map(
                    lambda x: x.at[:, d].set(x[:, s]), tr),
                donate_argnums=donate)
        with self.tracer.span("cow_fork", track=TRACK_CACHE,
                              src=int(src), dst=int(dst)) as sp:
            self.tree = self._fork_fn(self.tree, jnp.int32(src),
                                      jnp.int32(dst))
            sp.fence(self.tree)

    def _invalidate_table(self, slot: Optional[int] = None):
        """A page-table mutation stales the cached device snapshots."""
        self._table_dev = None
        if slot is None:
            self._slot_dev.clear()
        else:
            self._slot_dev.pop(slot, None)

    def mark_prefilling(self, slot: int):
        """Flag an allocated lane as mid-prefill — its reservation shows
        up in the ``prefill_pages_in_use`` / ``lanes_prefilling`` gauges
        until ``unmark_prefilling`` (or ``release``)."""
        assert slot in self._pages_of, slot
        self._prefilling.add(slot)

    def unmark_prefilling(self, slot: int):
        self._prefilling.discard(slot)

    def advance(self, slot: int, n: int = 1):
        """Mark ``n`` more rows of lane ``slot`` as written.  Must stay
        within the lane's page reservation — a decode/verify write past it
        would have landed on the sentinel page."""
        new_len = int(self.seq_lens[slot]) + n
        assert slot in self._pages_of and \
            new_len <= len(self._pages_of[slot]) * self.page_size, \
            (slot, new_len)
        self.seq_lens[slot] = new_len

    def rollback(self, slot: int, new_len: int):
        """Shrink lane ``slot``'s valid-row count to ``new_len`` — drops a
        rejected speculative suffix.  Page-table-free by construction:
        the lane keeps its whole reservation, and the dropped rows are
        rewritten (through the same table entries) before any later query
        can attend them, so nothing needs freeing or zeroing.  Asserts
        ``0 <= new_len <= seq_lens[slot]`` — rollback never grows a
        lane."""
        assert slot in self._pages_of, slot
        assert 0 <= new_len <= int(self.seq_lens[slot]), \
            (slot, new_len, int(self.seq_lens[slot]))
        self.seq_lens[slot] = new_len

    # ---- device views ---------------------------------------------------
    def seq_lens_device(self):
        # hand jax a PRIVATE numpy snapshot.  Despite jnp.array's
        # documented copy semantics, on CPU jax 0.4.37 was OBSERVED
        # materializing ``jnp.array(self.seq_lens)`` with values the
        # engine wrote AFTER the call (dispatched decodes read
        # post-``advance`` lengths; ~half of runs produced wrong tokens,
        # the eligibility apparently alignment-/timing-dependent, hence
        # the nondeterminism).  Do not "simplify" the .copy() away —
        # re-aliasing the live buffer resurrects a silent correctness
        # bug.  The snapshot itself is never mutated, so jax aliasing
        # it is safe.  sanitizer.device_view is jnp.asarray plus (under
        # REPRO_SANITIZE=1) zero-copy-alias tracking: dropping the
        # .copy() here becomes a deterministic DispatchRaceError.
        return sanitizer.device_view(self.seq_lens.copy())

    def page_table_device(self, slot: Optional[int] = None):
        # the table only mutates at admission/release (which invalidate
        # via _invalidate_table), so both the whole-table decode view and
        # the per-slot prefill rows are cached instead of re-snapshotted
        # every call (the .copy() snapshots are private to jax — see
        # seq_lens_device for the aliasing rationale)
        if slot is not None:
            dev = self._slot_dev.get(slot)
            if dev is None:
                dev = sanitizer.device_view(self.page_table[slot].copy())
                self._slot_dev[slot] = dev
            return dev
        if self._table_dev is None:
            self._table_dev = sanitizer.device_view(self.page_table.copy())
        return self._table_dev

    # ---- gauges ---------------------------------------------------------
    def gauges(self) -> Dict[str, float]:
        """Cache-utilization gauges: page occupancy, internal
        fragmentation (reserved-but-unwritten rows / reserved rows),
        in-flight prefill — pages reserved by lanes whose prompt is still
        being chunk-prefilled under the interleaved schedule (these pages
        are committed but not yet earning decode tokens) — and prefix-
        cache sharing: ``cache_hit_rate`` (admissions that claimed cached
        pages; 0.0 with no prefix cache attached), ``shared_pages``
        (pages referenced more than once), ``cow_forks`` (cumulative
        copy-on-write page copies).  A degenerate ``page_budget=0`` cache
        reports 0.0 utilization rather than dividing by zero."""
        used_rows = int(self.seq_lens.sum())
        reserved_rows = self.pages_in_use * self.page_size
        frag = 0.0 if reserved_rows == 0 else 1.0 - used_rows / reserved_rows
        prefill_pages = sum(len(self._pages_of[s]) for s in self._prefilling
                            if s in self._pages_of)
        util = (0.0 if self.page_budget == 0
                else self.pages_in_use / self.page_budget)
        hit_rate = 0.0 if self._prefix is None else self._prefix.hit_rate
        return {
            "pages_in_use": float(self.pages_in_use),
            "pages_total": float(self.page_budget),
            "page_utilization": util,
            "kv_fragmentation": frag,
            "lanes_prefilling": float(len(self._prefilling)),
            "prefill_pages_in_use": float(prefill_pages),
            "cache_hit_rate": hit_rate,
            "shared_pages": float(sum(1 for n in self._refs.values()
                                      if n > 1)),
            "cow_forks": float(self.cow_forks),
        }

    def bytes_resident(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.tree))


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_len: int):
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"SlotKVCache requires an attention KV cache; "
                f"family={cfg.family!r} keeps recurrent state instead")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.tree = init_cache(cfg, n_slots, max_len)
        # version-stamped under REPRO_SANITIZE=1 — see PagedKVCache
        self.seq_lens = sanitizer.guard(np.zeros(n_slots, np.int32),
                                        "SlotKVCache.seq_lens")
        self._free = list(range(n_slots - 1, -1, -1))  # pop() -> slot 0 first
        self._prefilling: set = set()    # lanes mid-prefill (gauges)
        # slot allocation is a host-side list pop — no spans worth a
        # track row; the attr just keeps set_tracer layout-agnostic
        self.tracer = NULL_TRACER

    # ---- slot lifecycle -------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return bool(self._free) and n_tokens <= self.max_len

    def alloc(self, n_tokens: int = 0) -> Optional[int]:
        """Claim a free slot (or None).  The caller prefills it next."""
        if not self.can_admit(n_tokens):
            return None
        return self._free.pop()

    def release(self, slot: int):
        """Return a finished request's slot to the pool."""
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self.seq_lens[slot] = 0
        self._prefilling.discard(slot)
        self._free.append(slot)

    def mark_prefilling(self, slot: int):
        """Flag an allocated lane as mid-prefill (``lanes_prefilling``
        gauge) until ``unmark_prefilling`` (or ``release``)."""
        assert slot not in self._free, slot
        self._prefilling.add(slot)

    def unmark_prefilling(self, slot: int):
        self._prefilling.discard(slot)

    def advance(self, slot: int, n: int = 1):
        """Mark ``n`` more rows of ``slot`` as written (bounded by the
        slot's fixed ``max_len`` capacity)."""
        new_len = int(self.seq_lens[slot]) + n
        assert new_len <= self.max_len, (slot, new_len)
        self.seq_lens[slot] = new_len

    # ---- device views ---------------------------------------------------
    def seq_lens_device(self):
        # see PagedKVCache.seq_lens_device for the snapshot rationale
        return sanitizer.device_view(self.seq_lens.copy())

    # ---- gauges ---------------------------------------------------------
    def gauges(self) -> Dict[str, float]:
        """Slot-layout analogues of the paged gauges — keyed ``slot*``
        since the unit is a whole max_len lane, not a page: every
        admitted lane reserves max_len rows, so fragmentation is the
        unwritten share."""
        used_rows = int(self.seq_lens.sum())
        reserved_rows = self.n_active * self.max_len
        frag = 0.0 if reserved_rows == 0 else 1.0 - used_rows / reserved_rows
        return {
            "slots_in_use": float(self.n_active),
            "slots_total": float(self.n_slots),
            "slot_utilization": self.n_active / self.n_slots,
            "kv_fragmentation": frag,
            "lanes_prefilling": float(len(self._prefilling)),
        }

    def bytes_resident(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.tree))
