"""Slot-based KV cache for continuous batching.

A fixed pool of ``n_slots`` batch lanes over the model's decode cache
([L, B, T, K, hd] K/V arrays).  Each slot carries its own ``seq_len`` —
the number of valid cache rows — so requests of different lengths share
one jitted decode step, and a slot vacated by a finished request can be
re-filled by a newly admitted request mid-flight without touching the
other lanes (prefill simply overwrites the slot's rows from position 0).

The device arrays live in ``tree`` and are updated functionally by the
jitted prefill/decode calls; this class owns the host-side bookkeeping
(free list, per-slot lengths).

Invariant: free slots are dirty, not zeroed — batched ragged decode
writes its placeholder token's K/V into row 0 of every free lane (lanes
are fixed under jit), and finished slots keep their old rows.  This is
safe because admission always chunk-prefills a slot from row 0 before
any of its rows are attended; a future mid-slot prefill (e.g. paged KV)
must clear or rewrite row 0 first.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.models import init_cache


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_len: int):
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"SlotKVCache requires an attention KV cache; "
                f"family={cfg.family!r} keeps recurrent state instead")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.tree = init_cache(cfg, n_slots, max_len)
        self.seq_lens = np.zeros(n_slots, np.int32)
        self._free = list(range(n_slots - 1, -1, -1))  # pop() -> slot 0 first

    # ---- slot lifecycle -------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self) -> Optional[int]:
        """Claim a free slot (or None).  The caller prefills it next."""
        if not self._free:
            return None
        return self._free.pop()

    def free(self, slot: int):
        """Return a finished request's slot to the pool."""
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self.seq_lens[slot] = 0
        self._free.append(slot)

    # ---- device views ---------------------------------------------------
    def seq_lens_device(self):
        # jnp.array (not asarray): on CPU, asarray can alias the numpy
        # buffer zero-copy, and the engine mutates seq_lens while the async
        # decode dispatch may still be reading it — a data race.
        return jnp.array(self.seq_lens)

    def bytes_resident(self) -> int:
        import jax
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.tree))
