"""KV caches for continuous batching: page-granular (default) and the
legacy slot-granular layout.

``PagedKVCache`` stores K/V as [L, n_pages, page_size, K, hd] pools plus a
per-lane page table [n_slots, max_pages]: lane ``b``'s logical rows
[i*ps, (i+1)*ps) live in physical page ``page_table[b, i]``.  Pages — not
whole ``max_len`` slots — are the allocation unit, so many short requests
pack densely into the same pool a few long ones would use, and the pool
budget (``n_pages``) can be provisioned for the live-token working set
rather than ``n_slots * max_len`` worst case.

Paged invariants (asserted by tests/test_paged_serving.py):
  * **Page 0 is a sentinel** — never allocated to a request.  Free lanes'
    table rows and table entries past a lane's reservation all point at
    it, so the batched decode step's placeholder writes for idle lanes
    and prefill's chunk-padding writes land in page 0, which is never
    attended (length masking).  Allocated pages are therefore never
    dirtied by another lane — the slot layout's "free slots are dirty,
    prefill must rewrite row 0 first" invariant is gone by construction.
  * **No page is owned by two lanes**: ``alloc`` hands out each non-
    sentinel page to at most one lane until ``free`` returns it.
  * **Reservation covers the request lifetime**: admission reserves
    ``ceil((prompt + max_new_tokens + overdraft)/ps)`` pages up front, so
    a decode step can never run out of pages mid-flight (the engine has
    no preemption).  ``overdraft`` (speculative decoding: ``spec_k - 1``)
    covers verify-block rows written past the request's own lifetime and
    then rolled back via ``rollback()`` — reserved so block writes land
    in lane-owned pages, never on the shared sentinel.  The admission
    *gate* is page availability, not lane count alone.

The device arrays live in ``tree`` and are updated functionally by the
jitted prefill/decode calls; this class owns the host-side bookkeeping
(free page pool, per-lane tables and lengths).

``SlotKVCache`` keeps the PR-1 slot-granular layout ([L, B, T, K, hd],
one ``max_len`` slot per lane) — it remains the reference implementation
the paged engine is tested token-identical against, and its docstring
invariant still applies: free slots are dirty, and batched ragged decode
writes idle lanes' placeholder K/V into row 0, which is safe only because
slot prefill always rewrites from row 0 before any row is attended.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro.analysis import sanitizer
from repro.models import init_cache, init_paged_cache


class PagedKVCache:
    """Page-granular KV cache: fixed page pool + per-lane page tables."""

    def __init__(self, cfg, n_slots: int, max_len: int, page_size: int,
                 page_budget: Optional[int] = None, overdraft: int = 0):
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"PagedKVCache requires an attention KV cache; "
                f"family={cfg.family!r} keeps recurrent state instead")
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        # ``overdraft`` rows per lane beyond the request's own lifetime:
        # speculative decoding writes a verify block of W = spec_k + 1
        # tokens starting at the last emitted position, so up to
        # spec_k - 1 rows past ``prompt + max_new_tokens`` are written
        # (then rolled back, never attended).  Reserving them keeps every
        # block write inside pages the lane owns — without the overdraft
        # those writes would fall onto the shared sentinel page, where a
        # same-dispatch query of another lane could read them.
        self.overdraft = overdraft
        self.max_pages = -(-(max_len + overdraft) // page_size)  # table width
        self.max_len = self.max_pages * page_size     # lane logical capacity
        if page_budget is None:
            page_budget = n_slots * self.max_pages    # fits slot worst case
        self.page_budget = page_budget
        self.n_pages = page_budget + 1                # + sentinel page 0
        self.tree = init_paged_cache(cfg, self.n_pages, page_size)
        # under REPRO_SANITIZE=1 these carry version-stamped guards: a
        # device view built from the live buffer + a later mutation is a
        # deterministic DispatchRaceError instead of a timing coin flip
        self.seq_lens = sanitizer.guard(np.zeros(n_slots, np.int32),
                                        "PagedKVCache.seq_lens")
        self.page_table = sanitizer.guard(
            np.zeros((n_slots, self.max_pages), np.int32),
            "PagedKVCache.page_table")
        self._free_slots = list(range(n_slots - 1, -1, -1))  # pop() -> 0
        self._free_pages = list(range(self.n_pages - 1, 0, -1))  # never 0
        self._pages_of: Dict[int, List[int]] = {}
        self._prefilling: set = set()    # lanes mid-prefill (gauges)
        self._table_dev = None           # device copy, rebuilt on mutation

    # ---- lifecycle ------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def pages_in_use(self) -> int:
        return self.page_budget - len(self._free_pages)

    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` rows — pure page math, no overdraft."""
        return -(-n_tokens // self.page_size)

    def lifetime_pages(self, n_tokens: int) -> int:
        """Pages ``alloc(n_tokens)`` will actually reserve: the request's
        ``n_tokens`` lifetime rows plus the cache-wide speculative
        ``overdraft`` rows."""
        return self.pages_needed(n_tokens + self.overdraft)

    def can_admit(self, n_tokens: int) -> bool:
        return (bool(self._free_slots)
                and self.lifetime_pages(n_tokens) <= len(self._free_pages)
                and n_tokens + self.overdraft <= self.max_len)

    def alloc(self, n_tokens: int) -> Optional[int]:
        """Claim a free lane plus pages for ``n_tokens`` lifetime rows.

        Reserves ``lifetime_pages(n_tokens)`` pages (the overdraft rows
        for speculative block writes are part of the reservation) and
        points the lane's page-table row at them, sentinel tail beyond.
        Returns the lane index, or None when lanes or pages are short —
        never raises; admission simply waits.  The caller prefills the
        lane next; until then ``seq_lens[slot]`` stays 0."""
        need = self.lifetime_pages(n_tokens)
        if not self.can_admit(n_tokens):
            return None
        slot = self._free_slots.pop()
        pages = [self._free_pages.pop() for _ in range(need)]
        self._pages_of[slot] = pages
        self.page_table[slot] = 0                     # sentinel tail
        self.page_table[slot, :need] = pages
        self._table_dev = None
        return slot

    def free(self, slot: int):
        """Return a finished request's lane and pages to the pools.

        Resets the lane's table row to the sentinel and its ``seq_lens``
        to 0.  Asserts the lane is currently allocated (double-free is a
        bookkeeping bug, not a recoverable condition).  Freed pages are
        NOT zeroed — the sentinel-tail table row keeps them unattendable
        until re-allocated, and prefill/decode rewrite rows before any
        query can see them."""
        assert 0 <= slot < self.n_slots and slot in self._pages_of, slot
        self._free_pages.extend(reversed(self._pages_of.pop(slot)))
        self.page_table[slot] = 0
        self.seq_lens[slot] = 0
        self._prefilling.discard(slot)
        self._free_slots.append(slot)
        self._table_dev = None

    def mark_prefilling(self, slot: int):
        """Flag an allocated lane as mid-prefill — its reservation shows
        up in the ``prefill_pages_in_use`` / ``lanes_prefilling`` gauges
        until ``unmark_prefilling`` (or ``free``)."""
        assert slot in self._pages_of, slot
        self._prefilling.add(slot)

    def unmark_prefilling(self, slot: int):
        self._prefilling.discard(slot)

    def advance(self, slot: int, n: int = 1):
        """Mark ``n`` more rows of lane ``slot`` as written.  Must stay
        within the lane's page reservation — a decode/verify write past it
        would have landed on the sentinel page."""
        new_len = int(self.seq_lens[slot]) + n
        assert slot in self._pages_of and \
            new_len <= len(self._pages_of[slot]) * self.page_size, \
            (slot, new_len)
        self.seq_lens[slot] = new_len

    def rollback(self, slot: int, new_len: int):
        """Shrink lane ``slot``'s valid-row count to ``new_len`` — drops a
        rejected speculative suffix.  Page-table-free by construction:
        the lane keeps its whole reservation, and the dropped rows are
        rewritten (through the same table entries) before any later query
        can attend them, so nothing needs freeing or zeroing.  Asserts
        ``0 <= new_len <= seq_lens[slot]`` — rollback never grows a
        lane."""
        assert slot in self._pages_of, slot
        assert 0 <= new_len <= int(self.seq_lens[slot]), \
            (slot, new_len, int(self.seq_lens[slot]))
        self.seq_lens[slot] = new_len

    # ---- device views ---------------------------------------------------
    def seq_lens_device(self):
        # hand jax a PRIVATE numpy snapshot.  Despite jnp.array's
        # documented copy semantics, on CPU jax 0.4.37 was OBSERVED
        # materializing ``jnp.array(self.seq_lens)`` with values the
        # engine wrote AFTER the call (dispatched decodes read
        # post-``advance`` lengths; ~half of runs produced wrong tokens,
        # the eligibility apparently alignment-/timing-dependent, hence
        # the nondeterminism).  Do not "simplify" the .copy() away —
        # re-aliasing the live buffer resurrects a silent correctness
        # bug.  The snapshot itself is never mutated, so jax aliasing
        # it is safe.  sanitizer.device_view is jnp.asarray plus (under
        # REPRO_SANITIZE=1) zero-copy-alias tracking: dropping the
        # .copy() here becomes a deterministic DispatchRaceError.
        return sanitizer.device_view(self.seq_lens.copy())

    def page_table_device(self, slot: Optional[int] = None):
        if slot is not None:
            return sanitizer.device_view(self.page_table[slot].copy())
        # the table only mutates at admission/free, so the decode loop's
        # per-step copy is cached (the .copy() snapshot is private to
        # jax — see seq_lens_device for the aliasing rationale)
        if self._table_dev is None:
            self._table_dev = sanitizer.device_view(self.page_table.copy())
        return self._table_dev

    # ---- gauges ---------------------------------------------------------
    def gauges(self) -> Dict[str, float]:
        """Cache-utilization gauges: page occupancy, internal
        fragmentation (reserved-but-unwritten rows / reserved rows), and
        in-flight prefill — pages reserved by lanes whose prompt is still
        being chunk-prefilled under the interleaved schedule (these pages
        are committed but not yet earning decode tokens)."""
        used_rows = int(self.seq_lens.sum())
        reserved_rows = self.pages_in_use * self.page_size
        frag = 0.0 if reserved_rows == 0 else 1.0 - used_rows / reserved_rows
        prefill_pages = sum(len(self._pages_of[s]) for s in self._prefilling
                            if s in self._pages_of)
        return {
            "pages_in_use": float(self.pages_in_use),
            "pages_total": float(self.page_budget),
            "page_utilization": self.pages_in_use / self.page_budget,
            "kv_fragmentation": frag,
            "lanes_prefilling": float(len(self._prefilling)),
            "prefill_pages_in_use": float(prefill_pages),
        }

    def bytes_resident(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.tree))


class SlotKVCache:
    def __init__(self, cfg, n_slots: int, max_len: int):
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"SlotKVCache requires an attention KV cache; "
                f"family={cfg.family!r} keeps recurrent state instead")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.tree = init_cache(cfg, n_slots, max_len)
        # version-stamped under REPRO_SANITIZE=1 — see PagedKVCache
        self.seq_lens = sanitizer.guard(np.zeros(n_slots, np.int32),
                                        "SlotKVCache.seq_lens")
        self._free = list(range(n_slots - 1, -1, -1))  # pop() -> slot 0 first
        self._prefilling: set = set()    # lanes mid-prefill (gauges)

    # ---- slot lifecycle -------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return bool(self._free) and n_tokens <= self.max_len

    def alloc(self, n_tokens: int = 0) -> Optional[int]:
        """Claim a free slot (or None).  The caller prefills it next."""
        if not self.can_admit(n_tokens):
            return None
        return self._free.pop()

    def free(self, slot: int):
        """Return a finished request's slot to the pool."""
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self.seq_lens[slot] = 0
        self._prefilling.discard(slot)
        self._free.append(slot)

    def mark_prefilling(self, slot: int):
        """Flag an allocated lane as mid-prefill (``lanes_prefilling``
        gauge) until ``unmark_prefilling`` (or ``free``)."""
        assert slot not in self._free, slot
        self._prefilling.add(slot)

    def unmark_prefilling(self, slot: int):
        self._prefilling.discard(slot)

    def advance(self, slot: int, n: int = 1):
        """Mark ``n`` more rows of ``slot`` as written (bounded by the
        slot's fixed ``max_len`` capacity)."""
        new_len = int(self.seq_lens[slot]) + n
        assert new_len <= self.max_len, (slot, new_len)
        self.seq_lens[slot] = new_len

    # ---- device views ---------------------------------------------------
    def seq_lens_device(self):
        # see PagedKVCache.seq_lens_device for the snapshot rationale
        return sanitizer.device_view(self.seq_lens.copy())

    # ---- gauges ---------------------------------------------------------
    def gauges(self) -> Dict[str, float]:
        """Slot-layout analogues of the paged gauges — keyed ``slot*``
        since the unit is a whole max_len lane, not a page: every
        admitted lane reserves max_len rows, so fragmentation is the
        unwritten share."""
        used_rows = int(self.seq_lens.sum())
        reserved_rows = self.n_active * self.max_len
        frag = 0.0 if reserved_rows == 0 else 1.0 - used_rows / reserved_rows
        return {
            "slots_in_use": float(self.n_active),
            "slots_total": float(self.n_slots),
            "slot_utilization": self.n_active / self.n_slots,
            "kv_fragmentation": frag,
            "lanes_prefilling": float(len(self._prefilling)),
        }

    def bytes_resident(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.tree))
