"""Continuous-batching serving engine: chunked prefill + paged-KV decode.

The serving path is where STUN's wins land: a 25%-expert-pruned MoE has a
proportionally smaller EP all-to-all and per-chip weight set, and the
block-sparse kernel exploits stage-2 masks.  The engine:

  * **chunked prefill** — an S-token prompt is replayed through
    ``models.prefill_step_paged`` in fixed-size chunks, each a single
    jitted dispatch that computes the chunk forward, writes its K/V
    through the lane's page table, and masks padded / unwritten
    positions.  Cost is ``ceil(S/chunk)`` dispatches, independent of S.
  * **interleaved prefill/decode schedule** (``schedule="interleaved"``,
    the default) — each admitted request carries a resumable prefill
    cursor (``RequestState.prefill_pos``), and every engine step packs at
    most ``prefill_budget`` prompt-chunk tokens (Sarathi-style token
    budget, FIFO over mid-prefill requests) *before* the batched decode
    dispatch.  Decode lanes therefore never stall more than one budget's
    worth of prefill per token, instead of a whole prompt's
    ``ceil(S/chunk)`` dispatches.  ``schedule="blocking"`` keeps the
    PR-1 behaviour — an admitted prompt prefills to completion before
    the next decode dispatch — as the tested-identical reference
    (greedy outputs are token-identical between the two schedules; only
    latency differs).
  * **paged KV cache** (`kv_cache.PagedKVCache`, the default layout) —
    K/V in fixed-size pages with per-lane page tables; admission is
    page-budget-gated (a request needs pages for its whole
    prompt + max_new_tokens lifetime, not a whole ``max_len`` slot), and
    a finished request's page list returns to the pool immediately.
    Decode attention runs through the fused Pallas ragged paged kernel
    (`kernels.paged_decode_attention`) on TPU, its jnp gather reference
    elsewhere.  ``kv_layout="slot"`` keeps the PR-1 slot-granular cache —
    the reference the paged path is tested token-identical against.
  * **prefix caching** (``prefix_cache=True``, paged layout only —
    `prefix_cache.PrefixCache`) — a radix tree over page-aligned prompt
    chunks maps cached prefixes to physical page lists; admission claims
    the longest cached prefix by pointing the new lane's leading page-
    table entries at shared refcounted pages and starting the resumable
    prefill cursor at the claimed length.  A fully cached prompt costs
    **zero** prefill dispatches: the last shared page is forked
    copy-on-write and the final prompt token is replayed through the
    ordinary batched decode dispatch.  Finished lanes ``release`` (pages
    stay resident while cached); LRU eviction reclaims unreferenced
    cached pages under pool pressure.  Token streams are identical to
    cache-off serving (oracle-pinned in tests/test_prefix_cache.py).
  * **scheduler** (`scheduler.Scheduler`) — FIFO admission, per-request
    EOS / ``max_new_tokens`` termination (no post-EOS tokens, no decode
    steps burned on finished requests), per-request greedy or temperature
    sampling.  Requests that can never fit the cache are rejected at
    ``submit()`` with a ValueError rather than corrupting rows later.
  * **pruned-model plumbing** — a runtime ``expert_mask`` ([E] or [L, E])
    flows into every prefill/decode dispatch, and stage-2 unstructured
    masks from ``core.unstructured.sparsify_model`` can be re-applied to
    the weights at load time via ``weight_masks=``.
  * **sparse pruned-artifact runtime** (``sparse_weights=`` — a packed
    artifact from ``repro.sparse.pack_sparse_ffn``) — expert FFN weights
    load block-compressed (live MXU-tile blocks in a pool + per-expert
    block index, paged-KV-for-weights) instead of being densified by a
    load-time multiply, so a φ-block-sparse FFN is *physically smaller*
    in memory and its matmuls dispatch through the Pallas block-sparse
    gather kernel on TPU.  Off-TPU the execute path unpacks inside the
    dispatch and replays the identical einsum, so packed serving is
    bit-identical to ``weight_masks=`` serving with the plan's masks
    (oracle-pinned in tests/test_disaggregation.py).
  * **self-speculative decoding** (``spec_decode="pruned"``, paged layout
    only — `speculative.SpeculativeDecoder`) — the pruned artifact drafts
    a ``spec_tree`` x ``spec_k`` token tree per round in one fused
    dispatch and the dense model verifies the whole tree in one batched
    ``models.verify_step_paged`` dispatch over the same page tables.
    Greedy output stays token-identical to dense-only decode; sampled
    (``temperature > 0``) requests go through rejection-sampling
    verification, which keeps the emitted distribution exactly the dense
    model's (statistically pinned).  Dispatches per token drop to
    ``2 / (accepted + 1)``.
  * **per-request PRNG key chains** — all sampling noise derives from
    ``(seed, request_id, token_index)``, so a request's sampled token
    stream never depends on batch composition, admission order, or the
    prefill schedule.

Recurrent families (ssm/hybrid) have no length-indexed cache; they fall
back to a correct sequential per-request path.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizer
from repro.models import (decode_step, decode_step_paged, decode_step_ragged,
                          init_cache, prefill_step, prefill_step_paged)
from repro.sparse import install_sparse_ffn
from repro.serving import telemetry
from repro.serving.kv_cache import PagedKVCache, SlotKVCache
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Request, Scheduler
from repro.serving.speculative import (ROLE_TARGET, SpeculativeDecoder,
                                       request_key)
from repro.serving.telemetry import NULL_TRACER, Tracer, lane_track


def apply_weight_masks(params, cfg, masks: Dict):
    """Re-apply stage-2 block/unstructured sparsity masks to a param tree.

    ``masks`` is the ``{(layer, path) -> bool ndarray}`` dict returned by
    ``core.unstructured.sparsify_model`` — multiplying them back in keeps a
    served checkpoint exactly as sparse as the pruner left it (e.g. after
    fine-tuning or dtype casts re-densified small values).
    """
    from repro.core.unstructured import _get_path, _set_path

    stacked = cfg.family != "hybrid" and cfg.scan_layers
    new_params = params
    if stacked:
        # group per weight path so each stacked [L, ...] tensor is copied
        # once, not once per layer
        by_path: Dict = {}
        for (l, path), mask in masks.items():
            by_path.setdefault(path, []).append((l, mask))
        layers = new_params["layers"]
        for path, entries in by_path.items():
            W = _get_path(layers, path)
            Wn = np.asarray(W, np.float32).copy()
            for l, mask in entries:
                Wn[l] = Wn[l] * mask
            layers = _set_path(layers, path, jnp.asarray(Wn, dtype=W.dtype))
        return {**new_params, "layers": layers}
    for (l, path), mask in masks.items():
        sub = new_params["layers"][str(l)]
        W = _get_path(sub, path)
        Wn = np.asarray(W, np.float32) * mask
        sub = _set_path(sub, path, jnp.asarray(Wn, dtype=W.dtype))
        new_params = {**new_params,
                      "layers": {**new_params["layers"], str(l): sub}}
    return new_params


class ServeEngine:
    """Continuous-batching serve engine (see module docstring).

    ``spec_decode="pruned"`` turns on self-speculative decoding on the
    paged layout: the engine holds TWO param sets — the dense ``params``
    (prefill + verify) and a pruned drafter built from the same weights.
    In spec mode ``expert_mask`` / ``weight_masks`` / ``draft_params`` /
    ``sparse_weights`` describe the *drafter* (served output is
    dense-model quality: token-identical to plain greedy decode at
    temperature 0, distribution-identical under rejection sampling at
    temperature > 0); outside spec mode they prune the served model
    itself, as before.  ``spec_k`` draft tokens are proposed per branch
    per round (default 4) and ``spec_tree`` branches open at the first
    draft position (default 1 — the classic chain).

    ``sparse_weights`` is a packed artifact from
    ``repro.sparse.pack_sparse_ffn``: expert FFN weights are replaced by
    their block-compressed form (applied after ``weight_masks``, which
    then only dense-masks the non-FFN weights).  ``sparse_exec``
    optionally forces the execute path ("exact" | "gather" | "pallas" |
    "interpret"; default: kernel on TPU, bit-exact unpack elsewhere).

    ``prefix_cache=True`` (paged layout only) turns on radix-tree KV
    reuse: admissions claim the longest cached page-aligned prompt
    prefix (refcounted shared pages, copy-on-write at a shared last
    page) and prefill only the remainder — zero dispatches for a fully
    cached prompt.  ``prefix_cache_max_pages`` optionally caps trie
    residency below what pool pressure alone would enforce.

    ``schedule="interleaved"`` (default) meters prefill at
    ``prefill_budget`` prompt tokens per step (rounded down to whole
    ``prefill_chunk`` chunks, min one; default one chunk) so decode lanes
    never stall behind a long prompt; ``schedule="blocking"`` runs each
    admitted prompt's prefill to completion first — the reference
    schedule interleaved is tested token-identical against (greedy AND
    sampled: per-request key chains make sampled streams
    schedule-invariant too).
    """

    def __init__(self, params, cfg, max_len: int = 512, mesh=None,
                 max_batch: int = 8, prefill_chunk: int = 32,
                 expert_mask=None, weight_masks: Optional[Dict] = None,
                 seed: int = 0, kv_layout: str = "paged",
                 page_size: int = 16, page_budget: Optional[int] = None,
                 spec_decode: Optional[str] = None, spec_k: int = 4,
                 spec_tree: int = 1,
                 draft_params=None, schedule: str = "interleaved",
                 prefill_budget: Optional[int] = None,
                 sparse_weights: Optional[Dict] = None,
                 sparse_exec: Optional[str] = None,
                 prefix_cache: bool = False,
                 prefix_cache_max_pages: Optional[int] = None,
                 trace=None):
        if kv_layout not in ("paged", "slot"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if prefix_cache and kv_layout != "paged":
            raise ValueError(
                "prefix_cache requires kv_layout='paged': cached prefixes "
                "are shared physical pages claimed through page tables")
        if prefix_cache and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"prefix_cache requires a paged KV cache; "
                f"family={cfg.family!r} keeps recurrent state instead")
        if prefix_cache_max_pages is not None and not prefix_cache:
            raise ValueError("prefix_cache_max_pages without prefix_cache")
        if schedule not in ("interleaved", "blocking"):
            raise ValueError(f"unknown schedule {schedule!r}")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1")
        if spec_decode not in (None, "pruned"):
            raise ValueError(f"unknown spec_decode {spec_decode!r}")
        if sparse_weights is not None and cfg.family != "moe":
            raise ValueError("sparse_weights packs expert FFNs; "
                             f"family={cfg.family!r} has none")
        if sparse_exec:
            if sparse_weights is None:
                raise ValueError("sparse_exec without sparse_weights")
            cfg = dataclasses.replace(cfg, sparse_exec=sparse_exec)
        if spec_decode is not None:
            if kv_layout != "paged":
                raise ValueError(
                    "spec_decode requires kv_layout='paged': draft and "
                    "verify share one paged KV layout (the verify block "
                    "is scattered through the drafter's page tables)")
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    f"spec_decode requires a KV cache; family={cfg.family!r}")
            if spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            if spec_tree < 1:
                raise ValueError("spec_tree must be >= 1")
            # two param sets: dense verifies, the pruned artifact drafts
            draft = params if draft_params is None else draft_params
            if weight_masks:
                draft = apply_weight_masks(draft, cfg, weight_masks)
            if sparse_weights is not None:
                draft = install_sparse_ffn(draft, cfg, sparse_weights)
            self.draft_params = draft
        else:
            if weight_masks:
                params = apply_weight_masks(params, cfg, weight_masks)
            if sparse_weights is not None:
                params = install_sparse_ffn(params, cfg, sparse_weights)
            self.draft_params = None
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.mesh = mesh
        self.max_batch = max_batch
        self.prefill_chunk = min(prefill_chunk, max_len)
        self.schedule = schedule
        # Sarathi-style per-step prompt-token budget (interleaved
        # schedule): each step dispatches at most this many prompt tokens
        # of chunked prefill before the decode dispatch.  Rounded down to
        # whole chunks, minimum one chunk per step so prefill always
        # progresses.  Default: one chunk — decode lanes stall at most
        # one chunk dispatch per token.
        self.prefill_budget = (self.prefill_chunk if prefill_budget is None
                               else prefill_budget)
        self._budget_chunks = max(1, self.prefill_budget // self.prefill_chunk)
        self.kv_layout = kv_layout
        self.spec_decode = spec_decode
        self.spec_k = spec_k if spec_decode else 0
        self.spec_tree = spec_tree if spec_decode else 0
        self.scheduler = Scheduler(max_request_tokens=max_len)
        # rid -> (padded prompt buffer, S, n_pad, prefill ref) for
        # requests mid-prefill; the resumable cursor itself lives in
        # RequestState.prefill_pos
        self._prefills: Dict[int, tuple] = {}
        self.prefill_dispatches = 0      # jitted prefill calls (bench hook)
        self.decode_dispatches = 0
        self.requests_admitted = 0
        self.requests_canceled = 0       # cancel() calls that removed state
        self.pages_allocated = 0         # lifetime pages over all admissions
        # per-request PRNG key chains: every random draw derives from
        # (seed, rid, token-index) via speculative.request_key, so a
        # request's sampled stream is invariant to batch composition,
        # admission order, and schedule (there is no shared mutable
        # key stream anymore)
        self._base_key = jax.random.PRNGKey(seed)
        self._attn_cache = cfg.family not in ("ssm", "hybrid")

        em = None if expert_mask is None else jnp.asarray(expert_mask,
                                                          jnp.float32)
        # in spec mode the runtime expert mask prunes the DRAFTER only;
        # prefill/decode/verify run the dense model
        draft_em, em = (em, None) if spec_decode else (None, em)
        if self._attn_cache:
            # round the lane capacity up to whole prefill chunks: the last
            # chunk of a max_len-long prompt may extend past max_len, and
            # its padded rows must land in maskable (slot) or sentinel
            # (paged) storage rather than corrupt earlier rows
            C = self.prefill_chunk
            lane_len = ((max_len + C - 1) // C) * C
            # donate the cache arg: the engine always replaces cache.tree
            # with the result, and without donation every dispatch copies
            # the whole K/V pool.  CPU ignores donation with a warning, so
            # only donate on accelerators.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            if kv_layout == "paged":
                # widest spec block writes rows [n, n + spec_tree*spec_k]
                # with n <= total-2, so the reservation needs
                # spec_tree*spec_k - 1 overdraft rows past each lifetime
                self.cache = PagedKVCache(
                    cfg, max_batch, lane_len, page_size, page_budget,
                    overdraft=max(0, self.spec_tree * self.spec_k - 1))
                self._prefill = jax.jit(
                    lambda p, c, t, row, start: prefill_step_paged(
                        p, cfg, c, t, row, start, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
                self._decode = jax.jit(
                    lambda p, c, t, sl, tbl: decode_step_paged(
                        p, cfg, c, t, sl, tbl, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
            else:
                self.cache = SlotKVCache(cfg, max_batch, lane_len)
                self._prefill = jax.jit(
                    lambda p, c, t, slot, start: prefill_step(
                        p, cfg, c, t, slot, start, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
                self._decode = jax.jit(
                    lambda p, c, t, sl: decode_step_ragged(
                        p, cfg, c, t, sl, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
        else:
            self.cache = None
            self._decode_uniform = jax.jit(
                lambda p, c, t, n: decode_step(p, cfg, c, t, n, mesh=mesh,
                                               expert_mask=em))
        self.prefix_cache = None
        if prefix_cache:
            self.prefix_cache = PrefixCache(
                self.cache, page_size, max_pages=prefix_cache_max_pages)
            self.cache.attach_prefix_cache(self.prefix_cache)
            # partial-hit claims must leave the resumable prefill cursor
            # both chunk-aligned (so pad rows land on the sentinel, never
            # past the page table) and page-aligned (whole shared pages)
            self._claim_grain = math.lcm(self.prefill_chunk, page_size)
        self._spec = (SpeculativeDecoder(cfg, spec_k, mesh=mesh,
                                         draft_expert_mask=draft_em,
                                         donate=donate,
                                         n_branches=spec_tree, seed=seed)
                      if spec_decode else None)
        self._sample = jax.jit(self._sample_fn)
        # span tracer (telemetry.py): None/False -> the shared no-op
        # NullTracer (zero-allocation trace points — the default);
        # True -> a fresh Tracer; or pass a configured Tracer (e.g.
        # Tracer(fence_rate=0.1) to sample block_until_ready fencing)
        if trace is None or trace is False:
            tracer = NULL_TRACER
        elif trace is True:
            tracer = Tracer()
        elif isinstance(trace, (Tracer, telemetry.NullTracer)):
            tracer = trace
        else:
            raise ValueError(f"trace must be a Tracer, bool, or None: "
                             f"{trace!r}")
        self.set_tracer(tracer)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def set_tracer(self, tracer) -> None:
        """Wire ``tracer`` through every instrumented component (caches,
        scheduler completion hook).  Called by ``__init__``; also usable
        post-construction, e.g. to attach a fresh tracer after a
        warmup/compile wave so the trace covers only steady state."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.cache is not None:
            self.cache.tracer = self.tracer
        if self.prefix_cache is not None:
            self.prefix_cache.tracer = self.tracer
        # retroactive per-request lifecycle spans fire at completion,
        # while the stage stamps are still attached to the state
        self.scheduler.on_finish = (self.tracer.request_done
                                    if self.tracer.enabled else None)

    def submit(self, request: Request) -> int:
        """Queue a request; returns its id.  ``run()`` drains the queue.

        ``request.prompt`` is a 1-D int32 array of token ids in
        ``[0, cfg.vocab)``; outputs are 1-D int32 arrays of length
        ``<= max_new_tokens`` (shorter only when ``eos_id`` fires, which
        is then the final token).

        Raises ValueError for requests that could never be admitted
        (nothing is queued, no state leaks): empty prompts,
        ``prompt + max_new_tokens`` past ``max_len``, or requests whose
        lifetime page reservation (including the speculative overdraft)
        exceeds the whole page budget on the paged layout.  Sampled
        (``temperature > 0``) requests are served in spec-decode mode
        too: rejection-sampling verification keeps the emitted
        distribution exactly the dense model's at any temperature.
        """
        self._validate(request)
        rid = self.scheduler.submit(request, time.monotonic())
        self.tracer.record_request(rid, request.prompt,
                                   request.max_new_tokens,
                                   request.temperature)
        return rid

    def _validate(self, request: Request):
        """Raise ValueError for a request that could never be admitted —
        shared by ``submit`` and frontends that want to reject before
        queueing (nothing is mutated)."""
        if len(request.prompt) < 1:
            raise ValueError("empty prompt")
        total = len(request.prompt) + request.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"prompt({len(request.prompt)}) + max_new_tokens"
                f"({request.max_new_tokens}) exceeds max_len={self.max_len}")
        if isinstance(self.cache, PagedKVCache):
            need = self.cache.lifetime_pages(total)
            if need > self.cache.page_budget:
                raise ValueError(
                    f"request needs {need} pages "
                    f"({total} tokens + {self.cache.overdraft} overdraft "
                    f"rows at page_size="
                    f"{self.cache.page_size}) but the cache's whole page "
                    f"budget is {self.cache.page_budget}")

    def can_admit_now(self, request: Request) -> bool:
        """Would ``request`` be admitted by the next ``step()`` if it sat
        at the head of the queue — a free lane plus page headroom for its
        whole lifetime?  A *conservative* backpressure gate for streaming
        frontends (ignores prefix-cache sharing, which only reduces the
        pages actually drawn): False means "hold it client-side", not
        "submit would fail" — the FIFO admission loop copes either way."""
        if self.cache is None:
            return True                  # sequential fallback: no lanes
        total = len(request.prompt) + request.max_new_tokens
        return self.cache.can_admit(total)

    def cancel(self, rid: int) -> bool:
        """Abort request ``rid`` at whatever stage it is in, releasing its
        lane and page references immediately.  Returns True if state was
        removed; False for unknown rids and — deliberately — requests
        that already finished (their tokens belong to the caller until
        ``result()`` collects them; cancel never destroys a completed
        stream).

        Safe at every lifecycle point the single-threaded step loop can
        observe: **pending** (nothing allocated — just dequeued),
        **mid-prefill** (lane + lifetime reservation released; the staged
        prompt buffer is dropped; nothing was inserted into the prefix
        trie, which only ever caches *fully prefilled* prompts), and
        **decode-active** (lane released exactly like a finished
        request — shared prefix pages decrement their refcount, private
        pages return to the pool; in spec mode the next decode round
        simply rebuilds its lane list without the canceled request).
        The canceled state is marked so a late token delivery fails
        loudly instead of resurrecting the request."""
        stage, st = self.scheduler.cancel(rid)
        if stage is None:
            return False
        self.requests_canceled += 1
        self.tracer.instant("cancel", rid=rid, stage=stage)
        if stage in ("prefilling", "active") and self.cache is not None:
            self._prefills.pop(rid, None)
            self.cache.release(st.slot)
        return True

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Batch API: submit, drain, return outputs in request order."""
        rids = [self.submit(r) for r in requests]
        self.run()
        return [self.scheduler.result(rid) for rid in rids]

    @property
    def busy(self) -> bool:
        """True while any request is pending, mid-prefill, or decoding."""
        s = self.scheduler
        return s.has_pending or s.has_prefilling or s.has_active

    def run(self):
        """Drive admissions + prefill + decode until every request is done."""
        if not self._attn_cache:
            self._run_sequential()
            return
        while self.busy:
            self.step()

    def latency_stats(self) -> Dict[str, float]:
        """Engine observability snapshot, all values float.

        Keys ending ``_s`` are p50/p95 latency percentiles in seconds
        over recent windows: full-request and first-token (absent until a
        request completes) and inter-token / TPOT — the gap between
        consecutive tokens of one request, the metric a blocking prefill
        schedule inflates (absent until some request has emitted two
        tokens).  Cache gauges: ``pages_in_use`` / ``pages_total`` /
        ``page_utilization`` / ``kv_fragmentation`` plus the in-flight
        prefill gauges ``lanes_prefilling`` / ``prefill_pages_in_use``
        (paged) or their ``slot*`` analogues.  In spec-decode mode also
        ``spec_accept_rate`` (delivered-accepted / drafted),
        ``spec_tokens_per_verify`` (emitted tokens per verify dispatch,
        summed over the batch — up to ``n_active * (spec_k + 1)``), and
        the ``spec_rounds`` / ``spec_drafted`` / ``spec_drafted_nodes`` /
        ``spec_accepted`` / ``spec_corrections`` / ``spec_emitted``
        counters (``spec_emitted == spec_accepted + spec_corrections``
        by construction).  The paged gauges also carry the prefix-cache trio
        ``cache_hit_rate`` / ``shared_pages`` / ``cow_forks``; with
        ``prefix_cache=True`` the ``prefix_*`` counters (lookups, hits,
        hit rate, resident cached pages, claimed tokens, token-savings
        ratio, evicted pages) are merged in as well.  Completed requests
        also feed the JetStream-style stage split —
        ``p50/p95_{queue,prefill,decode}_s``.  Every key is declared in
        ``telemetry.METRICS_SCHEMA`` (the canonical schema, pinned to
        the table in docs/serving.md); undeclared keys raise
        ``MetricsSchemaError``."""
        stats = self.scheduler.latencies()
        if self.cache is not None:
            stats.update(self.cache.gauges())
        if self._spec is not None:
            stats.update(self._spec.stats.as_dict())
        if self.prefix_cache is not None:
            stats.update(self.prefix_cache.stats())
        # every emitted key must be declared in the unified schema
        # (telemetry.METRICS_SCHEMA, pinned to the docs/serving.md table)
        return telemetry.validate_metrics(stats, "latency_stats")

    def metrics(self) -> Dict[str, float]:
        """``latency_stats()`` plus the engine dispatch counters — the
        full unified-schema snapshot (every key declared in
        ``telemetry.METRICS_SCHEMA``)."""
        stats = self.latency_stats()
        stats.update({
            "prefill_dispatches": float(self.prefill_dispatches),
            "decode_dispatches": float(self.decode_dispatches),
            "requests_admitted": float(self.requests_admitted),
            "requests_canceled": float(self.requests_canceled),
            "pages_allocated": float(self.pages_allocated),
        })
        return telemetry.validate_metrics(stats, "metrics")

    def reset_stats(self):
        """Clear latency history and dispatch counters (e.g. after a
        warmup/compile wave)."""
        self.scheduler.reset_latencies()
        self.prefill_dispatches = 0
        self.decode_dispatches = 0
        self.requests_admitted = 0
        self.pages_allocated = 0
        if self._spec is not None:
            self._spec.stats.reset()
        if self.prefix_cache is not None:
            self.prefix_cache.reset_stats()   # counters only; trie stays

    # ------------------------------------------------------------------
    # continuous-batching loop (attention families)
    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration of the token-budgeted schedule:

        1. **Admit** while the page budget (and a lane) allows.  Under
           ``schedule="blocking"`` each admitted prompt prefills to
           completion right here (the PR-1 reference behaviour); under
           ``schedule="interleaved"`` admission only allocates the lane
           and reserves pages — prefill is metered in step 2.
        2. **Budgeted prefill** (interleaved only) — dispatch up to
           ``prefill_budget`` prompt tokens of chunked prefill, FIFO over
           mid-prefill requests, resuming each request at its
           ``prefill_pos`` cursor.  A request whose final chunk lands
           here samples its first token and becomes decode-active.
        3. **Decode round** for every active lane — a single batched
           ragged decode step, or in spec-decode mode one fused
           ``spec_k``-token draft dispatch plus one dense verify dispatch
           (emitting 1..spec_k+1 tokens per lane).  Runs every step that
           has an active lane, so no lane ever waits on more than one
           step's prefill budget between tokens.

        Idempotent when nothing is pending, prefilling, or active.
        Never raises for admissible workloads; unservable requests were
        already rejected at ``submit()``."""
        sched, cache = self.scheduler, self.cache
        while sched.has_pending:
            nxt = sched.pending[0]
            S = len(nxt.req.prompt)
            total = S + nxt.req.max_new_tokens
            # one admission span per attempt, covering prefix match +
            # claim + page allocation; a failed attempt (pool full)
            # records admitted=False and ends the FIFO scan
            with self.tracer.span("admission", prompt_len=S) as sp:
                cached_len, full_hit = 0, False
                if self.prefix_cache is not None:
                    cached_len, shared = \
                        self.prefix_cache.match(nxt.req.prompt)
                    full_hit = cached_len == S
                    if not full_hit:
                        # partial hits resume on the chunked-prefill grid:
                        # claim whole claim-grain units so chunk dispatches
                        # stay aligned with the cold-path grid
                        grain = self._claim_grain
                        cached_len = (cached_len // grain) * grain
                        shared = shared[: cached_len // cache.page_size]
                    slot = cache.alloc(total, shared_pages=shared,
                                       fork_last=full_hit)
                else:
                    slot = cache.alloc(total)
                if slot is None:       # FIFO: wait for pages/lane to free
                    sp.set(admitted=False)
                    break
                st = sched.admit(slot)
                sp.set(rid=st.rid, slot=slot, cached_len=cached_len,
                       full_hit=full_hit)
                self.requests_admitted += 1
                if isinstance(cache, PagedKVCache):
                    self.pages_allocated += cache.lifetime_pages(total)
                if self.prefix_cache is not None:
                    self.prefix_cache.note_claim(cached_len, S)
                if full_hit:
                    # fully cached prompt — ZERO prefill dispatches: rows
                    # [0, S-1) are shared cached K/V; row S-1 lives in the
                    # COW-forked private last page and is rewritten by
                    # replaying the final prompt token through the next
                    # batched decode dispatch, whose logits yield the first
                    # generated token (numerically the same last-position
                    # logits prefill would have produced)
                    st.prefill_pos = S
                    st.replay_token = int(nxt.req.prompt[S - 1])
                    cache.seq_lens[st.slot] = S - 1
                    sched.activate(st.rid)
                    continue
                if cached_len:
                    # resume the PR-4 prefill cursor past the claimed
                    # prefix; rows [0, cached_len) already hold valid
                    # shared K/V, so interleaved placeholder writes (at
                    # row cached_len, in the first PRIVATE page) stay off
                    # the shared pages
                    st.prefill_pos = cached_len
                    cache.seq_lens[st.slot] = cached_len
                self._begin_prefill(st)
            if self.schedule == "blocking":
                while st.rid in sched.prefilling:   # run prompt to the end
                    self._prefill_chunk(st)
        if self.schedule == "interleaved":
            for _ in range(self._budget_chunks):
                if not sched.has_prefilling:
                    break
                self._prefill_chunk(sched.next_prefilling())
        if not sched.has_active:
            return
        if self._spec is not None:
            self._spec.decode_round(self)
            return
        B = cache.n_slots
        tokens = np.zeros((B, 1), np.int32)
        active = list(sched.active.values())
        for st in active:
            # a fully-cached admission has no tokens yet: replay its last
            # prompt token (first-token logits, zero prefill dispatches)
            tokens[st.slot, 0] = (st.tokens[-1] if st.tokens
                                  else st.replay_token)
        with self.tracer.span("decode", n_active=len(active)) as sp:
            if isinstance(cache, PagedKVCache):
                logits, cache.tree = self._decode(
                    self.params, cache.tree, sanitizer.device_view(tokens),
                    cache.seq_lens_device(), cache.page_table_device())
            else:
                logits, cache.tree = self._decode(
                    self.params, cache.tree, sanitizer.device_view(tokens),
                    cache.seq_lens_device())
            sp.fence(logits)
        self.decode_dispatches += 1
        for st in active:
            cache.advance(st.slot)
        toks = np.asarray(self._sample_batch(logits, active))
        now = time.monotonic()
        for st in active:
            if sched.on_token(st.rid, int(toks[st.slot]), now):
                cache.release(st.slot)

    def _begin_prefill(self, st):
        """Stage lane ``st.slot`` for chunked prefill of
        ``st.req.prompt``: build the right-padded prompt buffer, resolve
        the dispatch ref (page-table row / slot index), and mark the lane
        mid-prefill for the cache gauges."""
        cache = self.cache
        prompt = np.asarray(st.req.prompt, np.int32)
        S, C = len(prompt), self.prefill_chunk
        n_pad = ((S + C - 1) // C) * C
        if isinstance(cache, PagedKVCache):
            ref = cache.page_table_device(st.slot)
        else:
            assert n_pad <= cache.max_len, (n_pad, cache.max_len)
            ref = jnp.int32(st.slot)
        # buf outlives many steps in self._prefills and is aliased into
        # every chunk dispatch — guarded so any future mutation while a
        # chunk view exists fails deterministically under the sanitizer
        buf = sanitizer.guard(np.zeros(n_pad, np.int32),
                              f"ServeEngine.prefill_buf[rid={st.rid}]")
        buf[:S] = prompt
        cache.mark_prefilling(st.slot)
        self._prefills[st.rid] = (buf, S, n_pad, ref)

    def _prefill_chunk(self, st):
        """Dispatch ONE prefill chunk at ``st.prefill_pos`` and advance
        the cursor.  On the final chunk, sample the first generated token
        from the last-prompt-token logits and activate the request.

        Mid-prefill, ``cache.seq_lens[slot]`` tracks the chunk-aligned
        written prefix (< prompt length by construction).  That makes the
        lane safe under interleaved decode / speculative dispatches:
        their placeholder write for this lane lands at the cursor row,
        which the *next* prefill chunk rewrites before ``seq_lens`` ever
        advances past it — so no row is attended before it holds real
        prompt K/V, on either cache layout."""
        cache = self.cache
        buf, S, n_pad, ref = self._prefills[st.rid]
        C = self.prefill_chunk
        c0 = st.prefill_pos
        # span carries the resumable-cursor position, so a Perfetto lane
        # row shows exactly which prompt chunk each dispatch covered
        with self.tracer.span("prefill_chunk", track=lane_track(st.slot),
                              rid=st.rid, pos=c0, chunk=C) as sp:
            logits, cache.tree = self._prefill(
                self.params, cache.tree,
                sanitizer.device_view(buf[None, c0: c0 + C]), ref,
                jnp.int32(c0))
            sp.fence(logits)
        self.prefill_dispatches += 1
        st.prefill_pos = c0 + C
        if st.prefill_pos < n_pad:
            cache.seq_lens[st.slot] = st.prefill_pos
            return
        # final chunk: the last prompt token's logits live here
        del self._prefills[st.rid]
        cache.seq_lens[st.slot] = S
        cache.unmark_prefilling(st.slot)
        self.scheduler.activate(st.rid)
        if self.prefix_cache is not None:
            # cache the fully prefilled prompt's full pages: their rows
            # hold final prompt K/V no later write touches (decode,
            # draft and verify all write at rows >= S)
            self.prefix_cache.insert(st.req.prompt,
                                     cache.lane_pages(st.slot))
        last = logits[0, (S - 1) - (n_pad - C)][None]         # [1, Vp]
        tok = np.asarray(self._sample_batch(last, [st]))[0]
        if self.scheduler.on_token(st.rid, int(tok), time.monotonic()):
            cache.release(st.slot)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _sample_fn(self, logits, temps, rids, ms):
        """logits [B, Vp], temps/rids/ms [B] -> tokens [B].

        Greedy where temp==0; otherwise gumbel-max sampling whose noise
        comes from the ROLE_TARGET stream of ``request_key(seed, rid, m)``
        with ``m`` the 0-based index of the token being sampled — the
        same stream speculative decoding consumes for draft proposals
        (branch 0) and bonus tokens, which is what makes spec sampling
        stream-compatible with plain sampling.
        """
        lg = logits[:, : self.cfg.vocab].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1)
        base = self._base_key
        g = jax.vmap(
            lambda r, m: jax.random.gumbel(
                jax.random.fold_in(request_key(base, r, m), ROLE_TARGET),
                (lg.shape[1],), jnp.float32))(rids, ms)
        samp = jnp.argmax(lg / jnp.maximum(temps[:, None], 1e-6) + g, axis=-1)
        return jnp.where(temps > 0, samp, greedy).astype(jnp.int32)

    def _sample_batch(self, logits, states):
        B = logits.shape[0]
        temps = np.zeros(B, np.float32)
        rids = np.zeros(B, np.int32)
        ms = np.zeros(B, np.int32)
        for st in states:
            idx = st.slot if B > 1 else 0
            temps[idx] = st.req.temperature
            rids[idx] = st.rid
            ms[idx] = len(st.tokens)
        return self._sample(logits, jnp.asarray(temps), jnp.asarray(rids),
                            jnp.asarray(ms))

    # ------------------------------------------------------------------
    # recurrent-family fallback (no KV cache => per-request sequential)
    # ------------------------------------------------------------------
    def _run_sequential(self):
        sched = self.scheduler
        while sched.has_pending:
            st = sched.admit(slot=0)
            sched.activate(st.rid)     # sequential path has no chunk stage
            # scheduler.submit normalized (and, sanitizing, guarded) the
            # prompt — slice it directly so the guard survives into the
            # device views below
            prompt = st.req.prompt
            cache = init_cache(self.cfg, 1, self.max_len)
            logits = None
            for t in range(len(prompt)):
                logits, cache = self._decode_uniform(
                    self.params, cache,
                    sanitizer.device_view(prompt[None, t: t + 1]),
                    jnp.int32(t))
            pos = len(prompt)
            while True:
                tok = np.asarray(self._sample_batch(logits, [st]))[0]
                if sched.on_token(st.rid, int(tok), time.monotonic()):
                    break
                logits, cache = self._decode_uniform(
                    self.params, cache,
                    jnp.asarray([[tok]], np.int32), jnp.int32(pos))
                pos += 1


def greedy_generate(params, cfg, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 256) -> np.ndarray:
    eng = ServeEngine(params, cfg, max_len=max_len, max_batch=1)
    return eng.generate([Request(prompt, n_tokens)])[0]
