"""Continuous-batching serving engine: chunked prefill + slot-based decode.

The serving path is where STUN's wins land: a 25%-expert-pruned MoE has a
proportionally smaller EP all-to-all and per-chip weight set, and the
block-sparse kernel exploits stage-2 masks.  The engine:

  * **chunked prefill** — an S-token prompt is replayed through
    ``models.prefill_step`` in fixed-size chunks, each a single jitted
    dispatch that computes the chunk forward, writes its K/V into the
    request's cache slot, and masks padded / unwritten positions.  Cost is
    ``ceil(S/chunk)`` dispatches, independent of S (the seed engine paid
    one decode dispatch per prompt token and attended its left-pads).
  * **slot-based KV cache** (`kv_cache.SlotKVCache`) — per-request
    ``seq_len``, alloc/free, and admission of queued requests into slots
    vacated mid-flight by finished requests.
  * **scheduler** (`scheduler.Scheduler`) — FIFO admission, per-request
    EOS / ``max_new_tokens`` termination (no post-EOS tokens, no decode
    steps burned on finished requests), per-request greedy or temperature
    sampling.
  * **pruned-model plumbing** — a runtime ``expert_mask`` ([E] or [L, E])
    flows into every prefill/decode dispatch, and stage-2 unstructured
    masks from ``core.unstructured.sparsify_model`` can be re-applied to
    the weights at load time via ``weight_masks=``.

Recurrent families (ssm/hybrid) have no length-indexed cache; they fall
back to a correct sequential per-request path.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, decode_step_ragged, init_cache, prefill_step
from repro.serving.kv_cache import SlotKVCache
from repro.serving.scheduler import Request, Scheduler


def apply_weight_masks(params, cfg, masks: Dict):
    """Re-apply stage-2 block/unstructured sparsity masks to a param tree.

    ``masks`` is the ``{(layer, path) -> bool ndarray}`` dict returned by
    ``core.unstructured.sparsify_model`` — multiplying them back in keeps a
    served checkpoint exactly as sparse as the pruner left it (e.g. after
    fine-tuning or dtype casts re-densified small values).
    """
    from repro.core.unstructured import _get_path, _set_path

    stacked = cfg.family != "hybrid" and cfg.scan_layers
    new_params = params
    if stacked:
        # group per weight path so each stacked [L, ...] tensor is copied
        # once, not once per layer
        by_path: Dict = {}
        for (l, path), mask in masks.items():
            by_path.setdefault(path, []).append((l, mask))
        layers = new_params["layers"]
        for path, entries in by_path.items():
            W = _get_path(layers, path)
            Wn = np.asarray(W, np.float32).copy()
            for l, mask in entries:
                Wn[l] = Wn[l] * mask
            layers = _set_path(layers, path, jnp.asarray(Wn, dtype=W.dtype))
        return {**new_params, "layers": layers}
    for (l, path), mask in masks.items():
        sub = new_params["layers"][str(l)]
        W = _get_path(sub, path)
        Wn = np.asarray(W, np.float32) * mask
        sub = _set_path(sub, path, jnp.asarray(Wn, dtype=W.dtype))
        new_params = {**new_params,
                      "layers": {**new_params["layers"], str(l): sub}}
    return new_params


class ServeEngine:
    def __init__(self, params, cfg, max_len: int = 512, mesh=None,
                 max_batch: int = 8, prefill_chunk: int = 32,
                 expert_mask=None, weight_masks: Optional[Dict] = None,
                 seed: int = 0):
        if weight_masks:
            params = apply_weight_masks(params, cfg, weight_masks)
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.mesh = mesh
        self.max_batch = max_batch
        self.prefill_chunk = min(prefill_chunk, max_len)
        self.scheduler = Scheduler()
        self.prefill_dispatches = 0      # jitted prefill calls (bench hook)
        self.decode_dispatches = 0
        self._key = jax.random.PRNGKey(seed)
        self._attn_cache = cfg.family not in ("ssm", "hybrid")

        em = None if expert_mask is None else jnp.asarray(expert_mask,
                                                          jnp.float32)
        if self._attn_cache:
            # round the cache up to whole prefill chunks: the last chunk of a
            # max_len-long prompt may extend past max_len, and an out-of-range
            # dynamic_update_slice would clamp and silently corrupt earlier
            # rows
            C = self.prefill_chunk
            self.cache = SlotKVCache(cfg, max_batch,
                                     ((max_len + C - 1) // C) * C)
            # donate the cache arg: the engine always replaces cache.tree
            # with the result, and without donation every dispatch copies
            # the whole multi-slot K/V tree.  CPU ignores donation with a
            # warning, so only donate on accelerators.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            self._prefill = jax.jit(
                lambda p, c, t, slot, start: prefill_step(
                    p, cfg, c, t, slot, start, mesh=mesh, expert_mask=em),
                donate_argnums=donate)
            self._decode = jax.jit(
                lambda p, c, t, sl: decode_step_ragged(
                    p, cfg, c, t, sl, mesh=mesh, expert_mask=em),
                donate_argnums=donate)
        else:
            self.cache = None
            self._decode_uniform = jax.jit(
                lambda p, c, t, n: decode_step(p, cfg, c, t, n, mesh=mesh,
                                               expert_mask=em))
        self._sample = jax.jit(self._sample_fn)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its id.  ``run()`` drains the queue."""
        if len(request.prompt) < 1:
            raise ValueError("empty prompt")
        if len(request.prompt) + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt({len(request.prompt)}) + max_new_tokens"
                f"({request.max_new_tokens}) exceeds max_len={self.max_len}")
        return self.scheduler.submit(request, time.monotonic())

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Batch API: submit, drain, return outputs in request order."""
        rids = [self.submit(r) for r in requests]
        self.run()
        return [self.scheduler.result(rid) for rid in rids]

    def run(self):
        """Drive admissions + decode until queue and slots are empty."""
        if not self._attn_cache:
            self._run_sequential()
            return
        while self.scheduler.has_pending or self.scheduler.has_active:
            self.step()

    def latency_stats(self) -> Dict[str, float]:
        return self.scheduler.latencies()

    def reset_stats(self):
        """Clear latency history and dispatch counters (e.g. after a
        warmup/compile wave)."""
        self.scheduler.reset_latencies()
        self.prefill_dispatches = 0
        self.decode_dispatches = 0

    # ------------------------------------------------------------------
    # continuous-batching loop (attention families)
    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit into free slots, then one batched
        ragged decode step for every active slot."""
        sched, cache = self.scheduler, self.cache
        while sched.has_pending and cache.n_free:
            slot = cache.alloc()
            st = sched.admit(slot)
            self._prefill_into_slot(st)
        if not sched.has_active:
            return
        B = cache.n_slots
        tokens = np.zeros((B, 1), np.int32)
        active = list(sched.active.values())
        for st in active:
            tokens[st.slot, 0] = st.tokens[-1]
        logits, cache.tree = self._decode(self.params, cache.tree,
                                          jnp.asarray(tokens),
                                          cache.seq_lens_device())
        self.decode_dispatches += 1
        for st in active:
            cache.seq_lens[st.slot] += 1
        toks = np.asarray(self._sample_batch(logits, active))
        now = time.monotonic()
        for st in active:
            if sched.on_token(st.rid, int(toks[st.slot]), now):
                cache.free(st.slot)

    def _prefill_into_slot(self, st):
        """Chunked prefill of ``st.req.prompt`` into cache slot ``st.slot``
        + sample the first generated token from the last-prompt-token
        logits."""
        prompt = np.asarray(st.req.prompt, np.int32)
        S, C = len(prompt), self.prefill_chunk
        n_pad = ((S + C - 1) // C) * C
        assert n_pad <= self.cache.max_len, (n_pad, self.cache.max_len)
        buf = np.zeros(n_pad, np.int32)
        buf[:S] = prompt
        logits = None
        for c0 in range(0, n_pad, C):
            logits, self.cache.tree = self._prefill(
                self.params, self.cache.tree,
                jnp.asarray(buf[None, c0: c0 + C]),
                jnp.int32(st.slot), jnp.int32(c0))
            self.prefill_dispatches += 1
        self.cache.seq_lens[st.slot] = S
        # last prompt token always lives in the final chunk
        last = logits[0, (S - 1) - (n_pad - C)][None]         # [1, Vp]
        tok = np.asarray(self._sample_batch(last, [st]))[0]
        if self.scheduler.on_token(st.rid, int(tok), time.monotonic()):
            self.cache.free(st.slot)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _sample_fn(self, logits, temps, key):
        """logits [B, Vp], temps [B] -> tokens [B] (greedy where temp==0)."""
        lg = logits[:, : self.cfg.vocab].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1)
        g = jax.random.gumbel(key, lg.shape)
        samp = jnp.argmax(lg / jnp.maximum(temps[:, None], 1e-6) + g, axis=-1)
        return jnp.where(temps > 0, samp, greedy).astype(jnp.int32)

    def _sample_batch(self, logits, states):
        temps = np.zeros(logits.shape[0], np.float32)
        for st in states:
            idx = st.slot if logits.shape[0] > 1 else 0
            temps[idx] = st.req.temperature
        self._key, sub = jax.random.split(self._key)
        return self._sample(logits, jnp.asarray(temps), sub)

    # ------------------------------------------------------------------
    # recurrent-family fallback (no KV cache => per-request sequential)
    # ------------------------------------------------------------------
    def _run_sequential(self):
        sched = self.scheduler
        while sched.has_pending:
            st = sched.admit(slot=0)
            prompt = np.asarray(st.req.prompt, np.int32)
            cache = init_cache(self.cfg, 1, self.max_len)
            logits = None
            for t in range(len(prompt)):
                logits, cache = self._decode_uniform(
                    self.params, cache, jnp.asarray(prompt[None, t: t + 1]),
                    jnp.int32(t))
            pos = len(prompt)
            while True:
                tok = np.asarray(self._sample_batch(logits, [st]))[0]
                if sched.on_token(st.rid, int(tok), time.monotonic()):
                    break
                logits, cache = self._decode_uniform(
                    self.params, cache,
                    jnp.asarray([[tok]], np.int32), jnp.int32(pos))
                pos += 1


def greedy_generate(params, cfg, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 256) -> np.ndarray:
    eng = ServeEngine(params, cfg, max_len=max_len, max_batch=1)
    return eng.generate([Request(prompt, n_tokens)])[0]
