"""Batched serving engine: prefill + decode over the unified model.

The serving path is where STUN's wins land: a 25%-expert-pruned MoE has a
proportionally smaller EP all-to-all and per-chip weight set, and the
block-sparse kernel exploits stage-2 masks.  The engine is deliberately
simple (contiguous KV cache, synchronous batch scheduler) — the
distribution story lives in the shardings, not the scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, init_cache


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16


class ServeEngine:
    def __init__(self, params, cfg, max_len: int = 512, mesh=None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.mesh = mesh
        self._decode = jax.jit(
            lambda p, c, t, n: decode_step(p, cfg, c, t, n, mesh=mesh))

    def prefill(self, tokens):
        """tokens [B, S] -> (cache, last_logits [B, V]).

        Prefill runs the full forward, then replays tokens into the cache
        via teacher-forced decode (portable path; the TPU fast path fuses
        cache writes into the forward).
        """
        B, S = tokens.shape
        cache = init_cache(self.cfg, B, self.max_len)
        logits = None
        for t in range(S):
            logits, cache = self._decode(self.params, cache,
                                         tokens[:, t: t + 1], jnp.int32(t))
        return cache, logits

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Greedy batched generation (prompts left-aligned, same length)."""
        S = max(len(r.prompt) for r in requests)
        B = len(requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad with 0
        cache, logits = self.prefill(jnp.asarray(toks))
        max_new = max(r.max_new_tokens for r in requests)
        out = []
        cur = jnp.argmax(logits[:, : self.cfg.vocab], axis=-1)[:, None]
        for i in range(max_new):
            out.append(np.asarray(cur[:, 0]))
            logits, cache = self._decode(self.params, cache,
                                         cur.astype(jnp.int32),
                                         jnp.int32(S + i))
            cur = jnp.argmax(logits[:, : self.cfg.vocab], axis=-1)[:, None]
        gen = np.stack(out, axis=1)  # [B, max_new]
        return [gen[i, : requests[i].max_new_tokens] for i in range(B)]


def greedy_generate(params, cfg, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 256) -> np.ndarray:
    eng = ServeEngine(params, cfg, max_len=max_len)
    return eng.generate([Request(prompt, n_tokens)])[0]
