"""Continuous-batching serving engine: chunked prefill + paged-KV decode.

The serving path is where STUN's wins land: a 25%-expert-pruned MoE has a
proportionally smaller EP all-to-all and per-chip weight set, and the
block-sparse kernel exploits stage-2 masks.  The engine:

  * **chunked prefill** — an S-token prompt is replayed through
    ``models.prefill_step_paged`` in fixed-size chunks, each a single
    jitted dispatch that computes the chunk forward, writes its K/V
    through the lane's page table, and masks padded / unwritten
    positions.  Cost is ``ceil(S/chunk)`` dispatches, independent of S.
  * **paged KV cache** (`kv_cache.PagedKVCache`, the default layout) —
    K/V in fixed-size pages with per-lane page tables; admission is
    page-budget-gated (a request needs pages for its whole
    prompt + max_new_tokens lifetime, not a whole ``max_len`` slot), and
    a finished request's page list returns to the pool immediately.
    Decode attention runs through the fused Pallas ragged paged kernel
    (`kernels.paged_decode_attention`) on TPU, its jnp gather reference
    elsewhere.  ``kv_layout="slot"`` keeps the PR-1 slot-granular cache —
    the reference the paged path is tested token-identical against.
  * **scheduler** (`scheduler.Scheduler`) — FIFO admission, per-request
    EOS / ``max_new_tokens`` termination (no post-EOS tokens, no decode
    steps burned on finished requests), per-request greedy or temperature
    sampling.  Requests that can never fit the cache are rejected at
    ``submit()`` with a ValueError rather than corrupting rows later.
  * **pruned-model plumbing** — a runtime ``expert_mask`` ([E] or [L, E])
    flows into every prefill/decode dispatch, and stage-2 unstructured
    masks from ``core.unstructured.sparsify_model`` can be re-applied to
    the weights at load time via ``weight_masks=``.

Recurrent families (ssm/hybrid) have no length-indexed cache; they fall
back to a correct sequential per-request path.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (decode_step, decode_step_paged, decode_step_ragged,
                          init_cache, prefill_step, prefill_step_paged)
from repro.serving.kv_cache import PagedKVCache, SlotKVCache
from repro.serving.scheduler import Request, Scheduler


def apply_weight_masks(params, cfg, masks: Dict):
    """Re-apply stage-2 block/unstructured sparsity masks to a param tree.

    ``masks`` is the ``{(layer, path) -> bool ndarray}`` dict returned by
    ``core.unstructured.sparsify_model`` — multiplying them back in keeps a
    served checkpoint exactly as sparse as the pruner left it (e.g. after
    fine-tuning or dtype casts re-densified small values).
    """
    from repro.core.unstructured import _get_path, _set_path

    stacked = cfg.family != "hybrid" and cfg.scan_layers
    new_params = params
    if stacked:
        # group per weight path so each stacked [L, ...] tensor is copied
        # once, not once per layer
        by_path: Dict = {}
        for (l, path), mask in masks.items():
            by_path.setdefault(path, []).append((l, mask))
        layers = new_params["layers"]
        for path, entries in by_path.items():
            W = _get_path(layers, path)
            Wn = np.asarray(W, np.float32).copy()
            for l, mask in entries:
                Wn[l] = Wn[l] * mask
            layers = _set_path(layers, path, jnp.asarray(Wn, dtype=W.dtype))
        return {**new_params, "layers": layers}
    for (l, path), mask in masks.items():
        sub = new_params["layers"][str(l)]
        W = _get_path(sub, path)
        Wn = np.asarray(W, np.float32) * mask
        sub = _set_path(sub, path, jnp.asarray(Wn, dtype=W.dtype))
        new_params = {**new_params,
                      "layers": {**new_params["layers"], str(l): sub}}
    return new_params


class ServeEngine:
    def __init__(self, params, cfg, max_len: int = 512, mesh=None,
                 max_batch: int = 8, prefill_chunk: int = 32,
                 expert_mask=None, weight_masks: Optional[Dict] = None,
                 seed: int = 0, kv_layout: str = "paged",
                 page_size: int = 16, page_budget: Optional[int] = None):
        if kv_layout not in ("paged", "slot"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if weight_masks:
            params = apply_weight_masks(params, cfg, weight_masks)
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.mesh = mesh
        self.max_batch = max_batch
        self.prefill_chunk = min(prefill_chunk, max_len)
        self.kv_layout = kv_layout
        self.scheduler = Scheduler(max_request_tokens=max_len)
        self.prefill_dispatches = 0      # jitted prefill calls (bench hook)
        self.decode_dispatches = 0
        self.requests_admitted = 0
        self.pages_allocated = 0         # lifetime pages over all admissions
        self._key = jax.random.PRNGKey(seed)
        self._attn_cache = cfg.family not in ("ssm", "hybrid")

        em = None if expert_mask is None else jnp.asarray(expert_mask,
                                                          jnp.float32)
        if self._attn_cache:
            # round the lane capacity up to whole prefill chunks: the last
            # chunk of a max_len-long prompt may extend past max_len, and
            # its padded rows must land in maskable (slot) or sentinel
            # (paged) storage rather than corrupt earlier rows
            C = self.prefill_chunk
            lane_len = ((max_len + C - 1) // C) * C
            # donate the cache arg: the engine always replaces cache.tree
            # with the result, and without donation every dispatch copies
            # the whole K/V pool.  CPU ignores donation with a warning, so
            # only donate on accelerators.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            if kv_layout == "paged":
                self.cache = PagedKVCache(cfg, max_batch, lane_len,
                                          page_size, page_budget)
                self._prefill = jax.jit(
                    lambda p, c, t, row, start: prefill_step_paged(
                        p, cfg, c, t, row, start, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
                self._decode = jax.jit(
                    lambda p, c, t, sl, tbl: decode_step_paged(
                        p, cfg, c, t, sl, tbl, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
            else:
                self.cache = SlotKVCache(cfg, max_batch, lane_len)
                self._prefill = jax.jit(
                    lambda p, c, t, slot, start: prefill_step(
                        p, cfg, c, t, slot, start, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
                self._decode = jax.jit(
                    lambda p, c, t, sl: decode_step_ragged(
                        p, cfg, c, t, sl, mesh=mesh, expert_mask=em),
                    donate_argnums=donate)
        else:
            self.cache = None
            self._decode_uniform = jax.jit(
                lambda p, c, t, n: decode_step(p, cfg, c, t, n, mesh=mesh,
                                               expert_mask=em))
        self._sample = jax.jit(self._sample_fn)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its id.  ``run()`` drains the queue.

        Raises ValueError for requests that could never be admitted:
        empty prompts, ``prompt + max_new_tokens`` past ``max_len``, or —
        on the paged layout — past the whole page budget.
        """
        if len(request.prompt) < 1:
            raise ValueError("empty prompt")
        total = len(request.prompt) + request.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"prompt({len(request.prompt)}) + max_new_tokens"
                f"({request.max_new_tokens}) exceeds max_len={self.max_len}")
        if isinstance(self.cache, PagedKVCache):
            need = self.cache.pages_needed(total)
            if need > self.cache.page_budget:
                raise ValueError(
                    f"request needs {need} pages "
                    f"({total} tokens at page_size="
                    f"{self.cache.page_size}) but the cache's whole page "
                    f"budget is {self.cache.page_budget}")
        return self.scheduler.submit(request, time.monotonic())

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Batch API: submit, drain, return outputs in request order."""
        rids = [self.submit(r) for r in requests]
        self.run()
        return [self.scheduler.result(rid) for rid in rids]

    def run(self):
        """Drive admissions + decode until queue and slots are empty."""
        if not self._attn_cache:
            self._run_sequential()
            return
        while self.scheduler.has_pending or self.scheduler.has_active:
            self.step()

    def latency_stats(self) -> Dict[str, float]:
        """p50/p95 latency percentiles plus cache-utilization gauges
        (pages in use / total, internal fragmentation)."""
        stats = self.scheduler.latencies()
        if self.cache is not None:
            stats.update(self.cache.gauges())
        return stats

    def reset_stats(self):
        """Clear latency history and dispatch counters (e.g. after a
        warmup/compile wave)."""
        self.scheduler.reset_latencies()
        self.prefill_dispatches = 0
        self.decode_dispatches = 0
        self.requests_admitted = 0
        self.pages_allocated = 0

    # ------------------------------------------------------------------
    # continuous-batching loop (attention families)
    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit while the page budget (and a lane)
        allows, then one batched ragged decode step for every active
        lane."""
        sched, cache = self.scheduler, self.cache
        while sched.has_pending:
            nxt = sched.pending[0]
            total = len(nxt.req.prompt) + nxt.req.max_new_tokens
            slot = cache.alloc(total)
            if slot is None:           # FIFO: wait for pages/lane to free
                break
            st = sched.admit(slot)
            self.requests_admitted += 1
            if isinstance(cache, PagedKVCache):
                self.pages_allocated += cache.pages_needed(total)
            self._prefill_into_slot(st)
        if not sched.has_active:
            return
        B = cache.n_slots
        tokens = np.zeros((B, 1), np.int32)
        active = list(sched.active.values())
        for st in active:
            tokens[st.slot, 0] = st.tokens[-1]
        if isinstance(cache, PagedKVCache):
            logits, cache.tree = self._decode(self.params, cache.tree,
                                              jnp.asarray(tokens),
                                              cache.seq_lens_device(),
                                              cache.page_table_device())
        else:
            logits, cache.tree = self._decode(self.params, cache.tree,
                                              jnp.asarray(tokens),
                                              cache.seq_lens_device())
        self.decode_dispatches += 1
        for st in active:
            cache.seq_lens[st.slot] += 1
        toks = np.asarray(self._sample_batch(logits, active))
        now = time.monotonic()
        for st in active:
            if sched.on_token(st.rid, int(toks[st.slot]), now):
                cache.free(st.slot)

    def _prefill_into_slot(self, st):
        """Chunked prefill of ``st.req.prompt`` into lane ``st.slot``
        + sample the first generated token from the last-prompt-token
        logits."""
        cache = self.cache
        prompt = np.asarray(st.req.prompt, np.int32)
        S, C = len(prompt), self.prefill_chunk
        n_pad = ((S + C - 1) // C) * C
        paged = isinstance(cache, PagedKVCache)
        if paged:
            page_row = cache.page_table_device(st.slot)
        else:
            assert n_pad <= cache.max_len, (n_pad, cache.max_len)
        buf = np.zeros(n_pad, np.int32)
        buf[:S] = prompt
        logits = None
        for c0 in range(0, n_pad, C):
            ref = page_row if paged else jnp.int32(st.slot)
            logits, cache.tree = self._prefill(
                self.params, cache.tree,
                jnp.asarray(buf[None, c0: c0 + C]), ref, jnp.int32(c0))
            self.prefill_dispatches += 1
        cache.seq_lens[st.slot] = S
        # last prompt token always lives in the final chunk
        last = logits[0, (S - 1) - (n_pad - C)][None]         # [1, Vp]
        tok = np.asarray(self._sample_batch(last, [st]))[0]
        if self.scheduler.on_token(st.rid, int(tok), time.monotonic()):
            cache.free(st.slot)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _sample_fn(self, logits, temps, key):
        """logits [B, Vp], temps [B] -> tokens [B] (greedy where temp==0)."""
        lg = logits[:, : self.cfg.vocab].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1)
        g = jax.random.gumbel(key, lg.shape)
        samp = jnp.argmax(lg / jnp.maximum(temps[:, None], 1e-6) + g, axis=-1)
        return jnp.where(temps > 0, samp, greedy).astype(jnp.int32)

    def _sample_batch(self, logits, states):
        temps = np.zeros(logits.shape[0], np.float32)
        for st in states:
            idx = st.slot if logits.shape[0] > 1 else 0
            temps[idx] = st.req.temperature
        self._key, sub = jax.random.split(self._key)
        return self._sample(logits, jnp.asarray(temps), sub)

    # ------------------------------------------------------------------
    # recurrent-family fallback (no KV cache => per-request sequential)
    # ------------------------------------------------------------------
    def _run_sequential(self):
        sched = self.scheduler
        while sched.has_pending:
            st = sched.admit(slot=0)
            prompt = np.asarray(st.req.prompt, np.int32)
            cache = init_cache(self.cfg, 1, self.max_len)
            logits = None
            for t in range(len(prompt)):
                logits, cache = self._decode_uniform(
                    self.params, cache, jnp.asarray(prompt[None, t: t + 1]),
                    jnp.int32(t))
            pos = len(prompt)
            while True:
                tok = np.asarray(self._sample_batch(logits, [st]))[0]
                if sched.on_token(st.rid, int(tok), time.monotonic()):
                    break
                logits, cache = self._decode_uniform(
                    self.params, cache,
                    jnp.asarray([[tok]], np.int32), jnp.int32(pos))
                pos += 1


def greedy_generate(params, cfg, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 256) -> np.ndarray:
    eng = ServeEngine(params, cfg, max_len=max_len, max_batch=1)
    return eng.generate([Request(prompt, n_tokens)])[0]
