"""Radix-tree prefix cache: KV reuse across requests on the paged cache.

Production traffic shares prompt prefixes — system prompts, few-shot
preambles — across nearly every request, yet a cold-cache engine
re-prefills them from token 0 every time.  The paged KV layout makes
reuse almost free: a cached prefix is just a list of physical pages that
a new lane's page table can point at.  This module owns the host-side
index that makes that safe:

  * **Trie keyed on page-aligned token chunks.**  Each node holds exactly
    one ``page_size``-token chunk (a tuple of ints) and the physical page
    whose rows hold that chunk's K/V.  A root-to-node path spells out a
    page-aligned prompt prefix; children are keyed by the next chunk.
    Only *full* pages are ever cached — a prompt's trailing partial page
    stays private to its lane (its rows get overwritten by decode).
  * **Refcounts instead of free-on-finish.**  Every cached node retains
    its page in the pool (``pool.retain_page``), so a finished lane's
    ``release`` only decrements — pages stay resident while cached, and
    ``refcount(p) == referencing lane tables + trie entries`` is the
    invariant the stress tests assert.
  * **LRU eviction under pool pressure.**  ``evict(n)`` reclaims
    least-recently-touched leaves whose page refcount is 1 (trie-only —
    no lane references them).  Leaf-first order keeps the trie
    prefix-closed; because a lane that claims a path holds *every* page
    on it, a refcount-1 node's whole subtree is refcount-1, so
    ``evictable_pages()`` (the admission headroom the pool adds to its
    free count) is exact, not an estimate.

Insertion happens when a lane finishes prefilling (its full-page chunks
then hold final prompt K/V that no later write touches: decode, draft and
verify all write at rows ``>= prompt_len``).  Matching happens at
admission; the engine rounds a partial match down to its prefill-chunk
grid and starts the resumable prefill cursor at the claimed length.  A
*fully* cached prompt skips prefill entirely — the engine forks the last
page copy-on-write (the first decode write lands at row ``S-1`` inside
it) and replays the final prompt token through the ordinary batched
decode dispatch, so repeat requests cost **zero** prefill dispatches.

The pool is duck-typed (``retain_page`` / ``release_page`` /
``refcount``), so the trie's bookkeeping is unit-testable without an
engine or device arrays; ``PagedKVCache`` is the production pool.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.telemetry import NULL_TRACER, TRACK_CACHE


class _Node:
    """One cached page: ``chunk`` (page_size-token tuple) -> ``page``."""
    __slots__ = ("chunk", "page", "parent", "children", "tick")

    def __init__(self, chunk, page, parent):
        self.chunk = chunk
        self.page = page
        self.parent = parent                    # None for root children
        self.children: Dict[tuple, "_Node"] = {}
        self.tick = 0                           # LRU: last match/insert touch


class PrefixCache:
    """Trie over page-aligned prompt chunks -> physical page lists.

    ``pool`` must provide ``retain_page(p)`` / ``release_page(p)`` /
    ``refcount(p)``; ``max_pages`` optionally caps trie residency (LRU
    trimmed after inserts) below what pool pressure alone would allow.
    """

    def __init__(self, pool, page_size: int, max_pages: Optional[int] = None):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if max_pages is not None and max_pages < 0:
            raise ValueError("max_pages must be >= 0")
        self.pool = pool
        self.page_size = page_size
        self.max_pages = max_pages
        self._children: Dict[tuple, _Node] = {}   # root level
        self._tick = 0
        self.n_nodes = 0
        # counters (engine latency_stats / kv gauges pull from these)
        self.lookups = 0            # admissions that consulted the trie
        self.hits = 0               # admissions that claimed >= 1 page
        self.claimed_tokens = 0     # prompt tokens served from cache
        self.prompt_tokens = 0      # prompt tokens over all admissions
        self.inserted_pages = 0
        self.evicted_pages = 0
        self.tracer = NULL_TRACER   # set by ServeEngine.set_tracer

    # ---- chunking --------------------------------------------------------
    def _chunks(self, tokens) -> List[tuple]:
        """Full ``page_size``-token chunks of a prompt, as int tuples."""
        ps = self.page_size
        return [tuple(int(t) for t in tokens[i: i + ps])
                for i in range(0, (len(tokens) // ps) * ps, ps)]

    # ---- lookup / claim --------------------------------------------------
    def match(self, tokens) -> Tuple[int, List[int]]:
        """Longest cached page-aligned prefix of ``tokens``.

        Returns ``(cached_len, pages)`` — ``cached_len`` is a multiple of
        ``page_size`` (0 on a miss) and ``pages`` the physical pages
        holding those rows, in order.  Touches matched nodes for LRU but
        takes no references; the caller claims the pages (bumping
        refcounts) via the pool's ``alloc(..., shared_pages=pages)``, and
        may round the claim down (e.g. to its prefill-chunk grid) by
        truncating the list."""
        with self.tracer.span("prefix_match", track=TRACK_CACHE,
                              prompt_len=len(tokens)) as sp:
            self._tick += 1
            pages: List[int] = []
            level = self._children
            for chunk in self._chunks(tokens):
                node = level.get(chunk)
                if node is None:
                    break
                node.tick = self._tick
                pages.append(node.page)
                level = node.children
            sp.set(matched_tokens=len(pages) * self.page_size)
            return len(pages) * self.page_size, pages

    def note_claim(self, cached_len: int, prompt_len: int):
        """Hit/miss accounting for one successful admission (kept apart
        from ``match`` so failed admissions that retry don't double
        count)."""
        self.lookups += 1
        self.hits += cached_len > 0
        self.claimed_tokens += cached_len
        self.prompt_tokens += prompt_len

    # ---- insertion -------------------------------------------------------
    def insert(self, tokens, pages: Sequence[int]) -> int:
        """Cache a fully prefilled prompt's full-page chunks.

        ``pages`` is the owning lane's page list (only the first
        ``len(tokens) // page_size`` entries are used).  Existing nodes
        are touched, not replaced — concurrent identical prompts keep the
        first-cached pages and the latecomer's stay private.  Each new
        node retains its page, so the pages outlive the lane.  Returns
        the number of pages newly cached; afterwards an LRU trim enforces
        ``max_pages`` (never evicting lane-referenced pages)."""
        with self.tracer.span("prefix_insert", track=TRACK_CACHE,
                              prompt_len=len(tokens)) as sp:
            self._tick += 1
            added = 0
            level, parent = self._children, None
            for i, chunk in enumerate(self._chunks(tokens)):
                node = level.get(chunk)
                if node is None:
                    node = _Node(chunk, int(pages[i]), parent)
                    self.pool.retain_page(node.page)
                    level[chunk] = node
                    self.n_nodes += 1
                    added += 1
                node.tick = self._tick
                level, parent = node.children, node
            self.inserted_pages += added
            sp.set(added=added)
            if self.max_pages is not None and self.n_nodes > self.max_pages:
                self.evict(self.n_nodes - self.max_pages)
            return added

    # ---- eviction --------------------------------------------------------
    def _evictable_leaves(self) -> List[_Node]:
        out = []
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif self.pool.refcount(node.page) == 1:
                out.append(node)
        return out

    def evict(self, n_pages: int) -> int:
        """Reclaim up to ``n_pages`` cached pages, LRU leaf-first.

        Only nodes whose page refcount is 1 (trie-only — no lane table
        references it) are candidates, so eviction can never free a page
        out from under a live dispatch.  Evicting a leaf may expose its
        parent as the next candidate.  Returns the number reclaimed."""
        with self.tracer.span("prefix_evict", track=TRACK_CACHE,
                              wanted=int(n_pages)) as sp:
            done = self._evict(n_pages)
            sp.set(reclaimed=done)
            return done

    def _evict(self, n_pages: int) -> int:
        done = 0
        leaves = self._evictable_leaves()
        leaves.sort(key=lambda nd: nd.tick)     # oldest first
        while done < n_pages and leaves:
            node = leaves.pop(0)
            siblings = (node.parent.children if node.parent is not None
                        else self._children)
            del siblings[node.chunk]
            self.pool.release_page(node.page)   # refcount 1 -> 0: freed
            self.n_nodes -= 1
            self.evicted_pages += 1
            done += 1
            parent = node.parent
            if parent is not None and not parent.children and \
                    self.pool.refcount(parent.page) == 1:
                # newly exposed leaf: insert at its LRU position (its
                # tick is >= its children's — every touch walks the
                # path — but other leaves may still be newer)
                i = 0
                while i < len(leaves) and leaves[i].tick <= parent.tick:
                    i += 1
                leaves.insert(i, parent)
        return done

    def evictable_pages(self) -> int:
        """Pages eviction could reclaim right now.  Exact: a lane that
        references a node references its whole root path, so every
        descendant of a refcount-1 node is itself refcount-1 and the
        subtree drains leaf-first."""
        count = 0
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            count += self.pool.refcount(node.page) == 1
        return count

    # ---- observability ---------------------------------------------------
    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def stats(self) -> Dict[str, float]:
        """Merged into ``ServeEngine.latency_stats()`` (all float)."""
        saved = (self.claimed_tokens / self.prompt_tokens
                 if self.prompt_tokens else 0.0)
        return {
            "prefix_lookups": float(self.lookups),
            "prefix_hits": float(self.hits),
            "prefix_hit_rate": self.hit_rate,
            "prefix_cached_pages": float(self.n_nodes),
            "prefix_claimed_tokens": float(self.claimed_tokens),
            "prefix_token_savings": saved,
            "prefix_evicted_pages": float(self.evicted_pages),
        }

    def reset_stats(self):
        """Clear counters (trie contents stay — e.g. between bench
        waves)."""
        self.lookups = self.hits = 0
        self.claimed_tokens = self.prompt_tokens = 0
        self.inserted_pages = self.evicted_pages = 0

    # ---- introspection (tests) ------------------------------------------
    def pages(self) -> List[int]:
        """All pages the trie currently retains (one per node)."""
        out = []
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            out.append(node.page)
        return out
