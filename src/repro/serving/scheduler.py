"""Admission queue + per-request lifecycle for the continuous-batching engine.

Requests move through three stages:

  * **pending** — submitted, waiting in FIFO order for a cache lane and
    (paged layout) their lifetime page reservation.
  * **prefilling** — admitted to a lane; the prompt is being replayed in
    fixed-size chunks.  ``RequestState.prefill_pos`` is the resumable
    cursor (chunk-aligned prompt tokens already dispatched), so the engine
    can spread one prompt's chunks across many steps — the interleaved
    schedule packs at most ``prefill_budget`` prompt tokens per step next
    to the decode dispatch instead of running a whole prompt to
    completion while decode lanes stall.  A prefix-cache hit starts the
    cursor at the claimed cached length instead of 0; a *fully* cached
    prompt skips this stage entirely (``admit`` then ``activate`` in the
    same engine step, with ``RequestState.replay_token`` carrying the
    last prompt token into the first decode dispatch).
  * **active** — prefill complete (first token sampled); streams tokens
    until *its own* termination condition — EOS or ``max_new_tokens`` —
    and releases the lane immediately, so a long request never makes
    short batchmates burn decode steps past their end.

Latency accounting covers the three serving metrics: full-request
percentiles per completed request, first-token (TTFT) percentiles
recorded **at first-token time** (so requests still in flight — exactly
the ones an open-loop bench saturates the engine with — are visible to
p95 TTFT), plus **inter-token latency** (TPOT) — the gap between
consecutive tokens of the same request — which is what a blocking
prefill schedule inflates and the interleaved schedule bounds.  A
verified speculative block delivers many tokens at one wall instant;
``on_tokens`` amortizes the block's wall interval (previous block
boundary -> now) evenly across the tokens it delivers, so spec-mode
TPOT reflects the per-token pace a client actually experiences instead
of recording zero-length intra-block gaps.

All timestamps default to ``time.monotonic()`` when omitted — a direct
caller that forgets ``now`` must not silently record latencies against
``t = 0``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis import sanitizer


class SchedulerError(RuntimeError):
    """A scheduling invariant was violated (e.g. a token delivered to a
    request that already finished).  A real exception — unlike ``assert``
    it does not vanish under ``python -O``."""


@dataclasses.dataclass
class Request:
    """One generation request.

    temperature == 0.0 -> greedy; > 0 -> softmax sampling at that
    temperature.  ``eos_id`` terminates generation early (the EOS token is
    included in the output; nothing after it ever is).
    """
    prompt: np.ndarray                   # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0


@dataclasses.dataclass
class RequestState:
    rid: int
    req: Request
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # resumable prefill cursor: prompt (+ chunk padding) tokens already
    # dispatched OR claimed from the prefix cache; always a multiple of
    # the engine's prefill_chunk while the request is mid-prefill
    prefill_pos: int = 0
    # fully-cached prompt (zero prefill dispatches): the last prompt
    # token, replayed through the first batched decode dispatch to
    # produce first-token logits; None for every other request
    replay_token: Optional[int] = None
    canceled: bool = False
    t_submit: float = 0.0
    # stage boundaries for the queue -> prefill -> decode split
    # (telemetry.stage_timeline): admission grants the lane, activation
    # marks prefill complete / decode begun
    t_admit: Optional[float] = None
    t_active: Optional[float] = None
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None
    t_done: Optional[float] = None
    # this request's own inter-token gaps (seconds), parallel to
    # ``tokens[1:]`` — per-request TPOT percentiles for SLO attainment;
    # bounded by max_new_tokens and popped with the state at result()
    itl: List[float] = dataclasses.field(default_factory=list)


class Scheduler:
    def __init__(self, latency_window: int = 1024,
                 max_request_tokens: Optional[int] = None):
        # reject-at-submit bound on prompt + max_new_tokens: a request
        # past the cache's capacity would otherwise queue forever (or
        # corrupt rows if force-admitted), so surface it immediately
        self.max_request_tokens = max_request_tokens
        self._next_rid = 0
        self.pending: collections.deque = collections.deque()
        # insertion-ordered: the engine prefills the FIFO head first
        self.prefilling: Dict[int, RequestState] = {}
        self.active: Dict[int, RequestState] = {}
        self.finished: Dict[int, RequestState] = {}
        # bounded latency history: a long-lived engine must not grow
        # without bound, so percentile stats run over recent windows.
        # Inter-token gaps arrive ~max_new_tokens times per request, so
        # their window is wider than the per-request one.  TTFT has its
        # OWN window, fed at first-token time — long or in-flight
        # requests would otherwise be invisible to p95 TTFT exactly when
        # an open-loop load is saturating the engine.
        self._latency: collections.deque = collections.deque(
            maxlen=latency_window)
        self._ttft: collections.deque = collections.deque(
            maxlen=latency_window)
        self._itl: collections.deque = collections.deque(
            maxlen=8 * latency_window)
        # per-stage windows (queue wait / prefill / decode), fed at
        # completion from the stage stamps — the JetStream-style split
        # behind p50/p95_{queue,prefill,decode}_s
        self._queue: collections.deque = collections.deque(
            maxlen=latency_window)
        self._prefill: collections.deque = collections.deque(
            maxlen=latency_window)
        self._decode: collections.deque = collections.deque(
            maxlen=latency_window)
        # completion hook (e.g. Tracer.request_done): called with the
        # finished RequestState while its stamps are still attached,
        # BEFORE result() can pop it.  None (default) costs nothing.
        self.on_finish = None

    @staticmethod
    def _now(now: Optional[float]) -> float:
        """Defaulted wall clock: an omitted timestamp means "now", never
        the t=0 footgun (latencies recorded against the epoch)."""
        return time.monotonic() if now is None else now

    # ---- submission / admission ----------------------------------------
    def submit(self, req: Request, now: Optional[float] = None) -> int:
        if req.max_new_tokens < 1:
            raise ValueError("need at least one generated token")
        total = len(req.prompt) + req.max_new_tokens
        if self.max_request_tokens is not None and \
                total > self.max_request_tokens:
            raise ValueError(
                f"prompt({len(req.prompt)}) + max_new_tokens"
                f"({req.max_new_tokens}) = {total} exceeds the cache "
                f"capacity of {self.max_request_tokens} tokens")
        rid = self._next_rid
        self._next_rid += 1
        # the prompt buffer belongs to the engine from here on: normalize
        # to int32 and (under REPRO_SANITIZE=1) version-stamp it, so a
        # zero-copy device view of the live prompt + a later caller-side
        # mutation is a deterministic DispatchRaceError
        req.prompt = sanitizer.guard(np.asarray(req.prompt, np.int32),
                                     f"Request[{rid}].prompt")
        self.pending.append(RequestState(rid=rid, req=req,
                                         t_submit=self._now(now)))
        return rid

    def admit(self, slot: int, now: Optional[float] = None
              ) -> RequestState:
        """Move the oldest pending request into a (pre-allocated) lane.

        The request enters the **prefilling** stage; ``activate()`` moves
        it to decode-active once its prompt is fully prefilled."""
        st = self.pending.popleft()
        st.slot = slot
        st.t_admit = self._now(now)
        self.prefilling[st.rid] = st
        return st

    def activate(self, rid: int, now: Optional[float] = None
                 ) -> RequestState:
        """Prefill complete: move a prefilling request to decode-active.
        The caller samples the first token (from the final prefill
        chunk's logits) and feeds it through ``on_token`` next."""
        st = self.prefilling.pop(rid, None)
        if st is None:
            raise SchedulerError(f"activate() for request {rid}, which is "
                                 f"not mid-prefill")
        st.t_active = self._now(now)
        self.active[rid] = st
        return st

    def next_prefilling(self) -> RequestState:
        """FIFO head of the prefilling stage (oldest admitted)."""
        return next(iter(self.prefilling.values()))

    def state(self, rid: int) -> Optional[RequestState]:
        """Look up a request's live state at any stage (pending /
        prefilling / active / finished) — None if unknown (canceled, or
        already collected via ``result``).  The returned object is
        stable across stage transitions, so a frontend can hold it and
        watch ``tokens`` / ``done`` grow."""
        for stage in (self.active, self.prefilling, self.finished):
            st = stage.get(rid)
            if st is not None:
                return st
        for st in self.pending:
            if st.rid == rid:
                return st
        return None

    def cancel(self, rid: int) -> Tuple[Optional[str], Optional[RequestState]]:
        """Remove a request from the pipeline at whatever stage it is in.

        Returns ``(stage, state)`` with ``stage`` one of ``"pending"`` /
        ``"prefilling"`` / ``"active"``, or ``(None, None)`` if the
        request is unknown or already finished (a finished request's
        tokens belong to the caller — ``result`` collects them; cancel
        never destroys a completed stream).  The caller (engine) owns
        the lane/page cleanup for the two admitted stages; the state is
        marked ``canceled`` so a late token delivery fails loudly."""
        for i, st in enumerate(self.pending):
            if st.rid == rid:
                del self.pending[i]
                st.canceled = True
                return "pending", st
        st = self.prefilling.pop(rid, None)
        if st is not None:
            st.canceled = True
            return "prefilling", st
        st = self.active.pop(rid, None)
        if st is not None:
            st.canceled = True
            return "active", st
        return None, None

    @property
    def has_pending(self) -> bool:
        return bool(self.pending)

    @property
    def has_prefilling(self) -> bool:
        return bool(self.prefilling)

    @property
    def has_active(self) -> bool:
        return bool(self.active)

    # ---- token stream ---------------------------------------------------
    def on_token(self, rid: int, token: int, now: Optional[float] = None
                 ) -> bool:
        """Record one generated token; returns True if the request finished
        (its slot should be freed).

        Raises :class:`SchedulerError` if ``rid`` is not decode-active —
        a token delivered to a finished (or mid-prefill / canceled /
        unknown) request is an engine bug that must not be silently
        swallowed."""
        now = self._now(now)
        st = self.active.get(rid)
        if st is None or st.done:
            stage = ("finished" if rid in self.finished else
                     "mid-prefill" if rid in self.prefilling else
                     "unknown")
            raise SchedulerError(
                f"token delivered to {stage} request {rid}")
        st.tokens.append(int(token))
        if st.t_first_token is None:
            st.t_first_token = now
            # TTFT enters its window NOW, not at completion: an open-loop
            # bench saturating the engine must see still-streaming
            # requests in p95 TTFT
            self._ttft.append(now - st.t_submit)
        else:
            # inter-token (TPOT) gap — the stall a blocking prefill
            # schedule inflates; percentiles over the recent window,
            # plus the request's own gap list for per-request SLOs
            gap = now - st.t_last_token
            self._itl.append(gap)
            st.itl.append(gap)
        st.t_last_token = now
        eos = st.req.eos_id
        if (eos is not None and token == eos) or \
                len(st.tokens) >= st.req.max_new_tokens:
            st.done = True
            st.t_done = now
            del self.active[rid]
            self.finished[rid] = st
            self._latency.append(st.t_done - st.t_submit)
            if st.t_admit is not None and st.t_active is not None:
                self._queue.append(st.t_admit - st.t_submit)
                self._prefill.append(st.t_active - st.t_admit)
                self._decode.append(st.t_done - st.t_active)
            if self.on_finish is not None:
                self.on_finish(st)
            return True
        return False

    def on_tokens(self, rid: int, tokens, now: Optional[float] = None):
        """Feed a verified speculative block of tokens to one request.

        Acceptance-aware accounting: tokens are consumed in order until
        the request's own termination fires — EOS inside the accepted
        prefix or ``max_new_tokens`` mid-block — exactly as if they had
        been emitted by single-token decode steps.  Returns
        ``(consumed, finished)``: the number of tokens actually recorded
        (the caller rolls the KV cache back to the matching row count)
        and whether the request finished (its lane should be freed).

        **Amortized timestamps**: the whole block lands at one wall
        instant (``now``), so stamping every token with ``now`` would
        record zero-length intra-block gaps and systematically deflate
        spec-mode TPOT percentiles.  Instead the block's wall interval —
        previous block boundary (``t_last_token``) to ``now`` — is
        divided evenly across the tokens actually delivered: token ``i``
        of ``n`` is stamped ``prev + (i+1)/n * (now - prev)``, so the
        last delivered token lands exactly at ``now`` and the recorded
        per-token pace matches what a client draining the stream
        experiences.  A request whose very first delivery is a block (a
        fully-prefix-cached prompt in spec mode) has no previous
        boundary; its tokens all stamp at ``now`` (the instant they
        became available — TTFT is exact, intra-block gaps of that one
        block are zero)."""
        now = self._now(now)
        if len(tokens) == 0:
            return 0, False
        st = self.active.get(rid)
        if st is None or st.done:
            # delegate to on_token for the stage-specific error
            self.on_token(rid, int(tokens[0]), now)
            raise SchedulerError(f"unreachable: request {rid}")  # pragma: no cover
        # how many tokens the request's own termination lets it consume —
        # needed up front so the wall interval amortizes over the tokens
        # actually delivered, not the block's full width
        room = st.req.max_new_tokens - len(st.tokens)
        eos = st.req.eos_id
        n = 0
        for tok in tokens:
            n += 1
            if (eos is not None and int(tok) == eos) or n >= room:
                break
        prev = st.t_last_token
        consumed = 0
        for i, tok in enumerate(tokens):
            t_i = now if prev is None else prev + (i + 1) * (now - prev) / n
            consumed += 1
            if self.on_token(rid, int(tok), t_i):
                return consumed, True
        return consumed, False

    # ---- results --------------------------------------------------------
    def result(self, rid: int, keep: bool = False) -> np.ndarray:
        """Collect a finished request's tokens; pops the state (unless
        ``keep``) so a long-lived engine doesn't accumulate history."""
        st = self.finished[rid] if keep else self.finished.pop(rid)
        out = np.asarray(st.tokens, np.int32)
        eos = st.req.eos_id
        if eos is not None and np.any(out == eos) and \
                int(np.argmax(out == eos)) != len(out) - 1:
            # invariant: generation stopped at the first EOS
            raise SchedulerError(f"tokens after EOS in request {rid}")
        return out

    def latencies(self) -> Dict[str, float]:
        """Latency percentiles (seconds) over the recent windows:
        p50/p95 full-request (per completed request), first-token (TTFT,
        recorded at first-token time — in-flight requests count) and
        inter-token — TPOT, the gap between consecutive tokens of one
        request (present once any request has emitted two tokens)."""
        out: Dict[str, float] = {}
        if self._latency:
            total = np.asarray(self._latency)
            out["p50_latency_s"] = float(np.percentile(total, 50))
            out["p95_latency_s"] = float(np.percentile(total, 95))
        if self._ttft:
            first = np.asarray(self._ttft)
            out["p50_first_token_s"] = float(np.percentile(first, 50))
            out["p95_first_token_s"] = float(np.percentile(first, 95))
        if self._itl:
            itl = np.asarray(self._itl)
            out["p50_inter_token_s"] = float(np.percentile(itl, 50))
            out["p95_inter_token_s"] = float(np.percentile(itl, 95))
        for name, window in (("queue", self._queue),
                             ("prefill", self._prefill),
                             ("decode", self._decode)):
            if window:
                vals = np.asarray(window)
                out[f"p50_{name}_s"] = float(np.percentile(vals, 50))
                out[f"p95_{name}_s"] = float(np.percentile(vals, 95))
        return out

    def reset_latencies(self):
        self._latency.clear()
        self._ttft.clear()
        self._itl.clear()
        self._queue.clear()
        self._prefill.clear()
        self._decode.clear()
