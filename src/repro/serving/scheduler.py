"""Admission queue + per-request lifecycle for the continuous-batching engine.

Requests wait in a FIFO admission queue until a cache slot frees up, then
stream tokens until *their own* termination condition — EOS or
``max_new_tokens`` — and release the slot immediately, so a long request
never makes short batchmates burn decode steps past their end (the seed
engine ran every request to the batch max and sliced afterward).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.

    temperature == 0.0 -> greedy; > 0 -> softmax sampling at that
    temperature.  ``eos_id`` terminates generation early (the EOS token is
    included in the output; nothing after it ever is).
    """
    prompt: np.ndarray                   # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0


@dataclasses.dataclass
class RequestState:
    rid: int
    req: Request
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


class Scheduler:
    def __init__(self, latency_window: int = 1024,
                 max_request_tokens: Optional[int] = None):
        # reject-at-submit bound on prompt + max_new_tokens: a request
        # past the cache's capacity would otherwise queue forever (or
        # corrupt rows if force-admitted), so surface it immediately
        self.max_request_tokens = max_request_tokens
        self._next_rid = 0
        self.pending: collections.deque = collections.deque()
        self.active: Dict[int, RequestState] = {}
        self.finished: Dict[int, RequestState] = {}
        # bounded latency history: a long-lived engine must not grow
        # without bound, so percentile stats run over a recent window
        self._latency: collections.deque = collections.deque(
            maxlen=latency_window)

    # ---- submission / admission ----------------------------------------
    def submit(self, req: Request, now: float = 0.0) -> int:
        if req.max_new_tokens < 1:
            raise ValueError("need at least one generated token")
        total = len(req.prompt) + req.max_new_tokens
        if self.max_request_tokens is not None and \
                total > self.max_request_tokens:
            raise ValueError(
                f"prompt({len(req.prompt)}) + max_new_tokens"
                f"({req.max_new_tokens}) = {total} exceeds the cache "
                f"capacity of {self.max_request_tokens} tokens")
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(RequestState(rid=rid, req=req, t_submit=now))
        return rid

    def admit(self, slot: int) -> RequestState:
        """Move the oldest pending request into a (pre-allocated) slot."""
        st = self.pending.popleft()
        st.slot = slot
        self.active[st.rid] = st
        return st

    @property
    def has_pending(self) -> bool:
        return bool(self.pending)

    @property
    def has_active(self) -> bool:
        return bool(self.active)

    # ---- token stream ---------------------------------------------------
    def on_token(self, rid: int, token: int, now: float = 0.0) -> bool:
        """Record one generated token; returns True if the request finished
        (its slot should be freed)."""
        st = self.active[rid]
        assert not st.done, f"token for finished request {rid}"
        st.tokens.append(int(token))
        if st.t_first_token is None:
            st.t_first_token = now
        eos = st.req.eos_id
        if (eos is not None and token == eos) or \
                len(st.tokens) >= st.req.max_new_tokens:
            st.done = True
            st.t_done = now
            del self.active[rid]
            self.finished[rid] = st
            self._latency.append((st.t_done - st.t_submit,
                                  st.t_first_token - st.t_submit))
            return True
        return False

    def on_tokens(self, rid: int, tokens, now: float = 0.0):
        """Feed a verified speculative block of tokens to one request.

        Acceptance-aware accounting: tokens are consumed in order until
        the request's own termination fires — EOS inside the accepted
        prefix or ``max_new_tokens`` mid-block — exactly as if they had
        been emitted by single-token decode steps.  Returns
        ``(consumed, finished)``: the number of tokens actually recorded
        (the caller rolls the KV cache back to the matching row count)
        and whether the request finished (its lane should be freed).
        """
        consumed = 0
        for tok in tokens:
            consumed += 1
            if self.on_token(rid, int(tok), now):
                return consumed, True
        return consumed, False

    # ---- results --------------------------------------------------------
    def result(self, rid: int, keep: bool = False) -> np.ndarray:
        """Collect a finished request's tokens; pops the state (unless
        ``keep``) so a long-lived engine doesn't accumulate history."""
        st = self.finished[rid] if keep else self.finished.pop(rid)
        out = np.asarray(st.tokens, np.int32)
        eos = st.req.eos_id
        if eos is not None and np.any(out == eos):
            # invariant: generation stopped at the first EOS
            assert int(np.argmax(out == eos)) == len(out) - 1, \
                f"tokens after EOS in request {rid}"
        return out

    def latencies(self) -> Dict[str, float]:
        """p50/p95 full-request and first-token latencies (seconds) over
        the recent completion window."""
        if not self._latency:
            return {}
        total = np.array([t for t, _ in self._latency])
        first = np.array([f for _, f in self._latency])
        return {
            "p50_latency_s": float(np.percentile(total, 50)),
            "p95_latency_s": float(np.percentile(total, 95)),
            "p50_first_token_s": float(np.percentile(first, 50)),
            "p95_first_token_s": float(np.percentile(first, 95)),
        }

    def reset_latencies(self):
        self._latency.clear()
